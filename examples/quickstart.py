"""Quickstart: train FACADE on a small clustered dataset and watch the
minority cluster get fair treatment.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's headline result at CPU scale: a 6:2 imbalanced
two-cluster network (images of the minority cluster rotated 180 deg) where
standard Epidemic Learning under-serves the minority, and FACADE closes
the gap — at the same per-round communication cost.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.configs.facade_paper import lenet
from repro.core.runner import run_experiment
from repro.data.synthetic import SynthSpec, make_clustered_data


def main():
    # --- a clustered dataset with feature skew (paper Sec. V-A) -----------
    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=16,
                     test_per_class=32, seed=3)
    ds = make_clustered_data(spec, cluster_sizes=(6, 2),
                             transforms=("rot0", "rot180"))
    cfg = lenet(smoke=True).replace(n_classes=4)

    print("nodes:", ds.n_nodes, " clusters:", ds.k,
          " node->cluster:", ds.node_cluster.tolist())

    # --- FACADE vs Epidemic Learning --------------------------------------
    results = {}
    for algo in ("el", "facade"):
        print(f"\n=== {algo.upper()} ===")
        res = run_experiment(algo, cfg, ds, rounds=48, k=2, degree=2,
                             local_steps=4, batch_size=8, lr=0.05,
                             eval_every=12, seed=0, verbose=True)
        results[algo] = res

    el, facade = results["el"], results["facade"]
    print("\n================= summary =================")
    print(f"{'':18s}{'majority':>10s}{'minority':>10s}{'fair_acc':>10s}")
    print(f"{'EL':18s}{el.final_acc[0]:10.3f}{el.final_acc[1]:10.3f}"
          f"{el.best_fair_acc():10.3f}")
    print(f"{'FACADE':18s}{facade.final_acc[0]:10.3f}"
          f"{facade.final_acc[1]:10.3f}{facade.best_fair_acc():10.3f}")
    print(f"\nper-round bytes  EL: {el.comm.bytes[0]:.0f}   "
          f"FACADE: {facade.comm.bytes[0]:.0f}  (same cost, Sec. V-E)")
    print(f"final head choice per node: "
          f"{facade.cluster_history[-1][1].tolist()}")


if __name__ == "__main__":
    main()
