"""Serve per-cluster FACADE models with batched requests.

    PYTHONPATH=src python examples/serve_batched.py

The deployment story of the paper: after decentralized training, each
cluster owns a specialized model (shared core + its head). A serving tier
routes each request to its cluster's model and decodes with a KV cache.
This example builds two cluster models from one FACADE state, batches
mixed-cluster requests, groups them per cluster, and decodes.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs  # noqa: F401
from repro.core import split
from repro.core.bindings import make_binding
from repro.core.state import init_facade_state
from repro.models import transformer
from repro.models.base import get_config


def main():
    arch = "llama3.2-1b"
    cfg = get_config(arch, smoke=True)
    binding = make_binding(cfg)
    n, k = 4, 2

    # stand-in for a trained FACADE state (in practice: checkpoint.load)
    state = init_facade_state(binding, jax.random.PRNGKey(0), n, k,
                              head_jitter=0.05)
    state = state._replace(cluster_id=jnp.asarray([0, 0, 1, 1], jnp.int32))

    # one deployable model per cluster: core of a member node + cluster head
    cluster_models = []
    for c in range(k):
        node = int(np.argmax(np.asarray(state.cluster_id) == c))
        core = jax.tree.map(lambda l: l[node], state.cores)
        head = split.select_head(
            jax.tree.map(lambda l: l[node], state.heads), jnp.int32(c))
        cluster_models.append(split.merge_params(core, head))

    # --- mixed request queue: (cluster_id, prompt tokens) ------------------
    rng = np.random.default_rng(0)
    prompt_len, gen_len = 32, 16
    requests = [(int(rng.integers(0, k)),
                 rng.integers(1, cfg.vocab_size, size=prompt_len)
                 .astype(np.int32)) for _ in range(8)]

    @jax.jit
    def prefill(params, toks):
        return transformer.prefill(cfg, params, toks, cache_extra=gen_len)

    @jax.jit
    def decode(params, cache, toks, pos):
        return transformer.decode_step(cfg, params, cache, toks, pos)

    # --- group per cluster, batch, decode ----------------------------------
    for c in range(k):
        batch = [t for cc, t in requests if cc == c]
        if not batch:
            continue
        toks = jnp.asarray(np.stack(batch))
        params = cluster_models[c]
        logits, cache = prefill(params, toks)
        last = jnp.argmax(logits, -1).astype(jnp.int32)
        outs = [np.asarray(last)]
        pos = jnp.full((len(batch),), prompt_len, jnp.int32)
        for _ in range(gen_len - 1):
            logits, cache = decode(params, cache, last[:, None], pos)
            last = jnp.argmax(logits, -1).astype(jnp.int32)
            outs.append(np.asarray(last))
            pos = pos + 1
        gen = np.stack(outs, axis=1)
        print(f"cluster {c}: served {len(batch)} requests; "
              f"generated [{len(batch)}, {gen.shape[1]}] tokens; "
              f"first: {gen[0, :8].tolist()}")

    print("\nall requests served with cluster-specialized models")


if __name__ == "__main__":
    main()
