"""The fairness observatory, end to end: run FACADE with full telemetry,
read the per-eval DP/EO trajectory, check the run-health verdict, and
render the markdown run report.

    PYTHONPATH=src python examples/obs_demo.py

Everything here is pure observation — the run's trajectory is
bit-for-bit what it would have been with ``obs=None`` — and eval-side
fairness telemetry costs ZERO extra device dispatches: the ``EvalFrame``
series is host bookkeeping over arrays the evaluator drains anyway.
"""
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.configs.facade_paper import lenet
from repro.core.runner import run_experiment
from repro.data.synthetic import SynthSpec, make_clustered_data
from repro.obs import Obs, ObsConfig
from repro.obs.report import build_report


def main():
    # --- a small imbalanced clustered dataset (quickstart's setup) --------
    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=16,
                     test_per_class=32, seed=3)
    ds = make_clustered_data(spec, cluster_sizes=(6, 2),
                             transforms=("rot0", "rot180"))
    cfg = lenet(smoke=True).replace(n_classes=4)

    out_dir = pathlib.Path(tempfile.mkdtemp(prefix="obs-demo-"))
    obs = Obs(ObsConfig(), jsonl=out_dir / "trace.jsonl", out_dir=out_dir)

    # --- one FACADE run with the full observatory attached ----------------
    res = run_experiment("facade", cfg, ds, rounds=24, k=2, degree=2,
                         local_steps=4, batch_size=8, lr=0.05,
                         eval_every=4, warmup_rounds=4, seed=0, obs=obs)

    # --- layer 1: the per-eval fairness trajectory ------------------------
    table = obs.eval_table()
    print("\nper-eval fairness trajectory (DP gap over training):")
    for rnd, dp, eo, worst, churn in zip(
            table["round"], table["dp"], table["eo"],
            table["worst_cluster_acc"], table["cluster_churn"]):
        print(f"  round {rnd:3d}: dp={dp:.3f} eo={eo:.3f} "
              f"worst_cluster={worst:.3f} churn={churn:.0f}")
    last = res.eval_frames[-1]
    assert last.dp == res.dp and last.eo == res.eo   # final scalars ARE
    #                                                  the series' last entry

    # --- layer 2: the run-health verdict ----------------------------------
    manifest = obs.manifests[-1]
    print(f"\nhealth verdict: {manifest.health['verdict']}")
    for issue in manifest.health["issues"]:
        print(f"  {issue['rule']} [{issue['severity']}] rounds "
              f"{issue['round_start']}-{issue['round_end']}: "
              f"{issue['detail']}")
    if not manifest.health["issues"]:
        print("  no issues — a clean run")

    # --- layer 3: the rendered report -------------------------------------
    manifest_path = out_dir / f"manifest_{manifest.name}.json"
    _, markdown = build_report(manifest_path)
    print(f"\nrendered report ({manifest_path}):\n")
    print(markdown)
    print("re-render any time with:\n"
          f"  PYTHONPATH=src python -m repro.obs.report {manifest_path}")


if __name__ == "__main__":
    main()
