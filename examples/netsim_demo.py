"""netsim demo: the same FACADE experiment on an ideal network, on flaky
edge devices, through a scheduled partition-then-heal scenario, and under
the netsim-v2 axes — bursty Gilbert–Elliott links, a heterogeneous
core/edge link fabric, and asynchronous stale gossip.

    PYTHONPATH=src python examples/netsim_demo.py

Shows the netsim pieces composing with an unmodified algorithm: preset
conditions (churn/loss/stragglers), the latency/bandwidth cost model
(CommLog grows a simulated-time axis), seeded event schedules (a
reproducible burst failure + partition), per-link Markov loss state and
staleness buffers carried on device through the scan engine. Note how
"async-edge" trades a little accuracy for traffic AND simulated hours
(stale stragglers send nothing and never gate the round) — the
communication-cost axis the paper's Fig. 7 measures. Swap "facade" for
any of "el" / "dpsgd" / "deprl" / "dac" — the `net=` argument works for
all.

The next section reruns the nastiest preset ("edge-v2") with an
adaptive topology policy (`repro.topo`): per-link goodput EWMAs steer the
degree budget toward links that deliver, with a `min_inclusion` fairness
floor so edge-tier nodes stay in the mixture — and prints the
bytes/simulated-hours delta vs the blind uniform sampler.

The final section adds hostile nodes (`repro.resil`): a quarter of the
fleet publishes NaN-poisoned models every round on top of edge-v2's
bursty, tiered, async links. With the robust gossip guard (the default)
the mixture quarantines the poison and both tiers keep learning; with
`robust=False` one bad sender corrupts every neighbourhood within a
couple of rounds — the per-tier accuracy table shows the gap.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.configs.facade_paper import lenet
from repro.core.runner import run_experiment
from repro.data.synthetic import SynthSpec, make_clustered_data
from repro.netsim import BurstFailure, NetworkConfig, Partition
from repro.topo import TopoConfig


def main():
    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=16,
                     test_per_class=32, seed=3)
    ds = make_clustered_data(spec, cluster_sizes=(6, 2),
                             transforms=("rot0", "rot180"))
    cfg = lenet(smoke=True).replace(n_classes=4)

    # a scripted bad day: a third of the fleet dies at round 12 for 6
    # rounds, then the network splits in two camps for rounds 24-32
    bad_day = NetworkConfig.preset(
        "wan", events=(BurstFailure(start=12, duration=6, fraction=0.33),
                       Partition(start=24, duration=8, groups=2)))

    scenarios = {
        "ideal": NetworkConfig.preset("ideal"),
        "edge-churn": NetworkConfig.preset("edge-churn"),
        "wan+events": bad_day,
        # netsim v2: bursty links / core-edge tiers / async stale gossip,
        # then all three at once
        "bursty-wan": NetworkConfig.preset("bursty-wan"),
        "core-edge": NetworkConfig.preset("core-edge"),
        "async-edge": NetworkConfig.preset("async-edge"),
        "edge-v2": NetworkConfig.preset("edge-v2"),
    }

    print(f"{'scenario':<12} {'majority':>9} {'minority':>9} "
          f"{'fair_acc':>9} {'traffic':>10} {'sim time':>9}")
    for name, net in scenarios.items():
        res = run_experiment("facade", cfg, ds, rounds=48, k=2, degree=2,
                             local_steps=4, batch_size=8, lr=0.05,
                             eval_every=12, seed=0, net=net)
        print(f"{name:<12} {res.final_acc[0]:>9.3f} {res.final_acc[1]:>9.3f} "
              f"{res.best_fair_acc():>9.3f} "
              f"{res.comm.bytes[-1]/1e6:>7.1f} MB "
              f"{res.comm.seconds[-1]/3600:>7.2f} h")
        clusters = res.cluster_history[-1][1].tolist()
        print(f"{'':<12} final cluster choice per node: {clusters}")

    # --- adaptive topology (repro.topo) on the nastiest preset: the same
    # --- run with a reliability-driven, fairness-floored sampler instead
    # --- of the blind uniform draw — bytes AND simulated hours drop
    print("\nadaptive vs uniform topology on edge-v2 "
          "(reliability policy, min_inclusion=0.25):")
    kw = dict(rounds=48, k=2, degree=2, local_steps=4, batch_size=8,
              lr=0.05, eval_every=12, seed=0,
              net=NetworkConfig.preset("edge-v2"))
    uni = run_experiment("facade", cfg, ds, **kw)
    ada = run_experiment("facade", cfg, ds,
                         topo=TopoConfig(policy="reliability",
                                         min_inclusion=0.25, decay=0.7),
                         **kw)
    d_bytes = 1.0 - ada.comm.bytes[-1] / uni.comm.bytes[-1]
    d_hours = 1.0 - ada.comm.seconds[-1] / uni.comm.seconds[-1]
    print(f"{'uniform':<12} {uni.comm.bytes[-1]/1e6:7.1f} MB "
          f"{uni.comm.seconds[-1]/3600:7.2f} h "
          f"fair_acc {uni.best_fair_acc():.3f}")
    print(f"{'reliability':<12} {ada.comm.bytes[-1]/1e6:7.1f} MB "
          f"{ada.comm.seconds[-1]/3600:7.2f} h "
          f"fair_acc {ada.best_fair_acc():.3f}")
    print(f"{'':<12} delta: {100*d_bytes:.1f}% fewer bytes, "
          f"{100*d_hours:.1f}% fewer simulated hours")

    # --- hostile nodes (repro.resil) on edge-v2: 25% of senders publish
    # --- NaN-poisoned models each round; the robust gossip guard
    # --- quarantines them, the unguarded mixture collapses
    import dataclasses

    import numpy as np

    from repro.netsim import node_tiers
    from repro.resil import FaultConfig

    print("\nhostile nodes on edge-v2 (25% NaN corruption), robust "
          "guard on vs off:")
    base = NetworkConfig.preset("edge-v2")
    tiers = np.asarray(node_tiers(base, 8))
    print(f"{'guard':<12} {'fair_acc':>9} {'core tier':>10} "
          f"{'edge tier':>10} {'finite':>7}")
    for label, robust in (("robust", True), ("unguarded", False)):
        net = dataclasses.replace(base, faults=FaultConfig(
            corrupt_rate=0.25, corrupt_mode="nan", robust=robust))
        res = run_experiment("facade", cfg, ds, topo=None, net=net, **{
            k: v for k, v in kw.items() if k != "net"})
        acc = np.asarray(res.node_acc, float)
        finite = bool(np.all(np.isfinite(acc)))
        print(f"{label:<12} {res.best_fair_acc():>9.3f} "
              f"{acc[tiers == 0].mean():>10.3f} "
              f"{acc[tiers == 1].mean():>10.3f} "
              f"{'yes' if finite else 'NO':>7}")


if __name__ == "__main__":
    main()
