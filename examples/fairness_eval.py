"""Fairness audit of trained DL models (paper Sec. V-C/V-D).

    PYTHONPATH=src python examples/fairness_eval.py

Trains FACADE and EL briefly on an imbalanced clustered dataset, then
reports the full fairness panel: per-cluster accuracy, fair accuracy
(Eq. 5, sweeping lambda), demographic parity (Eq. 1), equalized odds
(Eq. 2) — the audit a deployment in the paper's hospital scenario would
run before going live.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.configs.facade_paper import lenet
from repro.core.runner import run_experiment
from repro.data.synthetic import SynthSpec, make_clustered_data
from repro.fairness.metrics import fair_accuracy


def main():
    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=16,
                     test_per_class=32, seed=3)
    ds = make_clustered_data(spec, (7, 1), ("rot0", "rot180"))
    cfg = lenet(smoke=True).replace(n_classes=4)

    panel = {}
    for algo in ("el", "facade"):
        res = run_experiment(algo, cfg, ds, rounds=48, k=2, degree=2,
                             local_steps=4, batch_size=8, lr=0.05,
                             eval_every=12, seed=0)
        panel[algo] = res

    print(f"{'metric':34s}{'EL':>10s}{'FACADE':>10s}")
    el, fa = panel["el"], panel["facade"]
    print(f"{'accuracy majority cluster':34s}{el.final_acc[0]:10.3f}"
          f"{fa.final_acc[0]:10.3f}")
    print(f"{'accuracy minority cluster':34s}{el.final_acc[1]:10.3f}"
          f"{fa.final_acc[1]:10.3f}")
    print(f"{'demographic parity (dn)':34s}{el.dp:10.4f}{fa.dp:10.4f}")
    print(f"{'equalized odds (dn)':34s}{el.eo:10.4f}{fa.eo:10.4f}")
    for lam in (0.5, 2 / 3, 0.9):
        fe = fair_accuracy(el.final_acc, lam=lam)
        ff = fair_accuracy(fa.final_acc, lam=lam)
        print(f"fair accuracy (lambda={lam:.2f}){'':11s}{fe:10.3f}"
              f"{ff:10.3f}")

    gap_el = el.final_acc[0] - el.final_acc[1]
    gap_fa = fa.final_acc[0] - fa.final_acc[1]
    print(f"\ncluster accuracy gap: EL {gap_el:+.3f}  FACADE {gap_fa:+.3f}")
    if gap_fa < gap_el:
        print("FACADE reduces the majority/minority gap "
              "(the paper's Fig. 3 finding).")


if __name__ == "__main__":
    main()
