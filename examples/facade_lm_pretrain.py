"""End-to-end driver: FACADE pretraining of a ~1M-param transformer
(llama3.2-1b family, reduced config) on clustered token streams for a few
hundred rounds.

    PYTHONPATH=src python examples/facade_lm_pretrain.py [--rounds 150]

This is the 'train a ~100M-class model for a few hundred steps' deliverable
scaled to the CPU container: the FULL llama3.2-1b config runs the same code
path on the production mesh (see repro/launch/dryrun.py --facade).

Feature heterogeneity for language = per-cluster vocabulary permutation
(structure preserved, surface statistics shifted — the LM analogue of the
paper's image rotations). FACADE's heads (final_norm + lm_head) specialize
per cluster; the transformer core is shared.
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs  # noqa: F401
from repro.core import facade as facade_mod
from repro.core.bindings import make_binding
from repro.core.state import init_facade_state
from repro.data import tokens as tokens_mod
from repro.models.base import get_config


def evaluate(binding, state, data, seq):
    """Per-cluster mean NLL of each node's deployed model on its cluster's
    held-out stream."""
    from repro.core import split
    k = len(data["test"])
    node_cluster = data["node_cluster"]
    losses = [[] for _ in range(k)]
    for i, c in enumerate(node_cluster):
        core = jax.tree.map(lambda l: l[i], state.cores)
        heads = jax.tree.map(lambda l: l[i], state.heads)
        head = split.select_head(heads, state.cluster_id[i])
        params = split.merge_params(core, head)
        test = data["test"][c][:8]
        batch = {kk: jnp.asarray(vv)
                 for kk, vv in tokens_mod.lm_batch(test).items()}
        losses[c].append(float(binding.loss(params, batch)))
    return [float(np.mean(l)) for l in losses if l]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--nodes", type=int, nargs="+", default=[3, 1])
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--eval-every", type=int, default=25)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    binding = make_binding(cfg)
    n = sum(args.nodes)
    k = len(args.nodes)

    tspec = tokens_mod.TokenSpec(vocab_size=cfg.vocab_size,
                                 seq_len=args.seq + 1, seed=0)
    data = tokens_mod.make_clustered_tokens(
        tspec, tuple(args.nodes),
        seqs_per_node=args.rounds * args.local_steps * args.batch // 4)
    train = data["train"]  # [n, N, S+1]

    fcfg = facade_mod.FacadeConfig(n_nodes=n, k=k, degree=min(2, n - 1),
                                   local_steps=args.local_steps, lr=args.lr,
                                   head_jitter=1e-3)
    state = init_facade_state(binding, jax.random.PRNGKey(0), n, k,
                              head_jitter=1e-3)
    import functools
    round_fn = jax.jit(functools.partial(facade_mod.facade_round,
                                         fcfg, binding))

    rng = np.random.default_rng(0)
    t0 = time.time()
    for rnd in range(args.rounds):
        idx = rng.integers(0, train.shape[1],
                           size=(n, args.local_steps, args.batch))
        rows = train[np.arange(n)[:, None, None], idx]  # [n,H,B,S+1]
        batch = {kk: jnp.asarray(vv)
                 for kk, vv in tokens_mod.lm_batch(rows).items()}
        state, info = round_fn(state, batch)
        if (rnd + 1) % args.eval_every == 0 or rnd == 0:
            nll = evaluate(binding, state, data, args.seq)
            print(f"round {rnd+1:4d}  per-cluster NLL {nll}  "
                  f"heads {np.asarray(state.cluster_id).tolist()}  "
                  f"({(rnd+1)/(time.time()-t0):.2f} rounds/s)", flush=True)

    print("\nfinal head assignment:", np.asarray(state.cluster_id).tolist())
    print("true clusters:        ", data["node_cluster"].tolist())


if __name__ == "__main__":
    main()
