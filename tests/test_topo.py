"""repro.topo: adaptive, netsim-aware topology policies with a fairness
floor.

Pins the subsystem's contracts: ``topo=None`` and
``TopoConfig(policy="uniform")`` are bit-for-bit the legacy sampling path
for FACADE + all four baselines on BOTH drivers; adaptive policies stay
engine/legacy bit-identical (the EWMA state rides the donated carry vs
the Python loop); the sampler keeps its structural invariants (symmetry,
zero diagonal, edge budget) and its deterministic fairness floor
(participation probability >= ``min_inclusion`` under hostile scores);
the EWMAs actually learn the simulated network; and the out-of-range
degree validation regression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import netsim
from repro import topo as topo_mod
from repro.configs.facade_paper import lenet
from repro.core import topology
from repro.core.cache import EngineSpec
from repro.core.netwire import comm_info
from repro.core.runner import run_experiment
from repro.data.synthetic import SynthSpec, make_clustered_data
from repro.netsim import NetworkConfig, RoundConditions
from repro.topo import TopoConfig, TopoState, inclusion_stats

pytestmark = pytest.mark.tier0

CFG = lenet(smoke=True).replace(n_classes=4)
ALL_ALGOS = ("facade", "el", "dpsgd", "deprl", "dac")
KW = dict(rounds=3, k=2, degree=2, local_steps=2, batch_size=4, lr=0.05,
          eval_every=1, seed=0)
ADAPTIVE = TopoConfig(policy="reliability", min_inclusion=0.2, decay=0.7)


@pytest.fixture(scope="module")
def tiny_ds():
    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    return make_clustered_data(spec, cluster_sizes=(3, 1),
                               transforms=("rot0", "rot180"))


def _assert_runs_identical(ref, got):
    assert ref.acc_per_cluster == got.acc_per_cluster
    assert ref.fair_acc == got.fair_acc
    assert ref.dp == got.dp and ref.eo == got.eo
    assert ref.final_acc == got.final_acc
    assert ref.comm.rounds == got.comm.rounds
    assert ref.comm.bytes == got.comm.bytes          # exact float equality
    assert ref.comm.seconds == got.comm.seconds
    np.testing.assert_array_equal(np.asarray(ref.node_acc),
                                  np.asarray(got.node_acc))
    for (r1, c1), (r2, c2) in zip(ref.cluster_history, got.cluster_history):
        assert r1 == r2
        np.testing.assert_array_equal(c1, c2)


def _hostile_state(n, weak=0, lo=1e-8, hi=5.0):
    """Scores engineered to starve node ``weak``: every link touching it
    is (near) worthless, every other link is great."""
    d = np.full((n, n), hi, np.float32)
    d[weak, :] = d[:, weak] = lo
    np.fill_diagonal(d, 0.0)
    return TopoState(delivery=jnp.asarray(d),
                     link_s=jnp.asarray(np.ones((n, n), np.float32)))


# -------------------------------------------------- uniform bit-parity ---
@pytest.mark.parametrize("engine", [True, False], ids=["engine", "legacy"])
@pytest.mark.parametrize("algo", ALL_ALGOS)
def test_uniform_policy_is_legacy_bitforbit(algo, engine, tiny_ds):
    """THE compatibility contract: ``TopoConfig(policy='uniform')`` and
    ``topo=None`` produce identical trajectories, bytes AND simulated
    seconds on both drivers — the round functions never even branch into
    the adaptive sampler (same PRNG splits, same graphs)."""
    net = NetworkConfig.preset("core-edge")
    ref = run_experiment(algo, CFG, tiny_ds, net=net, engine=engine, **KW)
    uni = run_experiment(algo, CFG, tiny_ds, net=net, engine=engine,
                         topo=TopoConfig(), **KW)
    _assert_runs_identical(ref, uni)


def test_uniform_policy_parity_without_netsim(tiny_ds):
    ref = run_experiment("el", CFG, tiny_ds, **KW)
    uni = run_experiment("el", CFG, tiny_ds, topo=TopoConfig(), **KW)
    _assert_runs_identical(ref, uni)


# ------------------------------------------- adaptive engine == legacy ---
@pytest.mark.parametrize("algo", ALL_ALGOS)
def test_adaptive_engine_matches_legacy_bitforbit(algo, tiny_ds):
    """The TopoState EWMAs ride the donated scan carry in the engine and
    a Python variable in the legacy loop — both must advance identically
    (the same ``repro.topo.advance``/``sample`` calls, like netsim's
    shared ``advance_conditions``)."""
    net = NetworkConfig.preset("core-edge")
    eng = run_experiment(algo, CFG, tiny_ds, net=net, topo=ADAPTIVE,
                         engine=True, **KW)
    leg = run_experiment(algo, CFG, tiny_ds, net=net, topo=ADAPTIVE,
                         engine=False, **KW)
    _assert_runs_identical(eng, leg)


def test_adaptive_runs_under_every_v2_preset(tiny_ds):
    for preset in ("bursty-wan", "core-edge", "edge-v2"):
        res = run_experiment("facade", CFG, tiny_ds,
                             net=NetworkConfig.preset(preset),
                             topo=ADAPTIVE, **KW)
        assert np.isfinite(res.comm.bytes[-1])
        assert np.isfinite(res.comm.seconds[-1])
        assert all(np.isfinite(a) for a in res.final_acc)
        assert res.node_acc is not None and len(res.node_acc) == 4


def test_adaptive_without_netsim_counts_actual_bytes(tiny_ds):
    """With no netsim, the legacy path reports the nominal n*degree byte
    count; an adaptive policy draws a varying graph, so its bytes must
    count the real directed edges instead (and never exceed nominal by
    construction of the edge budget)."""
    ref = run_experiment("el", CFG, tiny_ds, **KW)
    ada = run_experiment("el", CFG, tiny_ds, topo=ADAPTIVE, **KW)
    assert ada.comm.bytes[-1] <= ref.comm.bytes[-1]
    assert ada.comm.bytes[-1] > 0


def test_comm_info_actual_flag():
    n = 4
    adj = jnp.asarray(topology.ring(n, 2))
    nominal = comm_info(None, adj, 100.0, n * 2)
    actual = comm_info(None, adj, 100.0, n * 2, actual=True)
    assert float(nominal["round_bytes"]) == n * 2 * 100.0
    assert float(actual["round_bytes"]) == float(adj.sum()) * 100.0


# ---------------------------------------------------- sampler contract ---
def test_sample_structural_invariants():
    cfg = TopoConfig(policy="reliability", min_inclusion=0.2)
    n = 12
    for r in (1, 2, 4, 5):
        for seed in range(4):
            state = _hostile_state(n, weak=seed % n)
            adj = np.asarray(topo_mod.sample(
                cfg, state, jax.random.PRNGKey(seed), n, r))
            kpick = max(1, r // 2)
            assert np.array_equal(adj, adj.T)
            assert np.all(np.diag(adj) == 0)
            assert set(np.unique(adj)) <= {0.0, 1.0}
            # edge budget: never more undirected edges than the legacy
            # r-regular draw spends (each row contributes <= kpick picks)
            assert adj.sum() <= 2 * n * kpick


def test_sample_deterministic_in_key():
    cfg = TopoConfig(policy="bandwidth", min_inclusion=0.3)
    state = _hostile_state(8, weak=3)
    a = topo_mod.sample(cfg, state, jax.random.PRNGKey(7), 8, 4)
    b = topo_mod.sample(cfg, state, jax.random.PRNGKey(7), 8, 4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_participation_floor_is_exact_under_hostile_scores():
    """The deterministic fairness guarantee: participation probability
    >= min_inclusion for EVERY node no matter the scores — including the
    all-zero matrix, where score normalization could divide by zero."""
    n = 10
    for floor in (0.0, 0.1, 0.25, 0.9, 1.0):
        cfg = TopoConfig(policy="reliability", min_inclusion=floor)
        for state in (_hostile_state(n, weak=2),
                      TopoState(delivery=jnp.zeros((n, n)),
                                link_s=jnp.ones((n, n)))):
            p = np.asarray(topo_mod.participation_probs(cfg, state))
            assert np.all(p >= floor - 1e-7)
            assert np.all(p <= 1.0 + 1e-7)
    # and the best-connected node always participates
    cfg = TopoConfig(policy="reliability", min_inclusion=0.2)
    p = np.asarray(topo_mod.participation_probs(
        cfg, _hostile_state(n, weak=2)))
    assert p.max() == pytest.approx(1.0, abs=1e-6)


def test_starved_node_inclusion_frequency_meets_floor():
    """Empirical twin of the exact guarantee: over many rounds with a
    hostile score matrix, the starved node still lands in the graph at
    ~min_inclusion frequency (binomial tolerance), while without a floor
    it would vanish."""
    n, r, rounds, floor = 10, 4, 400, 0.25
    cfg = TopoConfig(policy="reliability", min_inclusion=floor)
    state = _hostile_state(n, weak=0)
    included = np.zeros(n)
    for rnd in range(rounds):
        adj = np.asarray(topo_mod.sample(
            cfg, state, jax.random.fold_in(jax.random.PRNGKey(0), rnd),
            n, r))
        included += adj.sum(1) > 0
    freq = included / rounds
    sigma = np.sqrt(floor * (1 - floor) / rounds)
    assert freq[0] >= floor - 3 * sigma
    # the healthy nodes participate (almost) always
    assert freq[1:].min() > 0.9


def test_topo_degree_budget_override(tiny_ds):
    """``TopoConfig.degree`` overrides the run degree for EVERY
    algorithm's adaptive sampler (including DAC, which routes through
    the shared ``gumbel_graph`` pipeline), and the sampler's edge budget
    follows the override."""
    assert topo_mod.budget(None, 2) == 2
    assert topo_mod.budget(TopoConfig(), 2) == 2
    override = TopoConfig(policy="reliability", degree=3, min_inclusion=0.2)
    assert topo_mod.budget(override, 2) == 3
    n = 12
    state = _hostile_state(n, weak=1)
    wide = TopoConfig(policy="reliability", degree=8, min_inclusion=1.0)
    adj = np.asarray(topo_mod.sample(wide, state, jax.random.PRNGKey(0),
                                     n, 2))
    assert adj.sum() <= 2 * n * 4            # budget follows the override
    assert adj.sum() > 2 * n * 1             # ...and actually uses it
    for algo in ("dac", "el"):
        res = run_experiment(algo, CFG, tiny_ds, topo=override,
                             net=NetworkConfig.preset("core-edge"), **KW)
        assert np.isfinite(res.comm.bytes[-1])


def test_inclusion_stats_on_core_edge():
    net = NetworkConfig.preset("core-edge")
    cfg = TopoConfig(policy="reliability", min_inclusion=0.3)
    st = inclusion_stats(cfg, net, n=10, rounds=300, degree=4)
    assert st["symmetric"] and st["binary"]
    assert st["mean_edges"] <= st["edge_budget"]
    sigma = np.sqrt(0.3 * 0.7 / 300)
    assert st["inclusion"].min() >= 0.3 - 3 * sigma
    assert st["participation"].min() >= 0.3 - 3 * sigma
    with pytest.raises(ValueError, match="adaptive"):
        inclusion_stats(TopoConfig(), net, n=10, rounds=10, degree=4)


# ------------------------------------------------------- EWMA learning ---
def test_advance_learns_the_simulated_network():
    """Rolling the EWMAs under core-edge conditions must separate the
    tiers: links touching an edge node end up with a larger learned
    link-time than core-core links, and delivery stays a valid rate."""
    net = NetworkConfig.preset("core-edge", seed=5)
    cfg = TopoConfig(policy="reliability", decay=0.7)
    n = 12
    state = topo_mod.init_state(cfg, net, n)
    chan = netsim.init_channel(net, n)
    for rnd in range(40):
        conds, chan = netsim.advance_conditions(net, n, rnd, chan)
        state = topo_mod.advance(cfg, net, state, conds)
    tiers = np.asarray(netsim.node_tiers(net, n))
    assert 0 < tiers.sum() < n                    # both tiers present
    link_s = np.asarray(state.link_s)
    delivery = np.asarray(state.delivery)
    np.testing.assert_array_equal(link_s, link_s.T)
    assert np.all(np.diag(link_s) == 0) and np.all(np.diag(delivery) == 0)
    off = ~np.eye(n, dtype=bool)
    assert np.all(delivery[off] >= 0) and np.all(delivery[off] <= 1)
    core = np.where(tiers == 0)[0]
    edge = np.where(tiers == 1)[0]
    core_core = link_s[np.ix_(core, core)][~np.eye(len(core), dtype=bool)]
    edge_any = link_s[edge]                   # every link touching an edge
    edge_any = edge_any[edge_any > 0]         # node (drop the zero diag)
    assert edge_any.mean() > core_core.mean() * 2


def test_advance_is_noop_without_conditions():
    cfg = TopoConfig(policy="reliability")
    state = topo_mod.init_state(cfg, None, 6)
    assert topo_mod.advance(cfg, None, state, None) is state
    assert topo_mod.init_state(TopoConfig(), None, 6) is None
    assert topo_mod.init_state(None, None, 6) is None


# ---------------------------------------------------------- validation ---
def test_topoconfig_validation():
    with pytest.raises(ValueError, match="policy"):
        TopoConfig(policy="psychic")
    with pytest.raises(ValueError, match="min_inclusion"):
        TopoConfig(min_inclusion=1.5)
    with pytest.raises(ValueError, match="decay"):
        TopoConfig(decay=1.0)


def test_out_of_range_degree_raises():
    """Regression: builders used to silently collapse multi-edges when
    degree >= n — now they fail loudly, as does run_experiment."""
    key = jax.random.PRNGKey(0)
    topology.random_regular(key, 4, 3)                # n-1 is fine
    for bad in (0, 4, 7):
        with pytest.raises(ValueError, match="degree"):
            topology.random_regular(key, 4, bad)
        with pytest.raises(ValueError, match="degree"):
            topology.ring(4, bad)


def test_run_experiment_rejects_out_of_range_degree(tiny_ds):
    kw = {k: v for k, v in KW.items() if k != "degree"}
    with pytest.raises(ValueError, match="degree"):
        run_experiment("el", CFG, tiny_ds, degree=tiny_ds.n_nodes, **kw)
    with pytest.raises(ValueError, match="degree"):
        run_experiment("el", CFG, tiny_ds, degree=0, **kw)
    with pytest.raises(ValueError, match="degree"):
        # the TopoConfig degree override is validated too
        run_experiment("el", CFG, tiny_ds, degree=2,
                       topo=TopoConfig(policy="reliability",
                                       degree=tiny_ds.n_nodes), **kw)


# ------------------------------------------------------ cache-key pins ---
# Every TopoConfig field must perturb the EngineSpec key (the topo config
# IS a key component); the table below must track the dataclass exactly,
# so a new knob without an entry fails the completeness check. Mirrors
# the NetworkConfig contract in tests/test_property.py, but hypothesis-
# free so it runs everywhere.
_TOPO_PERTURB = {
    "policy": lambda v: "reliability" if v != "reliability" else "bandwidth",
    "decay": lambda v: (v + 0.1) % 1.0,
    "degree": lambda v: 3 if v is None else v + 1,
    "min_inclusion": lambda v: (v + 0.05) % 1.0,
    "ref_payload_bytes": lambda v: v + 1.0,
    "seed": lambda v: v + 1,
}


def test_topo_perturb_covers_every_topoconfig_field():
    fields = {f.name for f in dataclasses.fields(TopoConfig)}
    assert fields == set(_TOPO_PERTURB)


def test_every_topoconfig_field_forks_the_cache_key():
    def spec(topo):
        return EngineSpec(algo="el", cfg=CFG, n=4, k=2, degree=2,
                          local_steps=2, batch_size=4, lr=0.05, topo=topo)

    base_topo = TopoConfig(policy="reliability")
    base = spec(base_topo)
    assert base == spec(TopoConfig(policy="reliability"))
    assert spec(None) != base                  # topo on/off forks
    assert spec(None) != spec(TopoConfig())    # uniform config still keys
    for field, perturb in _TOPO_PERTURB.items():
        mutated = spec(dataclasses.replace(
            base_topo, **{field: perturb(getattr(base_topo, field))}))
        assert mutated != base, field
        table = {base: "b", mutated: "m"}
        assert table[base] == "b" and table[mutated] == "m"
