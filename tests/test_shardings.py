"""Sharding rule engine: divisibility fallbacks, layout choices, and the
abstract (device-free) parts of the dry-run plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import shardings
from repro.configs import INPUT_SHAPES
from repro.launch.steps import is_supported, resolve_config
from repro.models.base import get_config


class FakeMesh:
    """Duck-typed mesh: shardings.py only reads .shape."""

    def __init__(self, **axes):
        self.shape = dict(axes)


MESH = FakeMesh(data=16, model=16)


def test_col_rule_shards_last_dim():
    spec = shardings.leaf_spec("layers/attn/wq", (2048, 4096), MESH)
    assert spec[-1] == "model"


def test_row_rule_shards_second_to_last():
    spec = shardings.leaf_spec("layers/attn/wo", (4096, 2048), MESH)
    assert spec[0] in ("model", "data")  # row -> model preferred
    assert spec[0] == "model"


def test_indivisible_dim_falls_back():
    # vocab 73448 = 8*9181 not divisible by 16 -> lm_head falls to dim -2
    spec = shardings.leaf_spec("lm_head", (2560, 73448), MESH)
    assert spec[-1] is None
    assert spec[-2] == "model"


def test_fully_indivisible_replicates():
    spec = shardings.leaf_spec("layers/attn/wq", (7, 9), MESH)
    assert all(s is None for s in spec)


def test_expert_rule_uses_expert_axis():
    # [E, D, F] with E=64 divisible by 16
    spec = shardings.leaf_spec("layers/moe/w_gate", (64, 2048, 1408), MESH)
    assert spec[0] == "model"


def test_expert_rule_fallback_to_col():
    # 8 experts < 16 -> shard inner dim instead
    spec = shardings.leaf_spec("layers/moe/w_gate", (8, 6144, 32768), MESH)
    assert spec[0] is None
    assert "model" in spec


def test_fsdp_shards_largest_free_dim():
    spec = shardings.leaf_spec("layers/attn/wq", (4096, 4096), MESH,
                               fsdp=True)
    assert "data" in spec and "model" in spec


def test_small_leaf_not_fsdp_sharded():
    spec = shardings.leaf_spec("layers/norm1", (4096,), MESH, fsdp=True)
    assert all(s is None for s in spec) or spec[0] != "data"


def test_batch_specs_multi_pod():
    mesh = FakeMesh(pod=2, data=16, model=16)
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    specs = shardings.batch_specs(batch, mesh)
    assert specs["tokens"][0] == ("pod", "data")


def test_batch_specs_indivisible_batch_falls_to_seq():
    # batch=3 not divisible -> the seq dim takes the data axis instead
    batch = {"tokens": jax.ShapeDtypeStruct((3, 64), jnp.int32)}
    specs = shardings.batch_specs(batch, MESH)
    assert specs["tokens"] == P(None, "data")


def test_batch_specs_nothing_divisible_replicates():
    batch = {"tokens": jax.ShapeDtypeStruct((3, 7), jnp.int32)}
    specs = shardings.batch_specs(batch, MESH)
    assert specs["tokens"] == P(None, None)


# --------------------------------------------------------------------------
def test_long_ctx_support_table():
    """Skips exactly match DESIGN.md: 4 full-attention archs skip long_500k."""
    skips = [(a, s) for a in
             ("minicpm3-4b grok-1-314b deepseek-moe-16b hymba-1.5b "
              "stablelm-12b llava-next-34b whisper-tiny qwen3-8b "
              "llama3.2-1b rwkv6-1.6b").split()
             for s in INPUT_SHAPES if not is_supported(a, s)]
    assert sorted(skips) == sorted([
        ("grok-1-314b", "long_500k"), ("deepseek-moe-16b", "long_500k"),
        ("llava-next-34b", "long_500k"), ("whisper-tiny", "long_500k")])


def test_long_ctx_swa_variant():
    cfg = resolve_config("llama3.2-1b", "long_500k")
    assert cfg.sliding_window == 8192
    cfg = resolve_config("llama3.2-1b", "train_4k")
    assert cfg.sliding_window == 0


def test_unroll_resolve():
    cfg = resolve_config("llama3.2-1b", "train_4k", unroll=True)
    assert cfg.scan_unroll == cfg.n_layers


@pytest.mark.parametrize("arch", ["llama3.2-1b", "grok-1-314b",
                                  "rwkv6-1.6b", "whisper-tiny"])
def test_param_specs_cover_full_tree(arch):
    """Every full-config param leaf gets a PartitionSpec of matching rank."""
    from repro.models import api
    cfg = get_config(arch)
    sds = jax.eval_shape(
        lambda k: api.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = shardings.param_specs(sds, MESH)
    flat_s, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree.leaves(sds)
    assert len(flat_s) == len(flat_l)
    for sp, leaf in zip(flat_s, flat_l):
        assert isinstance(sp, P)
        assert len(sp) <= len(leaf.shape)
        # every named axis divides its dim
        for d, ax in enumerate(sp):
            if ax is None:
                continue
            size = np.prod([MESH.shape[a] for a in
                            (ax if isinstance(ax, tuple) else (ax,))])
            assert leaf.shape[d] % size == 0, (arch, sp, leaf.shape)