"""repro.netsim: condition masks, timing model, event schedules, and the
netsim path through facade/baseline rounds (ideal == bit-for-bit legacy).

netsim v2: Gilbert–Elliott bursty links (carried channel state),
heterogeneous core/edge link matrices, and async stale gossip — including
the zero-staleness parity contract (async with ``max_staleness=0`` is
bit-for-bit the synchronous path for all five algorithms)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.facade_paper import lenet
from repro.core import facade as facade_mod
from repro.core import topology
from repro.core.baselines import (DACConfig, DeprlConfig, DpsgdConfig,
                                  ELConfig, dac_round, deprl_round,
                                  dpsgd_round, el_round, init_dac_extra)
from repro.core.bindings import make_binding
from repro.core.runner import run_experiment
from repro.core.state import init_baseline_state, init_facade_state
from repro.data.synthetic import SynthSpec, make_clustered_data
from repro import netsim
from repro.netsim import (BurstConfig, BurstFailure, LinkClasses,
                          NetworkConfig, Partition, RoundConditions,
                          round_conditions)

pytestmark = pytest.mark.tier0

N, K, H, B = 4, 2, 2, 4
ALL_ALGOS = ("facade", "el", "dpsgd", "deprl", "dac")


def _ones_conditions(n):
    return RoundConditions(edge_mask=jnp.ones((n, n), jnp.float32),
                           active=jnp.ones((n,), jnp.float32),
                           straggler=jnp.zeros((n,), jnp.float32))


@pytest.fixture(scope="module")
def setup():
    cfg = lenet(smoke=True).replace(n_classes=4)
    binding = make_binding(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (N, H, B, cfg.image_size, cfg.image_size, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (N, H, B), 0, 4,
                           dtype=jnp.int32)
    return cfg, binding, {"x": x, "y": y}


# ----------------------------------------------------------- conditions --
def test_presets_exist_and_ideal_is_clean():
    for name in ("ideal", "lan", "wan", "edge-churn", "hostile",
                 "bursty-wan", "core-edge", "async-edge", "edge-v2"):
        NetworkConfig.preset(name)
    ideal = NetworkConfig.preset("ideal")
    c = round_conditions(ideal, 8, 0)
    assert float(c.active.sum()) == 8
    assert float(c.straggler.sum()) == 0
    # every off-diagonal edge delivered
    assert float((c.edge_mask * (1 - np.eye(8))).sum()) == 8 * 7
    with pytest.raises(ValueError):
        NetworkConfig.preset("nope")


def test_conditions_deterministic_and_edge_mask_symmetric():
    net = NetworkConfig.preset("hostile", seed=5)
    a = round_conditions(net, 12, 7)
    b = round_conditions(net, 12, 7)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    em = np.asarray(a.edge_mask)
    np.testing.assert_array_equal(em, em.T)
    assert set(np.unique(em)) <= {0.0, 1.0}


def test_churn_respects_outage_blocks():
    net = NetworkConfig.preset("edge-churn", seed=1)
    L = net.outage_rounds
    a0 = np.asarray(netsim.availability(net, 32, 0))
    for r in range(1, L):
        np.testing.assert_array_equal(
            a0, np.asarray(netsim.availability(net, 32, r)))


# ------------------------------------------------- masked mixing matrix --
def test_masked_mixing_row_stochastic_with_zero_degree_nodes():
    key = jax.random.PRNGKey(0)
    adj = topology.random_regular(key, 10, 4)
    active = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 1, 0, 1], jnp.float32)
    em = np.ones((10, 10), np.float32)
    em[3, :] = em[:, 3] = 0.0            # node 3 loses every message too
    eff = topology.effective_adjacency(adj, jnp.asarray(em), active)
    w = np.asarray(topology.mixing_matrix(eff))
    np.testing.assert_allclose(w.sum(1), 1.0, rtol=1e-6)
    assert np.all(w >= 0)
    # fully cut-off nodes keep exactly their own model
    for i in (1, 3, 4, 8):
        row = np.zeros(10); row[i] = 1.0
        np.testing.assert_allclose(w[i], row)


# ----------------------------------------------------------- facade path --
def test_ideal_masks_reproduce_facade_round_bitforbit(setup):
    cfg, binding, batches = setup
    fcfg = facade_mod.FacadeConfig(n_nodes=N, k=K, degree=2, local_steps=H,
                                   lr=0.05)
    state = init_facade_state(binding, jax.random.PRNGKey(0), N, K)
    s_ref, _ = facade_mod.facade_round(fcfg, binding, state, batches)
    s_net, info = facade_mod.facade_round(fcfg, binding, state, batches,
                                          net=_ones_conditions(N))
    for a, b in zip(jax.tree.leaves(s_ref.cores), jax.tree.leaves(s_net.cores)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(s_ref.heads), jax.tree.leaves(s_net.heads)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(s_ref.cluster_id),
                                  np.asarray(s_net.cluster_id))
    assert "adj_eff" in info and "payload_bytes" in info


def test_churned_out_node_is_frozen(setup):
    cfg, binding, batches = setup
    fcfg = facade_mod.FacadeConfig(n_nodes=N, k=K, degree=2, local_steps=H,
                                   lr=0.05)
    state = init_facade_state(binding, jax.random.PRNGKey(0), N, K)
    state = state._replace(cluster_id=jnp.asarray([0, 1, 0, 1], jnp.int32))
    conds = _ones_conditions(N)._replace(
        active=jnp.asarray([1, 1, 0, 1], jnp.float32))
    s2, _ = facade_mod.facade_round(fcfg, binding, state, batches, net=conds)
    for old, new in zip(jax.tree.leaves(state.cores), jax.tree.leaves(s2.cores)):
        np.testing.assert_array_equal(np.asarray(old)[2], np.asarray(new)[2])
        assert not np.array_equal(np.asarray(old)[0], np.asarray(new)[0])
    for old, new in zip(jax.tree.leaves(state.heads), jax.tree.leaves(s2.heads)):
        np.testing.assert_array_equal(np.asarray(old)[2], np.asarray(new)[2])
    assert int(s2.cluster_id[2]) == int(state.cluster_id[2])


# --------------------------------------------------------- baseline path --
BASELINES = [
    ("el", ELConfig, el_round),
    ("dpsgd", DpsgdConfig, dpsgd_round),
    ("deprl", DeprlConfig, deprl_round),
    ("dac", DACConfig, dac_round),
]


@pytest.mark.parametrize("name,cfg_cls,round_fn", BASELINES,
                         ids=[b[0] for b in BASELINES])
def test_baseline_ideal_bitforbit_and_freeze(name, cfg_cls, round_fn, setup):
    cfg, binding, batches = setup
    acfg = cfg_cls(n_nodes=N, degree=2, local_steps=H, lr=0.05)
    extra = init_dac_extra(N) if name == "dac" else None
    state = init_baseline_state(binding, jax.random.PRNGKey(0), N, extra=extra)

    s_ref, _ = round_fn(acfg, binding, state, batches)
    s_net, info = round_fn(acfg, binding, state, batches,
                           net=_ones_conditions(N))
    for a, b in zip(jax.tree.leaves(s_ref.params),
                    jax.tree.leaves(s_net.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert "adj_eff" in info

    conds = _ones_conditions(N)._replace(
        active=jnp.asarray([1, 0, 1, 1], jnp.float32))
    s_frozen, _ = round_fn(acfg, binding, state, batches, net=conds)
    for old, new in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(s_frozen.params)):
        np.testing.assert_array_equal(np.asarray(old)[1], np.asarray(new)[1])


# ---------------------------------------------------------------- events --
def test_event_schedule_deterministic_and_windowed():
    events = (BurstFailure(start=2, duration=3, fraction=0.5),
              Partition(start=4, duration=2, groups=2))
    net = NetworkConfig(name="evt", events=events, seed=9)
    n = 16
    # outside every window: clean masks
    c = round_conditions(net, n, 0)
    assert float(c.active.sum()) == n
    # burst window: same victims on every covered round
    a2 = np.asarray(round_conditions(net, n, 2).active)
    a3 = np.asarray(round_conditions(net, n, 3).active)
    np.testing.assert_array_equal(a2, a3)
    assert 0 < a2.sum() < n
    # heals after the window
    assert float(round_conditions(net, n, 5).active.sum()) == n
    # partition: cross-camp edges die, replays identically
    e4 = np.asarray(round_conditions(net, n, 4).edge_mask)
    e4b = np.asarray(round_conditions(net, n, 4).edge_mask)
    np.testing.assert_array_equal(e4, e4b)
    assert (e4 * (1 - np.eye(n))).sum() < n * (n - 1)
    assert float(np.asarray(round_conditions(net, n, 6).edge_mask)
                 [np.triu_indices(n, 1)].sum()) == n * (n - 1) / 2


# -------------------------------------------------- bursty channel (v2) --
def test_burst_channel_deterministic_symmetric_binary():
    """The carried Gilbert–Elliott chain replays under a fixed seed and
    keeps masks symmetric {0,1}; fixed-parameter twins of the hypothesis
    properties (stationary loss rate, mean burst length ~ 1/p_recover)."""
    burst = BurstConfig(p_bad=0.2, p_recover=0.5, drop_good=0.0,
                        drop_bad=1.0)
    net = NetworkConfig(name="ge", seed=11, burst=burst)
    n = 8
    chan = netsim.init_channel(net, n)
    chan_b = netsim.init_channel(net, n)
    for rnd in range(4):
        a, chan = netsim.advance_conditions(net, n, rnd, chan)
        b, chan_b = netsim.advance_conditions(net, n, rnd, chan_b)
        em = np.asarray(a.edge_mask)
        np.testing.assert_array_equal(em, np.asarray(b.edge_mask))
        np.testing.assert_array_equal(np.asarray(chan.bad),
                                      np.asarray(chan_b.bad))
        np.testing.assert_array_equal(em, em.T)
        assert set(np.unique(em)) <= {0.0, 1.0}
        assert np.all(np.diag(np.asarray(chan.bad)) == 0)

    stats = netsim.channel_stats(net, n=6, rounds=600)
    assert stats["symmetric"] and stats["binary"]
    assert abs(stats["bad_rate"] - burst.stationary_bad()) < 0.08
    assert abs(stats["loss_rate"] - burst.stationary_drop()) < 0.08
    assert abs(stats["mean_burst_len"] - 2.0) < 0.5      # 1/p_recover

    # stateless edge_mask calls on a bursty config must fail loudly, not
    # silently fall back to i.i.d. loss
    with pytest.raises(ValueError, match="channel state"):
        round_conditions(net, n, 0)


def test_burst_none_is_iid_path_bitforbit():
    """Without ``burst`` the v2 code path must reproduce the historical
    i.i.d. drop coins exactly (same stream, same comparison)."""
    net = NetworkConfig.preset("edge-churn", seed=3)
    for rnd in (0, 5):
        legacy = round_conditions(net, 10, rnd)
        conds, chan = netsim.advance_conditions(net, 10, rnd, None)
        assert chan is None
        for a, b in zip(legacy, conds):
            if a is not None:
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- link matrices (v2) ----
def test_link_matrices_symmetric_and_class_consistent():
    net = NetworkConfig.preset("core-edge", seed=5)
    n = 12
    tiers = np.asarray(netsim.node_tiers(net, n))
    assert set(np.unique(tiers)) <= {0, 1}
    lat, bw = (np.asarray(m) for m in netsim.link_matrices(net, n))
    np.testing.assert_array_equal(lat, lat.T)
    np.testing.assert_array_equal(bw, bw.T)
    cl = net.classes
    lat_of = np.where(tiers > 0, cl.edge_latency_s, cl.core_latency_s)
    bw_of = np.where(tiers > 0, cl.edge_bandwidth_bps, cl.core_bandwidth_bps)
    np.testing.assert_allclose(
        lat, np.maximum(lat_of[:, None], lat_of[None, :]), rtol=1e-6)
    np.testing.assert_allclose(
        bw, np.minimum(bw_of[:, None], bw_of[None, :]), rtol=1e-6)


def test_hetero_round_time_slower_than_all_core():
    """A fleet with slow edge links must take at least as long as the same
    round on all-core links, and the scalar path must be untouched by an
    all-core class config with matching values."""
    n = 8
    adj = topology.ring(n, 2)
    active, none_slow = jnp.ones((n,)), jnp.zeros((n,))
    base = NetworkConfig.preset("core-edge", seed=1)
    all_core = dataclasses.replace(
        base, classes=dataclasses.replace(base.classes, edge_fraction=0.0))
    t_het = float(netsim.round_time(base, adj, 1e6, active, none_slow, 10))
    t_core = float(netsim.round_time(all_core, adj, 1e6, active, none_slow,
                                     10))
    assert t_het >= t_core > 0
    # every edge-fraction draw at seed=1 puts >= 1 node in the edge tier
    assert np.asarray(netsim.node_tiers(base, n)).sum() >= 1
    assert t_het > t_core


# ------------------------------------------------- async staleness (v2) --
def test_round_seconds_excludes_stale_nodes():
    """A stale straggler must not gate the simulated round; a catch-up
    straggler (stale=0) must."""
    net = NetworkConfig.preset("lan")
    n = 4
    adj = jnp.asarray(topology.ring(n, 2))
    info = {"adj_eff": adj, "payload_bytes": jnp.float32(1e6)}
    strag = jnp.zeros((n,)).at[0].set(1.0)
    conds = RoundConditions(edge_mask=jnp.ones((n, n)),
                            active=jnp.ones((n,)), straggler=strag,
                            stale=jnp.zeros((n,)))
    from repro.core import netwire
    t_gate = float(netwire.round_seconds(net, info, conds, 10))
    conds_stale = conds._replace(stale=strag)
    t_free = float(netwire.round_seconds(net, info, conds_stale, 10))
    assert t_gate > t_free > 0
    # with nobody straggling, the stale mask is a no-op
    conds_none = conds._replace(straggler=jnp.zeros((n,)))
    t0 = float(netwire.round_seconds(net, info, conds_none, 10))
    assert t_free == t0


def test_comm_info_counts_no_bytes_for_stale_senders():
    from repro.core import netwire
    n = 4
    adj = jnp.ones((n, n)) - jnp.eye(n)
    conds = RoundConditions(edge_mask=jnp.ones((n, n)),
                            active=jnp.ones((n,)),
                            straggler=jnp.zeros((n,)),
                            stale=jnp.asarray([1.0, 0.0, 0.0, 0.0]))
    info = netwire.comm_info(conds, adj, 100.0, n * 2)
    # node 0's (n-1) outgoing messages carry no fresh bytes
    assert float(info["round_bytes"]) == (n * (n - 1) - (n - 1)) * 100.0
    sync = conds._replace(stale=None)
    assert float(netwire.comm_info(sync, adj, 100.0, 0)["round_bytes"]) \
        == n * (n - 1) * 100.0


@pytest.mark.parametrize("algo", ALL_ALGOS)
def test_async_zero_staleness_is_sync_bitforbit(algo, tiny_ds, setup):
    """THE async parity contract: ``async_gossip=True, max_staleness=0``
    forces every node fresh every round, so trajectories, bytes AND
    simulated seconds reproduce the synchronous path bit for bit."""
    cfg, _, _ = setup
    kw = dict(rounds=3, k=2, degree=2, local_steps=2, batch_size=4, lr=0.05,
              eval_every=1, seed=0)
    base = NetworkConfig.preset("edge-churn")
    async0 = dataclasses.replace(base, async_gossip=True, max_staleness=0)
    ref = run_experiment(algo, cfg, tiny_ds, net=base, **kw)
    got = run_experiment(algo, cfg, tiny_ds, net=async0, **kw)
    assert ref.acc_per_cluster == got.acc_per_cluster
    assert ref.fair_acc == got.fair_acc
    assert ref.comm.bytes == got.comm.bytes
    assert ref.comm.seconds == got.comm.seconds
    for (r1, c1), (r2, c2) in zip(ref.cluster_history, got.cluster_history):
        assert r1 == r2
        np.testing.assert_array_equal(c1, c2)


def test_async_staleness_changes_bytes_and_time(tiny_ds, setup):
    """With real staleness allowed, stale stragglers send no fresh bytes
    and stop gating the round — both axes must move vs the sync run."""
    cfg, _, _ = setup
    kw = dict(rounds=4, k=2, degree=2, local_steps=2, batch_size=4, lr=0.05,
              eval_every=2, seed=0)
    net = NetworkConfig.preset("async-edge")
    sync = dataclasses.replace(net, async_gossip=False)
    r_async = run_experiment("el", cfg, tiny_ds, net=net, **kw)
    r_sync = run_experiment("el", cfg, tiny_ds, net=sync, **kw)
    assert r_async.comm.bytes[-1] < r_sync.comm.bytes[-1]
    assert r_async.comm.seconds[-1] < r_sync.comm.seconds[-1]
    assert all(np.isfinite(a) for a in r_async.final_acc)


def test_run_experiment_all_algos_under_v2_presets(tiny_ds, setup):
    cfg, _, _ = setup
    for preset in ("bursty-wan", "core-edge", "edge-v2"):
        for algo in ("facade", "el"):
            res = run_experiment(algo, cfg, tiny_ds, rounds=2, k=2, degree=2,
                                 local_steps=2, batch_size=4, lr=0.05,
                                 eval_every=1, seed=0,
                                 net=NetworkConfig.preset(preset))
            assert len(res.comm.seconds) == 2
            assert np.isfinite(res.comm.seconds[-1])
            assert res.comm.bytes[-1] >= 0
            assert all(np.isfinite(a) for a in res.final_acc)


# ---------------------------------------------------------------- timing --
def test_round_time_stragglers_and_empty_round():
    net = NetworkConfig.preset("lan")
    n = 4
    adj = topology.ring(n, 2)
    active = jnp.ones((n,))
    none_slow = jnp.zeros((n,))
    one_slow = jnp.zeros((n,)).at[0].set(1.0)
    payload = 1e6
    t0 = float(netsim.round_time(net, adj, payload, active, none_slow, 10))
    t1 = float(netsim.round_time(net, adj, payload, active, one_slow, 10))
    assert t1 > t0 > 0
    # a straggler stretches the round by its compute slowdown
    expect = 10 * net.compute_s_per_step * net.straggler_slowdown
    assert t1 >= expect
    # everyone offline -> free round
    t_empty = float(netsim.round_time(net, jnp.zeros((n, n)), payload,
                                      jnp.zeros((n,)), none_slow, 10))
    assert t_empty == 0.0


# ------------------------------------------------------------ end-to-end --
@pytest.fixture(scope="module")
def tiny_ds():
    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    return make_clustered_data(spec, cluster_sizes=(3, 1),
                               transforms=("rot0", "rot180"))


def test_run_experiment_all_algos_under_edge_churn(tiny_ds, setup):
    cfg, _, _ = setup
    for algo in ("facade", "el", "dpsgd", "deprl", "dac"):
        res = run_experiment(algo, cfg, tiny_ds, rounds=2, k=2, degree=2,
                             local_steps=2, batch_size=4, lr=0.05,
                             eval_every=1, seed=0,
                             net=NetworkConfig.preset("edge-churn"))
        assert len(res.comm.seconds) == 2
        assert res.comm.seconds[-1] >= 0 and np.isfinite(res.comm.seconds[-1])
        assert res.comm.bytes[-1] >= 0
        assert all(np.isfinite(a) for a in res.final_acc)


def test_run_experiment_ideal_matches_legacy_trajectory(tiny_ds, setup):
    cfg, _, _ = setup
    kw = dict(rounds=3, k=2, degree=2, local_steps=2, batch_size=4, lr=0.05,
              eval_every=1, seed=0)
    ref = run_experiment("facade", cfg, tiny_ds, **kw)
    sim = run_experiment("facade", cfg, tiny_ds,
                         net=NetworkConfig.preset("ideal"), **kw)
    assert ref.acc_per_cluster == sim.acc_per_cluster
    assert ref.fair_acc == sim.fair_acc
    for (r1, c1), (r2, c2) in zip(ref.cluster_history, sim.cluster_history):
        assert r1 == r2
        np.testing.assert_array_equal(c1, c2)
    # the simulated clock advances even on an ideal network (compute time)
    assert sim.comm.seconds[-1] > 0
