"""repro.sweep + EngineCache: warm-cache sweep runs are bit-identical to
fresh ``run_experiment(engine=True)`` calls (all 5 algorithms, with and
without netsim, including donated-carry reuse across runs); cache keys
never collide across configs; cross-seed aggregation; and the
``target_acc``/``eval_every`` validation regression."""
import dataclasses

import numpy as np
import pytest

from repro.configs.facade_paper import lenet
from repro.core.cache import EngineCache, EngineSpec, data_fingerprint
from repro.core.runner import run_experiment
from repro.data.synthetic import SynthSpec, make_clustered_data
from repro.netsim import NetworkConfig
from repro.sweep import SweepCell, aggregate_cell, run_sweep

CFG = lenet(smoke=True).replace(n_classes=4)
ALGOS = ("facade", "el", "dpsgd", "deprl", "dac")
SEEDS = (0, 1, 2)
KW = dict(k=2, degree=2, local_steps=2, batch_size=4, lr=0.05, eval_every=2)


@pytest.fixture(scope="module")
def tiny_ds():
    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    return make_clustered_data(spec, cluster_sizes=(3, 1),
                               transforms=("rot0", "rot180"))


def _cell(algo, ds, net=None, rounds=4, **overrides):
    kw = dict(KW)
    kw.update(overrides)
    return SweepCell(name=algo, algo=algo, cfg=CFG, dataset=ds,
                     rounds=rounds, net=net, kwargs=kw)


def _assert_runs_identical(ref, got):
    assert ref.acc_per_cluster == got.acc_per_cluster
    assert ref.fair_acc == got.fair_acc
    assert ref.dp == got.dp and ref.eo == got.eo
    assert ref.final_acc == got.final_acc
    assert ref.comm.rounds == got.comm.rounds
    assert ref.comm.bytes == got.comm.bytes          # exact float equality
    assert ref.comm.seconds == got.comm.seconds
    assert ref.comm.evaled == got.comm.evaled
    assert len(ref.cluster_history) == len(got.cluster_history)
    for (r1, c1), (r2, c2) in zip(ref.cluster_history, got.cluster_history):
        assert r1 == r2
        np.testing.assert_array_equal(c1, c2)


# ----------------------------------------------------- cache-hit parity ----
@pytest.mark.parametrize("netname", [None, "edge-churn"],
                         ids=["ideal", "edge-churn"])
@pytest.mark.parametrize("algo", ALGOS)
def test_sweep_parity_bitforbit(algo, netname, tiny_ds):
    """A 3-seed warm-cache sweep cell (seeds 1 and 2 reuse seed 0's
    compiled, donated-carry segment programs) must equal three fresh
    ``run_experiment(engine=True)`` calls bit for bit — trajectories,
    stop rounds, and full CommLog contents."""
    cache = EngineCache()
    sweep = run_sweep([_cell(algo, tiny_ds, net=netname)], SEEDS,
                      cache=cache)
    assert cache.misses == 1                     # one entry for the cell
    assert cache.hits == len(SEEDS) - 1          # warm for seeds 1, 2
    net = NetworkConfig.preset(netname) if netname else None
    for seed, got in zip(SEEDS, sweep.cells[0].results):
        ref = run_experiment(algo, CFG, tiny_ds, rounds=4, seed=seed,
                             net=net, engine=True, **KW)
        _assert_runs_identical(ref, got)


def test_sweep_warmup_boundary_parity(tiny_ds):
    """FACADE's two-variant warmup/main compile split survives caching."""
    cache = EngineCache()
    cell = _cell("facade", tiny_ds, rounds=6, eval_every=4, warmup_rounds=3)
    sweep = run_sweep([cell], SEEDS, cache=cache)
    for seed, got in zip(SEEDS, sweep.cells[0].results):
        ref = run_experiment("facade", CFG, tiny_ds, rounds=6, seed=seed,
                             warmup_rounds=3,
                             **{**KW, "eval_every": 4})
        _assert_runs_identical(ref, got)


def test_sweep_target_acc_stop_parity(tiny_ds):
    """target_acc early exit fires at the same eval round warm as fresh."""
    cache = EngineCache()
    cell = _cell("el", tiny_ds, rounds=8, target_acc=0.0)
    sweep = run_sweep([cell], SEEDS, cache=cache)
    for seed, got in zip(SEEDS, sweep.cells[0].results):
        ref = run_experiment("el", CFG, tiny_ds, rounds=8, seed=seed,
                             target_acc=0.0, **KW)
        _assert_runs_identical(ref, got)
        assert got.comm.rounds[-1] == 2          # stopped at the first eval


def test_sweep_zero_recompiles_after_first_run(tiny_ds):
    cache = EngineCache()
    cells = [_cell("el", tiny_ds), _cell("dac", tiny_ds)]
    run_sweep(cells, SEEDS[:1], cache=cache)     # first run of each cell
    compiled = cache.compile_count
    assert compiled > 0
    run_sweep(cells, SEEDS, cache=cache)
    assert cache.compile_count == compiled


def test_sweep_v2_presets_zero_recompile_and_warm_parity(tiny_ds):
    """netsim-v2 knobs keep both sweep invariants: a warm cell never
    recompiles (the carried channel/gossip state is per-run, not
    per-compile), and warm-cache runs stay bit-identical to fresh
    ``run_experiment`` calls — including the async staleness buffers and
    the donated carry they ride in."""
    cache = EngineCache()
    cells = [_cell("el", tiny_ds, net="edge-v2"),
             _cell("facade", tiny_ds, net="bursty-wan"),
             _cell("dac", tiny_ds, net="async-edge")]
    run_sweep(cells, SEEDS[:1], cache=cache)     # first run of each cell
    compiled = cache.compile_count
    assert compiled > 0
    sweep = run_sweep(cells, SEEDS, cache=cache)
    assert cache.compile_count == compiled       # warm: zero recompiles
    for cell, cres in zip(cells, sweep.cells):
        for seed, got in zip(SEEDS, cres.results):
            ref = run_experiment(cell.algo, CFG, tiny_ds, rounds=4,
                                 seed=seed,
                                 net=NetworkConfig.preset(cell.net),
                                 engine=True, **KW)
            _assert_runs_identical(ref, got)


def test_sweep_topo_zero_recompile_and_warm_parity(tiny_ds):
    """Adaptive topology keeps both sweep invariants: the TopoState EWMAs
    are per-run carry state (minted fresh each run, donated through the
    scan), so a warm cell never recompiles, and warm-cache runs stay
    bit-identical to fresh ``run_experiment(topo=...)`` calls. A cell
    with ``topo`` set and one without fork into separate entries."""
    from repro.topo import TopoConfig

    topo = TopoConfig(policy="reliability", min_inclusion=0.25)
    cache = EngineCache()
    cells = [_cell("el", tiny_ds, net="core-edge", topo=topo),
             _cell("facade", tiny_ds, net="edge-v2", topo=topo)]
    run_sweep(cells, SEEDS[:1], cache=cache)     # first run of each cell
    compiled = cache.compile_count
    assert compiled > 0
    sweep = run_sweep(cells, SEEDS, cache=cache)
    assert cache.compile_count == compiled       # warm: zero recompiles
    for cell, cres in zip(cells, sweep.cells):
        for seed, got in zip(SEEDS, cres.results):
            ref = run_experiment(cell.algo, CFG, tiny_ds, rounds=4,
                                 seed=seed, topo=topo,
                                 net=NetworkConfig.preset(cell.net),
                                 engine=True, **KW)
            _assert_runs_identical(ref, got)
    # topo on/off is a key axis: the same cell without topo is a miss
    before = cache.misses
    run_experiment("el", CFG, tiny_ds, rounds=4, cache=cache,
                   net=NetworkConfig.preset("core-edge"), **KW)
    assert cache.misses == before + 1


# ------------------------------------------------- cache-key collisions ----
def test_cache_key_no_collision_on_local_steps_or_preset(tiny_ds):
    """Two configs differing ONLY in local_steps (or only in netsim
    preset) must not share entries — a collision would silently train
    with the wrong compiled program."""
    base = EngineSpec(algo="el", cfg=CFG, n=4, k=2, degree=2,
                      local_steps=2, batch_size=4, lr=0.05)
    cache = EngineCache()
    e_base = cache.entry(base)
    e_steps = cache.entry(dataclasses.replace(base, local_steps=3))
    e_net = cache.entry(
        dataclasses.replace(base, net=NetworkConfig.preset("edge-churn")))
    assert cache.misses == 3 and cache.hits == 0
    assert e_base is not e_steps and e_base is not e_net
    assert len({id(e_base.engine), id(e_steps.engine),
                id(e_net.engine)}) == 3
    # and the run-level path sees the same distinction
    cache2 = EngineCache()
    run_experiment("el", CFG, tiny_ds, rounds=2, cache=cache2, **KW)
    run_experiment("el", CFG, tiny_ds, rounds=2, cache=cache2,
                   **{**KW, "local_steps": 3})
    run_experiment("el", CFG, tiny_ds, rounds=2, cache=cache2, **KW)
    assert cache2.misses == 2 and cache2.hits == 1


def test_cache_key_equal_configs_share_entry():
    cache = EngineCache()
    a = EngineSpec(algo="facade", cfg=CFG, n=4, k=2, degree=2,
                   local_steps=2, batch_size=4, lr=0.05,
                   net=NetworkConfig.preset("wan"))
    b = EngineSpec(algo="facade", cfg=CFG, n=4, k=2, degree=2,
                   local_steps=2, batch_size=4, lr=0.05,
                   net=NetworkConfig.preset("wan"))
    assert a == b and hash(a) == hash(b)
    assert cache.entry(a) is cache.entry(b)
    assert cache.stats()["entries"] == 1


def test_evaluator_cache_keyed_on_data_content(tiny_ds):
    """Same shapes, different eval content => different fingerprint, so a
    changed dataset can never reuse a stale evaluator."""
    spec = dataclasses.replace(tiny_ds.spec, seed=tiny_ds.spec.seed + 1)
    other = make_clustered_data(spec, cluster_sizes=(3, 1),
                                transforms=("rot0", "rot180"))
    assert data_fingerprint(tiny_ds) != data_fingerprint(other)
    assert data_fingerprint(tiny_ds) == data_fingerprint(tiny_ds)
    cache = EngineCache()
    run_experiment("el", CFG, tiny_ds, rounds=2, cache=cache, **KW)
    run_experiment("el", CFG, other, rounds=2, cache=cache, **KW)
    assert cache.evaluator_builds == 2
    run_experiment("el", CFG, tiny_ds, rounds=2, cache=cache, **KW)
    assert cache.evaluator_builds == 2           # warm again


def test_compile_count_counts_retraces_on_new_train_shapes(tiny_ds):
    """A same-spec cell fed a different train shape RETRACES the cached
    jitted segment program; the compile counter must count that, or
    zero-recompile assertions would falsely pass while XLA recompiles."""
    spec2 = dataclasses.replace(tiny_ds.spec, samples_per_class=12)
    bigger = make_clustered_data(spec2, cluster_sizes=(3, 1),
                                 transforms=("rot0", "rot180"))
    cache = EngineCache()
    run_experiment("el", CFG, tiny_ds, rounds=2, cache=cache, **KW)
    c1 = cache.compile_count
    run_experiment("el", CFG, bigger, rounds=2, cache=cache, **KW)
    assert cache.misses == 1 and cache.hits == 1       # one shared entry
    assert cache.compile_count == c1 + 2               # retrace + evaluator
    c2 = cache.compile_count
    run_experiment("el", CFG, bigger, rounds=2, cache=cache, **KW)
    assert cache.compile_count == c2                   # warm for both shapes


# ------------------------------------------------------------ aggregation --
def test_aggregate_matches_manual(tiny_ds):
    sweep = run_sweep([_cell("el", tiny_ds)], SEEDS)
    cres = sweep.cells[0]
    s = cres.summary
    assert s["n_seeds"] == len(SEEDS)
    assert s["eval_rounds"] == [2, 4]
    for row in s["trajectory"]:
        fas = [dict(r.fair_acc)[row["round"]] for r in cres.results]
        assert row["n"] == len(SEEDS)
        assert row["fair_acc_mean"] == pytest.approx(np.mean(fas))
        assert row["fair_acc_std"] == pytest.approx(np.std(fas))
    assert s["total_bytes"]["mean"] == pytest.approx(
        np.mean([r.comm.bytes[-1] for r in cres.results]))
    assert s["dp"]["mean"] == pytest.approx(
        np.mean([r.dp for r in cres.results]))
    np.testing.assert_allclose(
        s["final_acc_mean"],
        np.mean([r.final_acc for r in cres.results], axis=0))


def test_sweep_to_target_table_and_json(tiny_ds, tmp_path):
    path = tmp_path / "sweep.json"
    sweep = run_sweep([_cell("el", tiny_ds)], SEEDS, targets=(0.0, 2.0),
                      json_path=path)
    tt = sweep.cells[0].summary["to_target"]
    assert tt["0"]["reached_frac"] == 1.0        # acc >= 0 at the first eval
    assert tt["0"]["bytes"]["mean"] > 0
    assert tt["2"]["reached_frac"] == 0.0        # acc can never reach 2.0
    # the CommLog never-reached sentinel propagates as an EXPLICIT None —
    # consumers key on `is None`, not on a missing key
    assert tt["2"]["bytes"] is None and tt["2"]["seconds"] is None
    import json
    blob = json.loads(path.read_text())
    assert blob["seeds"] == list(SEEDS)
    assert blob["cells"]["el"]["summary"]["n_seeds"] == len(SEEDS)
    assert blob["cache"]["entries"] == 1


def test_sweep_rejects_degenerate_grids(tiny_ds):
    """Regression: an empty seeds sequence used to make EVERY cell 'fail'
    on an empty aggregation and surface as a misleading every-cell-failed
    RuntimeError; an empty cell grid returned a useless empty SweepResult.
    Both now raise a clear ValueError up front."""
    with pytest.raises(ValueError, match="empty cell grid"):
        run_sweep([], SEEDS)
    with pytest.raises(ValueError, match="no seeds"):
        run_sweep([_cell("el", tiny_ds)], [])
    with pytest.raises(ValueError, match="no seeds"):
        run_sweep([_cell("el", tiny_ds)], iter(()))   # exhausted iterator


def test_sweep_all_cells_skipped_returns_cleanly(tiny_ds, tmp_path):
    """A rerun whose every cell is fingerprint-skipped must return the
    reloaded summaries, not trip the every-cell-failed guard (skipped
    cells carry no error)."""
    cells = lambda: [_cell("el", tiny_ds), _cell("dac", tiny_ds)]  # noqa: E731
    first = run_sweep(cells(), SEEDS[:2], ckpt_dir=tmp_path)
    assert not any(c.skipped for c in first.cells)
    again = run_sweep(cells(), SEEDS[:2], ckpt_dir=tmp_path)
    assert all(c.skipped for c in again.cells)
    assert all(c.error is None for c in again.cells)
    for a, b in zip(first.cells, again.cells):
        assert b.results == []                       # summary-only reload
        assert b.summary["n_seeds"] == a.summary["n_seeds"]
        assert b.summary["final_acc_mean"] == pytest.approx(
            a.summary["final_acc_mean"])


def test_sweep_rejects_seed_kwarg_and_dup_names(tiny_ds):
    cell = _cell("el", tiny_ds)
    cell.kwargs["seed"] = 7
    with pytest.raises(ValueError, match="seed"):
        run_sweep([cell], SEEDS)
    with pytest.raises(ValueError, match="duplicate"):
        run_sweep([_cell("el", tiny_ds), _cell("el", tiny_ds)], SEEDS)


# ------------------------------------------------------------- regression --
def test_target_acc_with_unreachable_eval_raises(tiny_ds):
    """Regression: target_acc + eval_every > rounds used to yield a run
    that could never early-exit; now it raises up front."""
    with pytest.raises(ValueError, match="eval_every"):
        run_experiment("el", CFG, tiny_ds, rounds=4, target_acc=0.5,
                       **{**KW, "eval_every": 8})
    with pytest.raises(ValueError, match="eval_every"):
        run_experiment("el", CFG, tiny_ds, rounds=0, target_acc=0.5, **KW)
    # without target_acc the same schedule stays legal (final-round eval)
    res = run_experiment("el", CFG, tiny_ds, rounds=2,
                         **{**KW, "eval_every": 8})
    assert res.comm.rounds[-1] == 2
