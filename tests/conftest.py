"""Shared fixtures, capability gates, and the failure-set diff helper.

NOTE: no XLA_FLAGS here — tests must see 1 CPU device (the 512-device
mesh is exclusively the dry-run's business).

Capability gates
----------------
Some suites exercise APIs this box's jax build may not have: the Pallas
kernels target the post-0.4 ``pallas.tpu.CompilerParams`` surface (and
need interpret-mode lowering to run on CPU), and the dry-run/hooks mesh
tests need ``jax.set_mesh`` / ``jax.sharding.get_abstract_mesh``. Rather
than fail on such boxes, the affected tests skip with an explicit reason
via the ``requires_*`` markers below — where the capability exists they
run exactly as before (kernels in interpret mode).

Failure-set baseline tooling
----------------------------
"Tests no worse than seed" is a statement about failure SETS, not exit
codes. Two options make that mechanically checkable::

    pytest -q --write-failures=results/failures.txt   # record the set
    pytest -q --diff-baseline=results/failures.txt    # exit 0 iff no NEW
                                                      # failures vs the file

``--diff-baseline`` prints newly-failing and newly-fixed node ids and
rewrites the session exit status: green iff the current failure set is a
subset of the baseline.
"""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# make sure the arch registry is populated for every test module
import repro.configs  # noqa: F401

ALL_ARCHS = [
    "minicpm3-4b", "grok-1-314b", "deepseek-moe-16b", "hymba-1.5b",
    "stablelm-12b", "llava-next-34b", "whisper-tiny", "qwen3-8b",
    "llama3.2-1b", "rwkv6-1.6b",
]


# ---------------------------------------------------------- capabilities --
def _pallas_interpret_reason():
    """None when the repo's Pallas kernels can run here (interpret mode on
    CPU), else a skip reason. Probes both the lowering and the
    ``pallas.tpu`` API surface the kernels are written against."""
    try:
        import jax.experimental.pallas as pl
        from jax.experimental.pallas import tpu as pltpu
    except Exception as e:  # pragma: no cover - import is fine on this box
        return f"jax.experimental.pallas unavailable: {e!r}"
    if not hasattr(pltpu, "CompilerParams"):
        return ("jax.experimental.pallas.tpu.CompilerParams missing "
                f"(jax {jax.__version__} predates the rename; kernels "
                "target the renamed API)")
    try:
        def _copy(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        x = jnp.zeros((8, 128), jnp.float32)
        pl.pallas_call(
            _copy, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True)(x)
    except Exception as e:
        return f"Pallas interpret-mode lowering unavailable here: {e!r}"
    return None


PALLAS_SKIP_REASON = _pallas_interpret_reason()

requires_pallas = pytest.mark.skipif(
    PALLAS_SKIP_REASON is not None,
    reason=PALLAS_SKIP_REASON or "pallas available")

requires_set_mesh = pytest.mark.skipif(
    not hasattr(jax, "set_mesh"),
    reason=f"jax.set_mesh unavailable (jax {jax.__version__})")

requires_abstract_mesh = pytest.mark.skipif(
    not hasattr(jax.sharding, "get_abstract_mesh"),
    reason=("jax.sharding.get_abstract_mesh unavailable "
            f"(jax {jax.__version__})"))


# ------------------------------------------------- failure-set baseline ---
_FAILED: set = set()


def pytest_addoption(parser):
    g = parser.getgroup("baseline", "failure-set baseline tooling")
    g.addoption("--write-failures", metavar="PATH", default=None,
                help="write the run's failure set (one test id per line)")
    g.addoption("--diff-baseline", metavar="PATH", default=None,
                help="diff the failure set against a baseline file; the "
                     "session exits 0 iff there are no NEW failures")


def pytest_runtest_logreport(report):
    if report.failed:
        _FAILED.add(report.nodeid)


def _read_baseline(path) -> set:
    p = pathlib.Path(path)
    if not p.exists():
        return set()
    return {ln.strip() for ln in p.read_text().splitlines() if ln.strip()}


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    bp = config.getoption("--diff-baseline")
    if not bp:
        return
    baseline = _read_baseline(bp)
    new = sorted(_FAILED - baseline)
    fixed = sorted(baseline - _FAILED)
    tr = terminalreporter
    tr.section("failure-set diff vs baseline")
    tr.write_line(f"baseline: {len(baseline)} failing, "
                  f"current: {len(_FAILED)} failing")
    for nid in new:
        tr.write_line(f"NEW     {nid}")
    for nid in fixed:
        tr.write_line(f"FIXED   {nid}")
    tr.write_line("no worse than baseline" if not new
                  else f"{len(new)} NEW failure(s)")


def pytest_sessionfinish(session, exitstatus):
    wp = session.config.getoption("--write-failures")
    if wp:
        p = pathlib.Path(wp)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text("".join(f"{nid}\n" for nid in sorted(_FAILED)))
    bp = session.config.getoption("--diff-baseline")
    if bp and session.exitstatus in (0, 1):
        baseline = _read_baseline(bp)
        session.exitstatus = 1 if (_FAILED - baseline) else 0


# ------------------------------------------------------------- fixtures ---
@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def lm_smoke_batch(cfg, b=2, s=64, key=None):
    """Batch dict for any backbone's smoke config."""
    key = jax.random.PRNGKey(7) if key is None else key
    k1, k2 = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size,
                                     dtype=jnp.int32),
        "labels": jax.random.randint(k2, (b, s), 0, cfg.vocab_size,
                                     dtype=jnp.int32),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.arch_type == "vlm":
        batch["img_embeds"] = 0.02 * jax.random.normal(
            k1, (b, cfg.n_image_tokens, cfg.d_model), cfg.dt)
    if cfg.encoder_layers > 0:
        batch["frames"] = 0.02 * jax.random.normal(
            k1, (b, cfg.encoder_seq, cfg.d_model), cfg.dt)
    return batch
