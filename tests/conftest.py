"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device mesh is exclusively the dry-run's business)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# make sure the arch registry is populated for every test module
import repro.configs  # noqa: F401

ALL_ARCHS = [
    "minicpm3-4b", "grok-1-314b", "deepseek-moe-16b", "hymba-1.5b",
    "stablelm-12b", "llava-next-34b", "whisper-tiny", "qwen3-8b",
    "llama3.2-1b", "rwkv6-1.6b",
]


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def lm_smoke_batch(cfg, b=2, s=64, key=None):
    """Batch dict for any backbone's smoke config."""
    key = jax.random.PRNGKey(7) if key is None else key
    k1, k2 = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size,
                                     dtype=jnp.int32),
        "labels": jax.random.randint(k2, (b, s), 0, cfg.vocab_size,
                                     dtype=jnp.int32),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.arch_type == "vlm":
        batch["img_embeds"] = 0.02 * jax.random.normal(
            k1, (b, cfg.n_image_tokens, cfg.d_model), cfg.dt)
    if cfg.encoder_layers > 0:
        batch["frames"] = 0.02 * jax.random.normal(
            k1, (b, cfg.encoder_seq, cfg.d_model), cfg.dt)
    return batch
