"""Sharded segment engine (ROADMAP Open Item 1): the node-axis mesh.

The parity contract this file pins (and ROADMAP's "Sharding contract"
section documents):

* ``mesh=None`` is the historical single-device path — untouched by
  construction (it never activates a :mod:`repro.core.meshctx` context,
  so the traced jaxpr is unchanged).
* ``mesh=(1,)`` is BIT-EXACT against ``mesh=None`` for every algorithm,
  including under the netsim-v2 edge preset + fault injection + in-scan
  telemetry: a one-device mesh reorders nothing.
* On a REAL multi-device mesh (forced host devices, subprocess), comm
  BYTES stay exact (PRNG draws and topology are layout-independent)
  while accuracies may drift within a small tolerance: per-node conv
  accumulation order differs inside shard_map row blocks, and FACADE's
  argmin head selection can flip on last-bit ties. Tests must NOT assert
  multi-device bit-exactness of accuracies.
* The mesh SHAPE is an :class:`EngineSpec` key field — sharded and
  unsharded runs never share compiled programs.
"""
import dataclasses
import json
import os
import pathlib
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.facade_paper import lenet
from repro.core import meshctx
from repro.core.cache import EngineCache, EngineSpec
from repro.core.runner import run_experiment
from repro.data.synthetic import SynthSpec, make_clustered_data
from repro.netsim import NetworkConfig
from repro.obs import Obs, ObsConfig
from repro.resil import FaultConfig

pytestmark = pytest.mark.tier0

REPO = pathlib.Path(__file__).resolve().parent.parent
CFG = lenet(smoke=True).replace(n_classes=4)
ALGOS = ("facade", "el", "dpsgd", "deprl", "dac")
KW = dict(rounds=4, k=2, degree=2, local_steps=2, batch_size=4, lr=0.05,
          eval_every=2, seed=0)


@pytest.fixture(scope="module")
def tiny_ds():
    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    return make_clustered_data(spec, cluster_sizes=(3, 1),
                               transforms=("rot0", "rot180"))


def _assert_runs_identical(ref, got):
    assert ref.acc_per_cluster == got.acc_per_cluster
    assert ref.fair_acc == got.fair_acc
    assert ref.dp == got.dp and ref.eo == got.eo
    assert ref.final_acc == got.final_acc
    assert ref.comm.rounds == got.comm.rounds
    assert ref.comm.bytes == got.comm.bytes          # exact float equality
    assert ref.comm.seconds == got.comm.seconds
    assert ref.comm.evaled == got.comm.evaled
    assert len(ref.cluster_history) == len(got.cluster_history)
    for (r1, c1), (r2, c2) in zip(ref.cluster_history, got.cluster_history):
        assert r1 == r2
        np.testing.assert_array_equal(c1, c2)


# --------------------------------------------- mesh=(1,) exact parity -----
@pytest.mark.parametrize("algo", ALGOS)
def test_mesh1_bitforbit_under_full_stack(algo, tiny_ds):
    """A one-device mesh must be bit-exact vs ``mesh=None`` for every
    algorithm, stacked with the edge-v2 preset, nan-corrupting fault
    injection AND in-scan telemetry — the full driver feature surface.
    The sharded code path (shard_map contractions, layout constraints,
    sharded carry placement) runs; with one shard it may reorder
    nothing."""
    net = dataclasses.replace(
        NetworkConfig.preset("edge-v2"),
        faults=FaultConfig(crash_rate=0.1, restart_rate=0.5,
                           corrupt_rate=0.2, corrupt_mode="nan"))
    ref = run_experiment(algo, CFG, tiny_ds, net=net,
                         obs=Obs(config=ObsConfig()), **KW)
    got = run_experiment(algo, CFG, tiny_ds, net=net,
                         obs=Obs(config=ObsConfig()), mesh=(1,), **KW)
    _assert_runs_identical(ref, got)


def test_mesh1_plain_parity_and_cache_reuse(tiny_ds):
    """No-net sanity: mesh=(1,) through a shared EngineCache still equals
    mesh=None, and the meshed cell warms its own entry (second seeded run
    is a hit, not a rebuild)."""
    cache = EngineCache()
    ref = run_experiment("facade", CFG, tiny_ds, **KW)
    got = run_experiment("facade", CFG, tiny_ds, mesh=(1,), cache=cache,
                         **KW)
    _assert_runs_identical(ref, got)
    assert cache.misses == 1
    again = run_experiment("facade", CFG, tiny_ds, mesh=(1,), cache=cache,
                           **KW)
    _assert_runs_identical(ref, again)
    assert cache.hits >= 1 and cache.misses == 1


# ------------------------------------------ 8 forced devices (child) ------
def test_eight_device_parity_subprocess(tiny_ds):
    """All 5 algorithms on a REAL 8-device mesh (forced host devices —
    must be set before jax imports, hence the subprocess): comm bytes are
    EXACT vs mesh=None, accuracies within tolerance (shard_map row blocks
    change per-node conv accumulation order; see module docstring)."""
    child = r"""
import dataclasses, json, os, sys
import numpy as np
from repro.core.runner import run_experiment
from repro.configs.facade_paper import lenet
from repro.data.synthetic import SynthSpec, make_clustered_data
from repro.netsim import NetworkConfig
from repro.resil import FaultConfig
from repro.obs import Obs, ObsConfig
import jax
spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                 test_per_class=8, seed=3)
ds = make_clustered_data(spec, cluster_sizes=(6, 2),
                         transforms=("rot0", "rot180"))
cfg = lenet(smoke=True).replace(n_classes=4)
net = dataclasses.replace(
    NetworkConfig.preset("edge-v2"),
    faults=FaultConfig(crash_rate=0.1, restart_rate=0.5,
                       corrupt_rate=0.2, corrupt_mode="nan"))
kw = dict(rounds=4, k=2, degree=2, local_steps=2, batch_size=4, lr=0.05,
          eval_every=2, seed=0, net=net)
out = {"n_devices": len(jax.devices())}
for algo in ("facade", "el", "dpsgd", "deprl", "dac"):
    ref = run_experiment(algo, cfg, ds, obs=Obs(config=ObsConfig()), **kw)
    got = run_experiment(algo, cfg, ds, obs=Obs(config=ObsConfig()),
                         mesh=(8,), **kw)
    ra = np.array([v for _, vs in ref.acc_per_cluster for v in vs])
    ga = np.array([v for _, vs in got.acc_per_cluster for v in vs])
    out[algo] = {"bytes_exact": ref.comm.bytes == got.comm.bytes,
                 "sec_exact": ref.comm.seconds == got.comm.seconds,
                 "acc_maxdiff": float(np.abs(ra - ga).max()),
                 "acc_finite": bool(np.isfinite(ga).all())}
print(json.dumps(out))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("REPRO_XLA_CACHE_DIR", None)
    proc = subprocess.run([sys.executable, "-c", child], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["n_devices"] == 8
    for algo in ALGOS:
        rec = out[algo]
        assert rec["bytes_exact"], (algo, rec)       # layout-independent
        assert rec["sec_exact"], (algo, rec)
        assert rec["acc_finite"], (algo, rec)
        assert rec["acc_maxdiff"] <= 0.1, (algo, rec)


# ----------------------------------------------------- validation ---------
def test_mesh_must_divide_n(tiny_ds):
    with pytest.raises(ValueError, match="divide"):
        run_experiment("el", CFG, tiny_ds, mesh=(3,), **KW)   # n=4


def test_mesh_requires_engine_driver(tiny_ds):
    with pytest.raises(ValueError, match="engine"):
        run_experiment("el", CFG, tiny_ds, mesh=(1,), engine=False, **KW)


def test_normalize_canonicalizes_and_rejects():
    assert meshctx.normalize(None) is None
    assert meshctx.normalize(8) == (8,)
    assert meshctx.normalize((8,)) == (8,)
    assert meshctx.normalize([4]) == (4,)
    with pytest.raises(ValueError, match="one axis"):
        meshctx.normalize((2, 4))
    with pytest.raises(ValueError, match="at least 1"):
        meshctx.normalize((0,))


def test_build_refuses_more_devices_than_visible():
    need = len(jax.devices()) + 1
    with pytest.raises(RuntimeError, match="device_count"):
        meshctx.build((need,))


# ------------------------------------------------- cache-key forking ------
def test_mesh_is_a_cache_key_axis():
    """A sharded segment program has different layouts and collectives
    than the single-device one — sharded/unsharded specs must never share
    an entry."""
    base = EngineSpec(algo="el", cfg=CFG, n=4, k=2, degree=2,
                      local_steps=2, batch_size=4, lr=0.05)
    meshed = dataclasses.replace(base, mesh=(1,))
    assert base != meshed and hash(base) != hash(meshed)
    cache = EngineCache()
    e_base = cache.entry(base)
    e_mesh = cache.entry(meshed)
    assert cache.misses == 2 and cache.hits == 0
    assert e_base is not e_mesh
    assert e_base.engine is not e_mesh.engine
    assert cache.entry(dataclasses.replace(base, mesh=(1,))) is e_mesh
    assert cache.hits == 1


# ------------------------------------------------- layout-rule units ------
def test_node_spec_rule():
    n = 6
    row = np.zeros((n, 3, 2))
    assert meshctx.node_spec(row, n) == P("node", None, None)
    assert meshctx.node_spec(np.zeros((n,)), n) == P("node")
    assert meshctx.node_spec(np.zeros((n - 1, 3)), n) == P()   # not node-led
    assert meshctx.node_spec(np.float32(0.0), n) == P()        # scalar
    assert meshctx.node_spec(np.zeros((2,)), n) == P()         # PRNG key


def test_launch_helpers_mirror_the_rule():
    from repro.launch.mesh import make_node_mesh
    from repro.launch.shardings import node_carry_specs

    n = 4
    carry = {"params": np.zeros((n, 3)), "mix": np.zeros((n, n)),
             "key": np.zeros((2,), np.uint32), "round": np.int32(0)}
    specs = node_carry_specs(carry, n)
    assert specs["params"] == P("node", None)
    assert specs["mix"] == P("node", None)
    assert specs["key"] == P() and specs["round"] == P()

    mesh = make_node_mesh(1)
    assert mesh.axis_names == (meshctx.NODE_AXIS,)
    assert mesh.size == 1
    # outside any trace context the bindings see no mesh
    assert meshctx.current() is None
    with meshctx.activate(mesh):
        assert meshctx.current() is mesh
    assert meshctx.current() is None
