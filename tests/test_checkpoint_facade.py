"""FACADE state checkpoint/resume: a run that saves at round R and resumes
must continue bit-identically with the same PRNG stream."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.configs.facade_paper import lenet
from repro.core import facade as facade_mod
from repro.core.bindings import make_binding
from repro.core.state import FacadeState, init_facade_state


def test_facade_state_checkpoint_resume_bit_identical():
    cfg = lenet(smoke=True).replace(n_classes=4)
    binding = make_binding(cfg)
    n, k, H, B = 4, 2, 2, 4
    fcfg = facade_mod.FacadeConfig(n_nodes=n, k=k, degree=2, local_steps=H,
                                   lr=0.05)
    state = init_facade_state(binding, jax.random.PRNGKey(0), n, k)

    def batch(i):
        kx = jax.random.PRNGKey(100 + i)
        return {"x": jax.random.normal(kx, (n, H, B, 16, 16, 3)),
                "y": jax.random.randint(jax.random.fold_in(kx, 1),
                                        (n, H, B), 0, 4, dtype=jnp.int32)}

    # straight-through run: 4 rounds
    s_ref = state
    for i in range(4):
        s_ref, _ = facade_mod.facade_round(fcfg, binding, s_ref, batch(i))

    # checkpointed run: 2 rounds, save, load, 2 more rounds
    s = state
    for i in range(2):
        s, _ = facade_mod.facade_round(fcfg, binding, s, batch(i))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "facade.npz")
        ckpt_io.save(path, s._asdict(), meta={"round": 2})
        loaded, meta = ckpt_io.load(path)
        assert meta["round"] == 2
        s2 = FacadeState(**{kk: jax.tree.map(jnp.asarray, vv)
                            for kk, vv in loaded.items()})
    for i in range(2, 4):
        s2, _ = facade_mod.facade_round(fcfg, binding, s2, batch(i))

    for a, b in zip(jax.tree.leaves(s_ref), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
