"""Baseline DL algorithms (EL, D-PSGD, DEPRL, DAC): one-round unit tests +
semantic checks that distinguish them."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import (DACConfig, DeprlConfig, DpsgdConfig,
                                  ELConfig, dac_round, deprl_round,
                                  dpsgd_round, el_round, init_dac_extra)
from repro.core.bindings import make_binding
from repro.core.state import init_baseline_state
from repro.configs.facade_paper import lenet

N, H, B = 4, 2, 4


@pytest.fixture(scope="module")
def setup():
    cfg = lenet(smoke=True).replace(n_classes=4)
    binding = make_binding(cfg)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (N, H, B, cfg.image_size, cfg.image_size, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (N, H, B), 0, 4,
                           dtype=jnp.int32)
    return cfg, binding, key, {"x": x, "y": y}


ROUNDS = [
    ("el", ELConfig, el_round),
    ("dpsgd", DpsgdConfig, dpsgd_round),
    ("deprl", DeprlConfig, deprl_round),
    ("dac", DACConfig, dac_round),
]


@pytest.mark.parametrize("name,cfg_cls,round_fn", ROUNDS,
                         ids=[r[0] for r in ROUNDS])
def test_one_round_updates_params(name, cfg_cls, round_fn, setup):
    cfg, binding, key, batches = setup
    acfg = cfg_cls(n_nodes=N, degree=2, local_steps=H, lr=0.05)
    extra = init_dac_extra(N) if name == "dac" else None
    state = init_baseline_state(binding, key, N, extra=extra)
    state2, info = round_fn(acfg, binding, state, batches)
    assert state2.round == 1
    assert float(info["round_bytes"]) > 0
    p1, p2 = jax.tree.leaves(state.params), jax.tree.leaves(state2.params)
    assert any(not np.allclose(np.asarray(a), np.asarray(b))
               for a, b in zip(p1, p2))
    assert all(np.all(np.isfinite(np.asarray(l))) for l in p2)


def test_deprl_heads_never_shared(setup):
    """DEPRL: model heads stay LOCAL — after one round with different data,
    nodes' head params must differ while cores get mixed."""
    cfg, binding, key, batches = setup
    acfg = DeprlConfig(n_nodes=N, degree=2, local_steps=H, lr=0.05)
    state = init_baseline_state(binding, key, N)
    state2, _ = deprl_round(acfg, binding, state, batches)
    head_tree = {k: state2.params[k] for k in binding.head_keys
                 if k in state2.params}
    leaves = [np.asarray(l, np.float32) for l in jax.tree.leaves(head_tree)]
    diffs = [not np.allclose(v[i], v[j])
             for v in leaves for i in range(N) for j in range(i)]
    assert any(diffs), "DEPRL heads should diverge across nodes"


def test_el_consensus_under_identical_data(setup):
    """With identical batches everywhere and a fully-mixed topology, EL nodes
    stay in consensus."""
    cfg, binding, key, _ = setup
    x1 = jax.random.normal(jax.random.PRNGKey(7), (1, H, B, 16, 16, 3))
    y1 = jax.random.randint(jax.random.PRNGKey(8), (1, H, B), 0, 4,
                            dtype=jnp.int32)
    batches = {"x": jnp.broadcast_to(x1, (N,) + x1.shape[1:]),
               "y": jnp.broadcast_to(y1, (N,) + y1.shape[1:])}
    acfg = ELConfig(n_nodes=N, degree=N - 1, local_steps=H, lr=0.05)
    state = init_baseline_state(binding, key, N)
    state2, _ = el_round(acfg, binding, state, batches)
    for leaf in jax.tree.leaves(state2.params):
        leaf = np.asarray(leaf, np.float32)
        for i in range(1, N):
            np.testing.assert_allclose(leaf[i], leaf[0], rtol=1e-4,
                                       atol=1e-5)


def test_dac_weights_adapt(setup):
    """DAC's similarity weights must react to loss differences."""
    cfg, binding, key, batches = setup
    acfg = DACConfig(n_nodes=N, degree=2, local_steps=H, lr=0.05)
    state = init_baseline_state(binding, key, N, extra=init_dac_extra(N))
    state2, _ = dac_round(acfg, binding, state, batches)
    w1 = np.asarray(state.extra["sim"])
    w2 = np.asarray(state2.extra["sim"])
    assert w1.shape == (N, N) and w2.shape == (N, N)
    assert not np.allclose(w1, w2), "DAC weights should update"
