"""Sharding hooks: no-op without a mesh, divisibility guards, fallbacks.

The mesh-aware cases are version-gated on the jax APIs they exercise
(``jax.sharding.get_abstract_mesh`` / ``jax.set_mesh``)."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import requires_abstract_mesh, requires_set_mesh

from repro.models import hooks


def teardown_function(_fn):
    hooks.clear()


@requires_abstract_mesh
def test_noop_without_mesh():
    hooks.set_activation_sharding(("data",), "model")
    x = jnp.ones((4, 8))
    y = hooks.shard_batch(x)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # outside any mesh context the constraint must not be inserted
    assert "sharding_constraint" not in str(
        jax.make_jaxpr(hooks.shard_batch)(x))


def test_noop_when_cleared():
    hooks.clear()
    x = jnp.ones((4, 8))
    assert "sharding_constraint" not in str(
        jax.make_jaxpr(hooks.shard_heads)(x))
    assert hooks.data_axis_size() == 1


@requires_set_mesh
def test_constraints_inside_mesh(tmp_path):
    """In a subprocess with 8 forced devices, hooks insert constraints with
    correct divisibility behavior."""
    import os
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.models import hooks

        mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(4, 2),
                    ("data", "model"))
        hooks.set_activation_sharding(("data",), "model", seq_model=True)
        with jax.set_mesh(mesh):
            def f(x):
                return hooks.shard_batch(x)
            # divisible batch (8 % 4 == 0) and seq (6 % 2 == 0)
            jx = jax.make_jaxpr(f)(jnp.ones((8, 6, 3)))
            assert "sharding_constraint" in str(jx), jx
            # indivisible batch -> no-op
            jx2 = jax.make_jaxpr(f)(jnp.ones((3, 6, 3)))
            assert "sharding_constraint" not in str(jx2), jx2
            # head fallback: 5 heads don't divide 2 -> seq dim constrained
            def g(x):
                return hooks.shard_heads(x, head_dim=2, seq_dim=1)
            jx3 = str(jax.make_jaxpr(g)(jnp.ones((8, 6, 5, 4))))
            assert "sharding_constraint" in jx3, jx3
            assert hooks.data_axis_size() == 4
        print("HOOKS_OK")
    """)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", script],
                         env=dict(os.environ, PYTHONPATH=src),
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "HOOKS_OK" in out.stdout
