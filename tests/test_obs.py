"""repro.obs: in-scan telemetry, span tracing and run manifests.

Pins the subsystem's contracts: ``obs=None`` (the default) is bit-for-bit
the untelemetered path AND attaching a full ``Obs`` never perturbs a
trajectory, for FACADE + all four baselines on BOTH drivers; the engine
and the legacy loop produce identical ``MetricsFrame`` streams (one
shared ``compute_frame``, same point in the round); every ``ObsConfig``
field forks the ``EngineSpec`` cache key (with a fields-coverage
completeness check, the ``TopoConfig`` pattern) while host-side
sink/tracer settings never do; frame semantics (staleness histogram mass,
inclusion bounds, baseline switch counts, byte split); JSONL events
round-trip through the sink; tracer span nesting and rollup; manifest
save/load; and ``run_sweep`` writing its manifest + per-cell cache stats.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro import netsim
from repro.core.cache import EngineCache, EngineSpec
from repro.core.runner import run_experiment
from repro.configs.facade_paper import lenet
from repro.data.synthetic import SynthSpec, make_clustered_data
from repro.obs import (FRAME_FIELDS, JsonlSink, MetricsFrame, Obs,
                       ObsConfig, RunManifest, Tracer, bench_stamp,
                       fingerprint, read_jsonl)
from repro.sweep import SweepCell, run_sweep

pytestmark = pytest.mark.tier0

CFG = lenet(smoke=True).replace(n_classes=4)
ALL_ALGOS = ("facade", "el", "dpsgd", "deprl", "dac")
KW = dict(rounds=3, k=2, degree=2, local_steps=2, batch_size=4, lr=0.05,
          eval_every=1, seed=0)


@pytest.fixture(scope="module")
def tiny_ds():
    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    return make_clustered_data(spec, cluster_sizes=(3, 1),
                               transforms=("rot0", "rot180"))


def _assert_runs_identical(ref, got):
    assert ref.acc_per_cluster == got.acc_per_cluster
    assert ref.fair_acc == got.fair_acc
    assert ref.dp == got.dp and ref.eo == got.eo
    assert ref.final_acc == got.final_acc
    assert ref.comm.rounds == got.comm.rounds
    assert ref.comm.bytes == got.comm.bytes          # exact float equality
    assert ref.comm.seconds == got.comm.seconds
    np.testing.assert_array_equal(np.asarray(ref.node_acc),
                                  np.asarray(got.node_acc))
    # the per-eval fairness trajectory (plain-scalar NamedTuples) must be
    # value-identical too — eval telemetry is pure observation
    assert ref.eval_frames == got.eval_frames
    for (r1, c1), (r2, c2) in zip(ref.cluster_history, got.cluster_history):
        assert r1 == r2
        np.testing.assert_array_equal(c1, c2)


# ------------------------------------------------- telemetry is pure ------
@pytest.mark.parametrize("engine", [True, False],
                         ids=["engine", "legacy"])
@pytest.mark.parametrize("algo", ALL_ALGOS)
def test_obs_never_perturbs_trajectory(algo, engine, tiny_ds, tmp_path):
    """The central off-switch contract, both directions at once:
    ``obs=None`` is the historical path, and a fully enabled ``Obs``
    (frames + tracer + JSONL sink) observes the SAME trajectory."""
    ref = run_experiment(algo, CFG, tiny_ds, engine=engine, **KW)
    obs = Obs(ObsConfig(), jsonl=tmp_path / f"{algo}.jsonl",
              out_dir=tmp_path)
    got = run_experiment(algo, CFG, tiny_ds, engine=engine, obs=obs, **KW)
    _assert_runs_identical(ref, got)
    # and telemetry actually observed every round
    assert obs.frames_table()["round"].tolist() == [1, 2, 3]
    assert len(obs.manifests) == 1
    # eval-side telemetry observed every eval, and the series' FINAL
    # entry is bit-for-bit the run's final DP/EO scalars (they are read
    # off the frame, never recomputed) — for all 5 algorithms on both
    # drivers via this parametrization
    et = obs.eval_table()
    assert et["round"].tolist() == [1, 2, 3]
    last = got.eval_frames[-1]
    assert last.dp == got.dp and last.eo == got.eo
    assert et["dp"][-1] == got.dp and et["eo"][-1] == got.eo
    assert last.fair_acc == got.fair_acc[-1][1]
    # churn only exists where a cluster assignment does
    if algo != "facade":
        assert et["cluster_churn"].tolist() == [0.0, 0.0, 0.0]


def test_obs_parity_under_netsim(tiny_ds):
    """Same contract on the hardest preset (bursty + tiers + async stale
    gossip), where the frame reads conds/gossip state."""
    net = netsim.NetworkConfig.preset("edge-v2")
    for engine in (True, False):
        ref = run_experiment("facade", CFG, tiny_ds, engine=engine,
                             net=net, **KW)
        got = run_experiment("facade", CFG, tiny_ds, engine=engine,
                             net=net, obs=Obs(ObsConfig()), **KW)
        _assert_runs_identical(ref, got)


# ------------------------------------------- engine/legacy frame parity --
@pytest.mark.parametrize("preset", [None, "async-edge", "edge-v2"])
@pytest.mark.parametrize("algo", ["facade", "el"])
def test_engine_and_legacy_frames_identical(algo, preset, tiny_ds):
    """Both drivers run the one shared ``compute_frame`` at the same
    point in the round — frames must agree like trajectories do."""
    net = netsim.NetworkConfig.preset(preset) if preset else None
    obs_e, obs_l = Obs(ObsConfig()), Obs(ObsConfig())
    run_experiment(algo, CFG, tiny_ds, engine=True, net=net, obs=obs_e,
                   **KW)
    run_experiment(algo, CFG, tiny_ds, engine=False, net=net, obs=obs_l,
                   **KW)
    te, tl = obs_e.frames_table(), obs_l.frames_table()
    for field in te:
        np.testing.assert_allclose(te[field], tl[field], rtol=1e-6,
                                   atol=1e-6, err_msg=field)


# ------------------------------------------------------ frame semantics --
def test_frame_semantics(tiny_ds):
    n = tiny_ds.n_nodes
    net = netsim.NetworkConfig.preset("edge-v2")
    obs = Obs(ObsConfig())
    run_experiment("facade", CFG, tiny_ds, net=net, obs=obs, **KW)
    t = obs.frames_table()
    assert set(t) == {"round"} | set(FRAME_FIELDS)
    # staleness histogram: one bin per node, every round
    np.testing.assert_allclose(t["stale_hist"].sum(axis=1), float(n))
    assert np.all(t["inclusion"] >= 0.0) and np.all(t["inclusion"] <= 1.0)
    assert np.all(t["delivered_edges"] <= n * (n - 1))
    assert np.all(t["update_norm"] >= 0) and np.all(t["param_norm"] > 0)
    assert np.all(t["bytes_core"] >= 0) and np.all(t["bytes_edge"] >= 0)


def test_baselines_report_zero_switches(tiny_ds):
    """Off-FACADE there is no cluster assignment — the field must be an
    all-zeros constant, never absent (fixed pytree contract)."""
    obs = Obs(ObsConfig())
    run_experiment("el", CFG, tiny_ds, obs=obs, **KW)
    t = obs.frames_table()
    np.testing.assert_array_equal(t["cluster_switches"], 0.0)
    # all-fresh run: staleness mass sits entirely in age bin 0
    np.testing.assert_allclose(t["stale_hist"][:, 0], tiny_ds.n_nodes)
    np.testing.assert_allclose(t["stale_hist"][:, 1:], 0.0)


def test_gated_off_fields_are_zero_not_absent(tiny_ds):
    cfg = ObsConfig(norms=False, comm=False, switches=False,
                    staleness_bins=2)
    obs = Obs(cfg)
    run_experiment("facade", CFG, tiny_ds, obs=obs, **KW)
    t = obs.frames_table()
    assert set(t) == {"round"} | set(FRAME_FIELDS)   # schema fixed
    for f in ("update_norm", "param_norm", "cluster_switches",
              "delivered_edges", "inclusion", "bytes_core", "bytes_edge"):
        np.testing.assert_array_equal(t[f], 0.0, err_msg=f)
    assert t["stale_hist"].shape[1] == 2


def test_obsconfig_validation():
    with pytest.raises(ValueError, match="staleness_bins"):
        ObsConfig(staleness_bins=0)


# ------------------------------------------------------- cache-key fork --
# Every ObsConfig field changes the compiled segment program's outputs
# (the MetricsFrame leaf), so every field must fork the EngineSpec key.
# Fields-coverage completeness check + perturbation, the _TOPO_PERTURB
# pattern; tests/test_property.py imports this table so the hypothesis
# twin can never drift.
_OBS_PERTURB = {
    "norms": lambda v: not v,
    "comm": lambda v: not v,
    "switches": lambda v: not v,
    "staleness_bins": lambda v: v + 1,
    "faults": lambda v: not v,
}


def test_obs_perturb_covers_every_obsconfig_field():
    fields = {f.name for f in dataclasses.fields(ObsConfig)}
    assert fields == set(_OBS_PERTURB)


def _spec(obs):
    return EngineSpec(algo="facade", cfg=CFG, n=4, k=2, degree=2,
                      local_steps=2, batch_size=4, lr=0.05, obs=obs)


def test_every_obsconfig_field_forks_the_cache_key():
    base = _spec(ObsConfig())
    assert base != _spec(None)                       # enabling forks
    assert base == _spec(ObsConfig())                # equal configs share
    for name, fn in _OBS_PERTURB.items():
        mutated = _spec(dataclasses.replace(
            ObsConfig(), **{name: fn(getattr(ObsConfig(), name))}))
        assert mutated != base, name
        table = {base: "b", mutated: "m"}
        assert table[base] == "b" and table[mutated] == "m"


def test_host_side_obs_settings_never_fork_the_key(tiny_ds, tmp_path):
    """Attaching different sinks / out dirs / no Obs config at all must
    reuse one cache entry: only the device-side ObsConfig is keyed."""
    cache = EngineCache()
    run_experiment("el", CFG, tiny_ds, cache=cache,
                   obs=Obs(ObsConfig(), jsonl=tmp_path / "a.jsonl"), **KW)
    run_experiment("el", CFG, tiny_ds, cache=cache,
                   obs=Obs(ObsConfig(), out_dir=tmp_path), **KW)
    run_experiment("el", CFG, tiny_ds, cache=cache, obs=Obs(ObsConfig()),
                   **KW)
    st = cache.stats()
    assert st["entries"] == 1 and st["hits"] == 2
    # and an Obs with config=None (spans only) shares the obs=None entry
    run_experiment("el", CFG, tiny_ds, cache=cache, **KW)
    run_experiment("el", CFG, tiny_ds, cache=cache, obs=Obs(config=None),
                   **KW)
    assert cache.stats()["entries"] == 2


# ------------------------------------------------------------ sink/trace --
def test_jsonl_round_trip(tmp_path):
    path = tmp_path / "events.jsonl"
    records = [{"type": "event", "name": "a", "x": 1},
               {"type": "span", "name": "b", "dur_s": 0.25,
                "attrs": {"nested": [1, 2, 3]}}]
    with JsonlSink(path) as sink:
        for r in records:
            sink.emit(r)
    assert sink.n_emitted == len(records)
    assert read_jsonl(path) == records
    assert read_jsonl(tmp_path / "never_written.jsonl") == []


def test_tracer_nesting_and_rollup(tmp_path):
    sink = JsonlSink(tmp_path / "t.jsonl")
    tr = Tracer(sink=sink)
    with tr.span("outer"):
        with tr.span("inner"):
            tr.event("tick", k=1)
        with tr.span("inner"):
            pass
    sink.close()
    by_name = {}
    for s in tr.spans:
        by_name.setdefault(s["name"], []).append(s)
    assert [s["parent"] for s in by_name["inner"]] == ["outer", "outer"]
    assert all(s["depth"] == 1 for s in by_name["inner"])
    assert by_name["outer"][0]["parent"] is None
    # inner spans closed before outer: durations nest
    assert by_name["outer"][0]["dur_s"] >= max(
        s["dur_s"] for s in by_name["inner"])
    roll = tr.rollup()
    assert roll["spans"]["inner"]["count"] == 2
    assert roll["events"] == {"tick": 1}
    # the sink saw every record (2 inner + 1 outer spans + 1 event)
    assert len(read_jsonl(sink.path)) == 4


def test_run_emits_expected_spans_and_events(tiny_ds, tmp_path):
    obs = Obs(ObsConfig(), jsonl=tmp_path / "run.jsonl")
    run_experiment("facade", CFG, tiny_ds, obs=obs, **KW)
    roll = obs.tracer.rollup()
    for name in ("cache.entry", "compile", "drain", "eval", "run"):
        assert name in roll["spans"], name
    assert roll["events"]["run.begin"] == roll["events"]["run.end"] == 1
    assert roll["events"]["cache.miss"] == 1        # private fresh cache
    recs = read_jsonl(tmp_path / "run.jsonl")
    assert {"span", "event", "metrics"} <= {r["type"] for r in recs}


def test_manifest_round_trip(tmp_path):
    m = RunManifest.build(kind="run", name="el-seed0",
                          spec=_spec(ObsConfig()),
                          settings={"rounds": 3},
                          timing={"spans": {}},
                          cache={"entries": 1})
    path = m.save(tmp_path / "manifest.json")
    back = RunManifest.load(path)
    assert back == m
    assert m.fingerprint == fingerprint(repr(_spec(ObsConfig())))
    # fingerprints are content hashes: same spec -> same print
    m2 = RunManifest.build(kind="run", name="other",
                           spec=_spec(ObsConfig()), settings={})
    assert m2.fingerprint == m.fingerprint
    assert RunManifest.build(
        kind="run", name="x", spec=_spec(None),
        settings={}).fingerprint != m.fingerprint


def test_bench_stamp_fingerprints_payload():
    stamp = bench_stamp("demo", {"a": 1})
    assert stamp["name"] == "demo"
    assert stamp["fingerprint"] == fingerprint({"a": 1})
    assert stamp["fingerprint"] != bench_stamp("demo", {"a": 2})["fingerprint"]


# ------------------------------------------------------------- run_sweep --
def test_run_sweep_manifest_and_cache_stats(tiny_ds, tmp_path):
    cells = [SweepCell(name=a, algo=a, cfg=CFG, dataset=tiny_ds, rounds=2,
                       kwargs=dict(k=2, degree=2, local_steps=2,
                                   batch_size=4, lr=0.05, eval_every=2))
             for a in ("facade", "el")]
    json_path = tmp_path / "sweep.json"
    obs = Obs(ObsConfig(), jsonl=tmp_path / "sweep.jsonl")
    sweep = run_sweep(cells, (0, 1), json_path=json_path, obs=obs)

    out = json.loads(json_path.read_text())
    assert out["cache"] == sweep.cache.stats()       # top-level stats
    for name in ("facade", "el"):
        cell = out["cells"][name]
        assert cell["cache"]["entries"] >= 1         # per-cell snapshot
    # snapshots are cumulative: the last cell's equals the final stats
    assert sweep.cells[-1].cache_stats == sweep.cache.stats()

    manifest = RunManifest.load(
        json_path.with_suffix(json_path.suffix + ".manifest.json"))
    assert manifest.kind == "sweep"
    assert manifest.cache == sweep.cache.stats()
    assert manifest.settings["cells"] == ["facade", "el"]
    assert "sweep.cell" in manifest.timing["spans"]
    # per-run manifests accumulated on the shared Obs: 2 cells x 2 seeds
    assert len(obs.manifests) == 4


def test_frames_table_concats_across_runs(tiny_ds):
    obs = Obs(ObsConfig())
    run_experiment("el", CFG, tiny_ds, obs=obs, **KW)
    run_experiment("el", CFG, tiny_ds, obs=obs, **{**KW, "seed": 1})
    t = obs.frames_table()
    assert t["round"].tolist() == [1, 2, 3, 1, 2, 3]
    for f in FRAME_FIELDS:
        assert t[f].shape[0] == 6


def test_empty_obs_frames_table():
    t = Obs(config=None).frames_table()
    assert t["round"].shape == (0,)
    assert all(t[f].shape[0] == 0 for f in FRAME_FIELDS)
    assert isinstance(MetricsFrame._fields, tuple)
