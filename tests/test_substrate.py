"""Substrate tests: optimizers, schedules, data pipeline, checkpointing,
comm accounting, sharding rules."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import io as ckpt_io
from repro.comm.accounting import CommLog
from repro.data import pipeline
from repro.data.synthetic import SynthSpec, apply_transform, \
    make_clustered_data
from repro.data.tokens import TokenSpec, lm_batch, make_clustered_tokens


# --------------------------------------------------------------------------
@pytest.mark.parametrize("make", [lambda: optim.sgd(0.1),
                                  lambda: optim.momentum(0.1),
                                  lambda: optim.adamw(0.1)],
                         ids=["sgd", "momentum", "adamw"])
def test_optimizer_converges_on_quadratic(make):
    opt = make()
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(150):
        g = jax.grad(loss)(params)
        ups, state = opt.update(g, state, params)
        params = optim.apply_updates(params, ups)
    assert float(loss(params)) < 1e-2


def test_momentum_slot_dtype():
    opt = optim.momentum(0.1, slot_dtype=jnp.bfloat16)
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    state = opt.init(params)
    slots = [l for l in jax.tree.leaves(state) if hasattr(l, "dtype")]
    assert any(l.dtype == jnp.bfloat16 for l in slots)


def test_schedules():
    import jax.numpy as jnp
    s = optim.cosine_warmup(peak=1.0, warmup_steps=10, total_steps=100)
    assert float(s(jnp.asarray(0))) < float(s(jnp.asarray(9))) <= 1.0 + 1e-6
    assert float(s(jnp.asarray(99))) < float(s(jnp.asarray(50)))
    c = optim.constant(0.5)
    assert float(c(0)) == float(c(1000)) == 0.5


# --------------------------------------------------------------------------
def test_synthetic_dataset_structure():
    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=0)
    ds = make_clustered_data(spec, (3, 1), ("rot0", "rot180"))
    assert ds.train_x.shape == (4, 32, 16, 16, 3)
    assert ds.train_y.shape == (4, 32)
    assert ds.k == 2 and ds.n_nodes == 4
    assert list(ds.node_cluster) == [0, 0, 0, 1]
    # uniform labels per node (paper: uniform partitioning)
    for i in range(4):
        counts = np.bincount(ds.train_y[i], minlength=4)
        assert np.all(counts == 8)


def test_rotation_transform_is_feature_skew_only():
    """Rotation preserves pixel statistics (same multiset of values)."""
    x = np.random.default_rng(0).normal(size=(5, 8, 8, 3)).astype(np.float32)
    r = apply_transform(x, "rot180")
    assert r.shape == x.shape
    np.testing.assert_allclose(np.sort(r.ravel()), np.sort(x.ravel()))
    np.testing.assert_allclose(apply_transform(r, "rot180"), x)


@pytest.mark.parametrize("name", ["gray", "sepia", "saturate"])
def test_color_transforms(name):
    x = np.random.default_rng(0).uniform(-1, 1, (4, 8, 8, 3)).astype(
        np.float32)
    out = apply_transform(x, name)
    assert out.shape == x.shape
    assert np.all(np.isfinite(out))
    assert not np.allclose(out, x)


def test_round_batch_sampling_deterministic():
    key = jax.random.PRNGKey(0)
    x = jnp.arange(4 * 10 * 2.0).reshape(4, 10, 2)
    y = jnp.tile(jnp.arange(10), (4, 1))
    b1 = pipeline.sample_round_batches(key, x, y, 3, 4)
    b2 = pipeline.sample_round_batches(key, x, y, 3, 4)
    assert b1["x"].shape == (4, 3, 4, 2)
    np.testing.assert_array_equal(np.asarray(b1["x"]), np.asarray(b2["x"]))


def test_clustered_tokens_perm_property():
    spec = TokenSpec(vocab_size=64, seq_len=32, seed=1)
    data = make_clustered_tokens(spec, (2, 2), seqs_per_node=4)
    assert data["train"].shape == (4, 4, 32)
    assert len(data["test"]) == 2
    b = lm_batch(data["train"][0])
    np.testing.assert_array_equal(b["tokens"][..., 1:], b["labels"][..., :-1])


# --------------------------------------------------------------------------
def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "step": jnp.asarray(7)}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        ckpt_io.save(path, tree, meta={"step": 7})
        out, meta = ckpt_io.load(path)
    assert meta["step"] == 7
    assert np.asarray(out["nested"]["b"]).dtype == np.dtype("bfloat16")
    np.testing.assert_allclose(
        np.asarray(out["nested"]["b"], np.float32), 1.0)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))


# --------------------------------------------------------------------------
def test_commlog_bytes_to_target():
    log = CommLog()
    log.record(1, 100, acc=0.1)
    log.record(2, 100, acc=0.5)
    log.record(3, 100, acc=0.9)
    assert log.bytes_to_target(0.5) == 200
    assert log.bytes_to_target(0.95) is None
    assert log.total_gb == pytest.approx(300 / 1e9)
