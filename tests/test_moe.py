"""MoE layer: grouped capacity dispatch vs the dense oracle, capacity
drops, load-balance loss, shared experts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.models import moe
from repro.models.base import get_config
import repro.configs  # noqa: F401


def _cfg(e=4, k=2, shared=0):
    base = get_config("deepseek-moe-16b", smoke=True)
    return base.replace(n_experts=e, experts_per_token=k,
                        n_shared_experts=shared)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 99), b=st.integers(1, 3),
       s=st.sampled_from([16, 32]), e=st.sampled_from([2, 4]),
       k=st.sampled_from([1, 2]))
def test_grouped_dispatch_matches_dense_oracle(seed, b, s, e, k):
    """With no-drop capacity, the GShard dispatch == dense computation."""
    cfg = _cfg(e=e, k=k)
    key = jax.random.PRNGKey(seed)
    p = moe.init_moe(key, cfg)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(seed + 1),
                                (b, s, cfg.d_model), cfg.dt)
    o1, a1 = moe.moe_forward(cfg, p, x, capacity_factor=float(e * 4))
    o2, a2 = moe.moe_forward_dense(cfg, p, x)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=5e-2, atol=5e-3)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_capacity_drops_tokens_gracefully():
    """Tiny capacity must not produce NaNs; dropped tokens contribute 0."""
    cfg = _cfg(e=4, k=2)
    key = jax.random.PRNGKey(0)
    p = moe.init_moe(key, cfg)
    x = 0.3 * jax.random.normal(key, (2, 64, cfg.d_model), cfg.dt)
    out, aux = moe.moe_forward(cfg, p, x, capacity_factor=0.05)
    assert np.all(np.isfinite(np.asarray(out, np.float32)))
    # severely capped output should carry less energy than uncapped
    full, _ = moe.moe_forward(cfg, p, x, capacity_factor=16.0)
    assert (np.linalg.norm(np.asarray(out, np.float32))
            <= np.linalg.norm(np.asarray(full, np.float32)) + 1e-3)


def test_aux_loss_balanced_vs_collapsed_router():
    """Perfectly uniform routing gives aux ~= 1; collapsed routing > 1."""
    cfg = _cfg(e=4, k=1)
    key = jax.random.PRNGKey(1)
    p = moe.init_moe(key, cfg)
    x = 0.3 * jax.random.normal(key, (2, 128, cfg.d_model), cfg.dt)
    _, aux_init = moe.moe_forward(cfg, p, x)
    # collapse the router onto expert 0
    p2 = dict(p)
    p2["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    _, aux_collapsed = moe.moe_forward(cfg, p2, x)
    assert float(aux_collapsed) > float(aux_init) > 0.5


def test_shared_experts_add_dense_path():
    cfg = _cfg(e=4, k=2, shared=1)
    key = jax.random.PRNGKey(2)
    p = moe.init_moe(key, cfg)
    assert "shared" in p
    x = 0.3 * jax.random.normal(key, (1, 16, cfg.d_model), cfg.dt)
    out, _ = moe.moe_forward(cfg, p, x)
    assert out.shape == x.shape


def test_grouped_dispatch_group_invariance():
    """The result must not depend on the group count (hooks-driven)."""
    from repro.models import hooks
    cfg = _cfg(e=4, k=2)
    key = jax.random.PRNGKey(3)
    p = moe.init_moe(key, cfg)
    x = 0.3 * jax.random.normal(key, (4, 16, cfg.d_model), cfg.dt)
    o1, _ = moe.moe_forward(cfg, p, x, capacity_factor=16.0)
    # simulate a different group count by reshaping batch: with no-drop
    # capacity, grouping is semantically invisible
    o2, _ = moe.moe_forward(cfg, p, x.reshape(2, 32, cfg.d_model),
                            capacity_factor=16.0)
    np.testing.assert_allclose(np.asarray(o1, np.float32).reshape(-1),
                               np.asarray(o2, np.float32).reshape(-1),
                               rtol=5e-2, atol=5e-3)
