"""Property-based tests (hypothesis) of system invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro import netsim
from repro.core import split, topology
from repro.core.cache import EngineSpec
from repro.core.engine import segment_plan
from repro.fairness.metrics import (demographic_parity, equalized_odds,
                                    fair_accuracy)
from repro.models.base import CNNConfig
from repro.netsim import (BurstConfig, BurstFailure, LinkClasses,
                          NetworkConfig)
from repro.resil import FaultConfig
from repro.models import transformer
from repro.models.attention import chunked_sdpa, sdpa
from repro.obs import ObsConfig
from repro.topo import TopoConfig, TopoState
from repro import topo as topo_mod
from repro.roofline.analysis import (collective_bytes_from_hlo,
                                     parse_shape_list)

pytestmark = pytest.mark.tier0

_settings = settings(max_examples=25, deadline=None)


# --------------------------------------------------------------------------
@_settings
@given(n=st.integers(4, 32), r=st.integers(1, 6), seed=st.integers(0, 999))
def test_topology_invariants(n, r, seed):
    r = min(r, n - 1)
    adj = np.asarray(topology.random_regular(jax.random.PRNGKey(seed), n, r))
    assert np.array_equal(adj, adj.T)
    assert np.all(np.diag(adj) == 0)
    assert np.all(adj.sum(1) >= 1)
    w = np.asarray(topology.mixing_matrix(jnp.asarray(adj)))
    np.testing.assert_allclose(w.sum(1), 1.0, rtol=1e-5)


# --------------------------------------------------------------------------
@_settings
@given(st.lists(st.floats(0.0, 1.0), min_size=1, max_size=6),
       st.floats(0.0, 1.0))
def test_fair_accuracy_bounds(accs, lam):
    fa = fair_accuracy(accs, lam=lam)
    assert -1e-9 <= fa <= 1.0 + 1e-9
    # equal accuracies maximize the penalty term
    fa_eq = fair_accuracy([accs[0]] * len(accs), lam=lam)
    assert fa_eq >= lam * accs[0] + (1 - lam) * 1.0 - 1e-9


@_settings
@given(n_classes=st.integers(2, 6), n=st.integers(10, 80),
       seed=st.integers(0, 99))
def test_dp_eo_bounds_and_perfect_case(n_classes, n, seed):
    rng = np.random.default_rng(seed)
    preds = [rng.integers(0, n_classes, n), rng.integers(0, n_classes, n)]
    labels = [rng.integers(0, n_classes, n), rng.integers(0, n_classes, n)]
    dp = demographic_parity(preds, n_classes)
    eo = equalized_odds(preds, labels, n_classes)
    assert 0.0 <= dp <= 2.0 + 1e-9   # sum over classes of |p0-p1| <= 2
    assert 0.0 <= eo <= 2.0 * n_classes + 1e-9
    # identical prediction distributions -> DP == 0
    assert demographic_parity([preds[0], preds[0]], n_classes) < 1e-9
    assert equalized_odds([preds[0], preds[0]], [labels[0], labels[0]],
                          n_classes) < 1e-9


# --------------------------------------------------------------------------
@_settings
@given(keys=st.integers(0, 999), k=st.integers(1, 5))
def test_split_partition_invariant(keys, k):
    key = jax.random.PRNGKey(keys)
    params = {"a": jax.random.normal(key, (3, 3)),
              "b": jax.random.normal(key, (2,)),
              "final_norm": jnp.ones((4,)),
              "lm_head": jax.random.normal(key, (4, 8))}
    core, head = split.split_params(params, ("final_norm", "lm_head"))
    assert set(core) | set(head) == set(params)
    assert not (set(core) & set(head))
    st_heads = split.stack_heads(head, k)
    for i in range(k):
        sel = split.select_head(st_heads, jnp.int32(i))
        for name in head:
            np.testing.assert_array_equal(np.asarray(sel[name]),
                                          np.asarray(head[name]))


# --------------------------------------------------------------------------
@_settings
@given(b=st.integers(1, 3), s=st.sampled_from([32, 64, 128]),
       hq=st.sampled_from([2, 4]), g=st.sampled_from([1, 2]),
       seed=st.integers(0, 99))
def test_chunked_sdpa_equals_sdpa(b, s, hq, g, seed):
    hkv = hq // g
    d = 16
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = 0.5 * jax.random.normal(ks[0], (b, s, hq, d))
    k = 0.5 * jax.random.normal(ks[1], (b, s, hkv, d))
    v = 0.5 * jax.random.normal(ks[2], (b, s, hkv, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    o1 = sdpa(q, k, v, pos, pos)
    o2 = chunked_sdpa(q, k, v, pos, pos, block_q=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


@_settings
@given(b=st.integers(1, 2), s=st.sampled_from([64, 128]),
       chunk=st.sampled_from([16, 32, 64]), seed=st.integers(0, 99))
def test_chunked_ce_matches_plain(b, s, chunk, seed):
    d, v = 32, 128
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    feats = jax.random.normal(ks[0], (b, s, d))
    w = 0.1 * jax.random.normal(ks[1], (d, v))
    labels = jax.random.randint(ks[2], (b, s), 0, v, dtype=jnp.int32)
    mask = (jax.random.uniform(ks[3], (b, s)) > 0.2).astype(jnp.float32)

    loss, acc = transformer.chunked_ce(feats, w, labels, mask, chunk=chunk)
    logits = (feats @ w).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    np.testing.assert_allclose(float(loss), float(want), rtol=1e-5)


# --------------------------------------------------------------------------
@_settings
@given(rounds=st.integers(0, 64), eval_every=st.integers(1, 70),
       warmup=st.integers(0, 70))
def test_segment_plan_properties(rounds, eval_every, warmup):
    """Invariants the scan engine's correctness rests on: every round is
    covered exactly once and in order; the plan cuts at every eval round
    and at the warmup boundary; the ``warmup`` flag is static per segment
    (no segment straddles the phase switch); ``eval_at_end`` marks exactly
    the legacy driver's eval schedule."""
    plan = segment_plan(rounds, eval_every, warmup_rounds=warmup)
    covered = [r for s in plan for r in range(s.start, s.start + s.length)]
    assert covered == list(range(rounds))          # exact, ordered coverage
    assert all(s.length >= 1 for s in plan)

    evals = set(range(eval_every, rounds + 1, eval_every))
    if rounds > 0:
        evals.add(rounds)                          # the final round evals
    ends = {s.start + s.length: s.eval_at_end for s in plan}
    for r in evals:
        assert ends.get(r) is True                 # cut + eval at each eval
    for end, evaled in ends.items():
        assert evaled == (end in evals)            # never a spurious eval

    for s in plan:
        assert s.warmup == (s.start < warmup)      # flag static per segment
        assert not (s.start < warmup < s.start + s.length)


# --------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(p_bad=st.floats(0.10, 0.50), p_recover=st.floats(0.30, 0.90),
       seed=st.integers(0, 99))
def test_gilbert_elliott_stationary_and_burst_length(p_bad, p_recover, seed):
    """The two invariants the burst model's realism rests on: the empirical
    per-link loss rate converges to the chain's stationary rate, and bad
    bursts last ~1/p_recover rounds in expectation. Masks stay symmetric
    {0,1} throughout. (``netsim.channel_stats`` rolls the engine's exact
    advance_conditions scan.)"""
    burst = BurstConfig(p_bad=p_bad, p_recover=p_recover,
                        drop_good=0.0, drop_bad=1.0)
    cfg = NetworkConfig(name="ge", seed=seed, burst=burst)
    stats = netsim.channel_stats(cfg, n=6, rounds=600)

    assert stats["symmetric"] and stats["binary"]
    # empirical loss rate ~ stationary rate (drop_bad=1 => loss == bad)
    assert abs(stats["bad_rate"] - burst.stationary_bad()) < 0.10
    assert abs(stats["loss_rate"] - burst.stationary_drop()) < 0.10
    # mean completed-burst length ~ 1/p_recover
    assert stats["n_bursts"] > 20               # enough bursts to average
    want = 1.0 / p_recover
    assert abs(stats["mean_burst_len"] - want) < max(0.4, 0.35 * want)


@_settings
@given(edge_fraction=st.floats(0.0, 1.0), n=st.integers(2, 24),
       seed=st.integers(0, 99),
       lat=st.tuples(st.floats(1e-4, 1e-1), st.floats(1e-4, 1e-1)),
       bw=st.tuples(st.floats(1e6, 1e9), st.floats(1e6, 1e9)))
def test_link_matrix_construction(edge_fraction, n, seed, lat, bw):
    """Tiered link matrices: symmetric, and every entry is exactly the
    worse endpoint's class value (max latency, min bandwidth)."""
    classes = LinkClasses(edge_fraction=edge_fraction,
                          core_latency_s=lat[0], edge_latency_s=lat[1],
                          core_bandwidth_bps=bw[0], edge_bandwidth_bps=bw[1])
    cfg = NetworkConfig(name="tiers", seed=seed, classes=classes)
    tiers = np.asarray(netsim.node_tiers(cfg, n))
    assert set(np.unique(tiers)) <= {0, 1}
    lat_m, bw_m = (np.asarray(m) for m in netsim.link_matrices(cfg, n))
    np.testing.assert_array_equal(lat_m, lat_m.T)
    np.testing.assert_array_equal(bw_m, bw_m.T)
    lat_of = np.where(tiers > 0, lat[1], lat[0]).astype(np.float32)
    bw_of = np.where(tiers > 0, bw[1], bw[0]).astype(np.float32)
    np.testing.assert_allclose(
        lat_m, np.maximum(lat_of[:, None], lat_of[None, :]), rtol=1e-6)
    np.testing.assert_allclose(
        bw_m, np.minimum(bw_of[:, None], bw_of[None, :]), rtol=1e-6)
    # the assignment is static: same (seed, n) -> same tiers
    np.testing.assert_array_equal(tiers, np.asarray(netsim.node_tiers(cfg, n)))


_SPEC_FIELDS = st.fixed_dictionaries(dict(
    algo=st.sampled_from(["facade", "el", "dpsgd", "deprl", "dac"]),
    width=st.integers(2, 8),
    n=st.integers(2, 64),
    k=st.integers(1, 4),
    degree=st.integers(1, 6),
    local_steps=st.integers(1, 10),
    batch_size=st.integers(1, 16),
    lr=st.sampled_from([0.01, 0.05, 0.1]),
    warmup_rounds=st.integers(0, 20),
    head_jitter=st.sampled_from([0.0, 0.1]),
    preset=st.sampled_from([None, "lan", "wan", "edge-churn",
                            "bursty-wan", "core-edge", "async-edge",
                            "edge-v2"]),
    eval_batch=st.sampled_from([64, 256]),
    topo=st.sampled_from([None, "uniform", "reliability", "bandwidth"]),
    obs=st.sampled_from([None, 1, 4, 8]),   # staleness_bins | disabled
))

_PERTURB = {
    "algo": lambda v: "el" if v != "el" else "dac",
    "cfg": lambda v: v.replace(width=v.width + 1),
    "n": lambda v: v + 1,
    "k": lambda v: v + 1,
    "degree": lambda v: v + 1,
    "local_steps": lambda v: v + 1,
    "batch_size": lambda v: v + 1,
    "lr": lambda v: v + 0.001,
    "warmup_rounds": lambda v: v + 1,
    "head_jitter": lambda v: v + 0.5,
    "net": lambda v: (NetworkConfig.preset("hostile") if v is None
                      else None),
    "eval_batch": lambda v: v + 1,
    "topo": lambda v: (TopoConfig(policy="reliability") if v is None
                       else None),
    "obs": lambda v: (ObsConfig() if v is None else None),
    "mesh": lambda v: ((1,) if v is None else None),
}


def _spec_from(fields) -> EngineSpec:
    cfg = CNNConfig(name="lenet-prop", kind="lenet", image_size=8,
                    width=fields["width"], n_classes=4)
    net = (NetworkConfig.preset(fields["preset"])
           if fields["preset"] else None)
    topo = TopoConfig(policy=fields["topo"]) if fields["topo"] else None
    obs = (ObsConfig(staleness_bins=fields["obs"])
           if fields["obs"] else None)
    return EngineSpec(algo=fields["algo"], cfg=cfg, n=fields["n"],
                      k=fields["k"], degree=fields["degree"],
                      local_steps=fields["local_steps"],
                      batch_size=fields["batch_size"], lr=fields["lr"],
                      warmup_rounds=fields["warmup_rounds"],
                      head_jitter=fields["head_jitter"], net=net,
                      eval_batch=fields["eval_batch"], topo=topo, obs=obs)


@_settings
@given(fields=_SPEC_FIELDS, perturb=st.sampled_from(sorted(_PERTURB)))
def test_engine_cache_key_properties(fields, perturb):
    """Equal configs -> the same key (and hash); perturbing ANY single
    static field -> a different key. A collision here would silently hand
    a sweep the wrong compiled programs."""
    a, b = _spec_from(fields), _spec_from(fields)
    assert a == b and hash(a) == hash(b)

    mutated = dataclasses.replace(
        a, **{perturb: _PERTURB[perturb](getattr(a, perturb))})
    assert mutated != a
    # the perturbed spec round-trips through dict lookup as its own key
    table = {a: "a", mutated: "m"}
    assert table[a] == "a" and table[mutated] == "m"


# Every NetworkConfig field — including every netsim-v2 knob — must
# perturb the EngineSpec key: the net config IS a key component, and a
# collision would hand a sweep cell a program compiled for a different
# network. (ROADMAP cache-key contract.)
_NET_PERTURB = {
    "name": lambda v: v + "-x",
    "drop_rate": lambda v: v + 0.01,
    "churn_rate": lambda v: v + 0.01,
    "outage_rounds": lambda v: v + 1,
    "straggler_rate": lambda v: v + 0.01,
    "straggler_slowdown": lambda v: v + 0.5,
    "latency_s": lambda v: v + 1e-4,
    "bandwidth_bps": lambda v: v + 1e3,
    "compute_s_per_step": lambda v: v + 1e-3,
    "seed": lambda v: v + 1,
    "events": lambda v: v + (BurstFailure(start=0, duration=1,
                                          fraction=0.5),),
    "burst": lambda v: (BurstConfig() if v is None
                        else dataclasses.replace(v, p_bad=v.p_bad + 0.01)),
    "classes": lambda v: (LinkClasses() if v is None
                          else dataclasses.replace(
                              v, edge_fraction=(v.edge_fraction + 0.1) % 1.0)),
    "async_gossip": lambda v: not v,
    "max_staleness": lambda v: v + 1,
    "faults": lambda v: (FaultConfig(crash_rate=0.1) if v is None
                         else dataclasses.replace(
                             v, crash_rate=(v.crash_rate + 0.1) % 1.0)),
}


def test_net_perturb_covers_every_networkconfig_field():
    """The perturbation table must track the dataclass: a new
    NetworkConfig knob without a perturbation entry here is a knob whose
    cache-key behavior is untested."""
    fields = {f.name for f in dataclasses.fields(NetworkConfig)}
    assert fields == set(_NET_PERTURB)


@_settings
@given(fields=_SPEC_FIELDS, perturb=st.sampled_from(sorted(_NET_PERTURB)))
def test_engine_cache_key_net_field_perturbation(fields, perturb):
    a = _spec_from(fields)
    net = a.net if a.net is not None else NetworkConfig.preset("lan")
    base = dataclasses.replace(a, net=net)
    mutated = dataclasses.replace(
        base, net=dataclasses.replace(
            net, **{perturb: _NET_PERTURB[perturb](getattr(net, perturb))}))
    assert mutated != base
    table = {base: "b", mutated: "m"}
    assert table[base] == "b" and table[mutated] == "m"


# Every TopoConfig field must perturb the EngineSpec key the same way —
# the topology policy config is the ``topo`` key component, and a
# collision would hand a sweep cell a program compiled for a different
# sampler. ONE perturbation table serves both suites: it lives in
# tests/test_topo.py (the hypothesis-free twin that runs everywhere,
# next to the fields-coverage completeness check), and this module
# imports it so the two can never drift.
from test_topo import _TOPO_PERTURB  # noqa: E402


@_settings
@given(fields=_SPEC_FIELDS, perturb=st.sampled_from(sorted(_TOPO_PERTURB)))
def test_engine_cache_key_topo_field_perturbation(fields, perturb):
    a = _spec_from(fields)
    topo = a.topo if a.topo is not None else TopoConfig(policy="reliability")
    base = dataclasses.replace(a, topo=topo)
    mutated = dataclasses.replace(
        base, topo=dataclasses.replace(
            topo, **{perturb: _TOPO_PERTURB[perturb](getattr(topo, perturb))}))
    assert mutated != base
    table = {base: "b", mutated: "m"}
    assert table[base] == "b" and table[mutated] == "m"


# Every ObsConfig field changes the compiled segment program's outputs
# (the MetricsFrame scan leaf), so every field must fork the key. The
# table lives in tests/test_obs.py next to its fields-coverage check;
# importing it here keeps the hypothesis twin from drifting.
from test_obs import _OBS_PERTURB  # noqa: E402


@_settings
@given(fields=_SPEC_FIELDS, perturb=st.sampled_from(sorted(_OBS_PERTURB)))
def test_engine_cache_key_obs_field_perturbation(fields, perturb):
    a = _spec_from(fields)
    obs = a.obs if a.obs is not None else ObsConfig()
    base = dataclasses.replace(a, obs=obs)
    mutated = dataclasses.replace(
        base, obs=dataclasses.replace(
            obs, **{perturb: _OBS_PERTURB[perturb](getattr(obs, perturb))}))
    assert mutated != base
    table = {base: "b", mutated: "m"}
    assert table[base] == "b" and table[mutated] == "m"


# Every FaultConfig field rides the key through ``net.faults`` — a
# collision would hand a sweep cell a program compiled for a different
# fault model. The table lives in tests/test_resil.py next to its
# fields-coverage check; importing it here keeps the twins in lockstep.
from test_resil import _FAULT_PERTURB  # noqa: E402


@_settings
@given(fields=_SPEC_FIELDS, perturb=st.sampled_from(sorted(_FAULT_PERTURB)))
def test_engine_cache_key_fault_field_perturbation(fields, perturb):
    a = _spec_from(fields)
    net = a.net if a.net is not None else NetworkConfig.preset("lan")
    faults = (net.faults if net.faults is not None
              else FaultConfig(crash_rate=0.1))
    base = dataclasses.replace(a, net=dataclasses.replace(
        net, faults=faults))
    mutated = dataclasses.replace(
        base, net=dataclasses.replace(net, faults=dataclasses.replace(
            faults,
            **{perturb: _FAULT_PERTURB[perturb](getattr(faults, perturb))})))
    assert mutated != base
    table = {base: "b", mutated: "m"}
    assert table[base] == "b" and table[mutated] == "m"


# ------------------------------------------------ adaptive graphs (topo) --
@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 24), r=st.integers(1, 6),
       floor=st.floats(0.0, 1.0), weak=st.integers(0, 99),
       policy=st.sampled_from(["reliability", "bandwidth"]),
       seed=st.integers(0, 99))
def test_adaptive_graph_invariants(n, r, floor, weak, policy, seed):
    """Structural invariants of the adaptive sampler under an arbitrary
    hostile score matrix: symmetric {0,1}, zero diagonal, never more
    undirected edges than the legacy degree budget, and the exact
    participation floor ``p_i >= min_inclusion`` for every node — the
    guarantee that makes reliability-weighted sampling safe for the
    paper's under-represented clusters."""
    r = min(r, n - 1)
    weak = weak % n
    rng = np.random.default_rng(seed)
    d = rng.uniform(0.0, 1.0, (n, n)).astype(np.float32)
    t = rng.uniform(1e-3, 2.0, (n, n)).astype(np.float32)
    d, t = np.triu(d, 1), np.triu(t, 1)
    d, t = d + d.T, t + t.T
    d[weak, :] = d[:, weak] = 0.0          # hostile: starve one node
    state = TopoState(delivery=jnp.asarray(d), link_s=jnp.asarray(t))
    cfg = TopoConfig(policy=policy, min_inclusion=floor)

    p = np.asarray(topo_mod.participation_probs(cfg, state))
    assert np.all(p >= floor - 1e-6) and np.all(p <= 1.0 + 1e-6)

    adj = np.asarray(topo_mod.sample(cfg, state, jax.random.PRNGKey(seed),
                                     n, r))
    kpick = max(1, r // 2)
    assert np.array_equal(adj, adj.T)
    assert np.all(np.diag(adj) == 0)
    assert set(np.unique(adj)) <= {0.0, 1.0}
    assert adj.sum() <= 2 * n * kpick      # degree budget respected


@settings(max_examples=10, deadline=None)
@given(n=st.integers(4, 16), seed=st.integers(0, 99))
def test_uniform_policy_sampler_is_legacy(n, seed):
    """The uniform policy never reaches the adaptive sampler: the round
    functions branch on ``adaptive(cfg)``, which must be False for
    ``None`` and for uniform configs regardless of other fields."""
    assert not topo_mod.adaptive(None)
    assert not topo_mod.adaptive(TopoConfig())
    assert not topo_mod.adaptive(TopoConfig(min_inclusion=0.7, seed=seed))
    assert topo_mod.adaptive(TopoConfig(policy="reliability"))
    # and a uniform config mints no carry state
    assert topo_mod.init_state(TopoConfig(), None, n) is None


# --------------------------------------------------------------------------
@_settings
@given(dt=st.sampled_from(["f32", "bf16", "s32"]),
       dims=st.lists(st.integers(1, 64), min_size=0, max_size=4))
def test_parse_shape_bytes(dt, dims):
    nb = {"f32": 4, "bf16": 2, "s32": 4}[dt]
    text = f"{dt}[{','.join(map(str, dims))}]"
    want = nb * int(np.prod(dims)) if dims else nb
    assert parse_shape_list(text) == want


def test_collective_parse_on_synthetic_hlo():
    hlo = """
  %ag = f32[4,8]{1,0} all-gather(%x), dimensions={0}
  %ar.1 = bf16[16]{0} all-reduce(%y), to_apply=%sum
  %dot.5 = f32[2,2]{1,0} dot(%a, %b)
  %cp = f32[4]{0} collective-permute(%z)
  %tup = (f32[2,2]{1,0}, f32[4]{0}) all-reduce(%p, %q), to_apply=%sum
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 4 * 8 * 4
    assert out["all-reduce"] == 16 * 2 + (2 * 2 * 4 + 4 * 4)
    assert out["collective-permute"] == 4 * 4
    assert out["total"] == out["all-gather"] + out["all-reduce"] + \
        out["collective-permute"]
    assert out["count"] == 4
