"""Property-based tests (hypothesis) of system invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core import split, topology
from repro.core.cache import EngineSpec
from repro.core.engine import segment_plan
from repro.fairness.metrics import (demographic_parity, equalized_odds,
                                    fair_accuracy)
from repro.models.base import CNNConfig
from repro.netsim import NetworkConfig
from repro.models import transformer
from repro.models.attention import chunked_sdpa, sdpa
from repro.roofline.analysis import (collective_bytes_from_hlo,
                                     parse_shape_list)

_settings = settings(max_examples=25, deadline=None)


# --------------------------------------------------------------------------
@_settings
@given(n=st.integers(4, 32), r=st.integers(1, 6), seed=st.integers(0, 999))
def test_topology_invariants(n, r, seed):
    r = min(r, n - 1)
    adj = np.asarray(topology.random_regular(jax.random.PRNGKey(seed), n, r))
    assert np.array_equal(adj, adj.T)
    assert np.all(np.diag(adj) == 0)
    assert np.all(adj.sum(1) >= 1)
    w = np.asarray(topology.mixing_matrix(jnp.asarray(adj)))
    np.testing.assert_allclose(w.sum(1), 1.0, rtol=1e-5)


# --------------------------------------------------------------------------
@_settings
@given(st.lists(st.floats(0.0, 1.0), min_size=1, max_size=6),
       st.floats(0.0, 1.0))
def test_fair_accuracy_bounds(accs, lam):
    fa = fair_accuracy(accs, lam=lam)
    assert -1e-9 <= fa <= 1.0 + 1e-9
    # equal accuracies maximize the penalty term
    fa_eq = fair_accuracy([accs[0]] * len(accs), lam=lam)
    assert fa_eq >= lam * accs[0] + (1 - lam) * 1.0 - 1e-9


@_settings
@given(n_classes=st.integers(2, 6), n=st.integers(10, 80),
       seed=st.integers(0, 99))
def test_dp_eo_bounds_and_perfect_case(n_classes, n, seed):
    rng = np.random.default_rng(seed)
    preds = [rng.integers(0, n_classes, n), rng.integers(0, n_classes, n)]
    labels = [rng.integers(0, n_classes, n), rng.integers(0, n_classes, n)]
    dp = demographic_parity(preds, n_classes)
    eo = equalized_odds(preds, labels, n_classes)
    assert 0.0 <= dp <= 2.0 + 1e-9   # sum over classes of |p0-p1| <= 2
    assert 0.0 <= eo <= 2.0 * n_classes + 1e-9
    # identical prediction distributions -> DP == 0
    assert demographic_parity([preds[0], preds[0]], n_classes) < 1e-9
    assert equalized_odds([preds[0], preds[0]], [labels[0], labels[0]],
                          n_classes) < 1e-9


# --------------------------------------------------------------------------
@_settings
@given(keys=st.integers(0, 999), k=st.integers(1, 5))
def test_split_partition_invariant(keys, k):
    key = jax.random.PRNGKey(keys)
    params = {"a": jax.random.normal(key, (3, 3)),
              "b": jax.random.normal(key, (2,)),
              "final_norm": jnp.ones((4,)),
              "lm_head": jax.random.normal(key, (4, 8))}
    core, head = split.split_params(params, ("final_norm", "lm_head"))
    assert set(core) | set(head) == set(params)
    assert not (set(core) & set(head))
    st_heads = split.stack_heads(head, k)
    for i in range(k):
        sel = split.select_head(st_heads, jnp.int32(i))
        for name in head:
            np.testing.assert_array_equal(np.asarray(sel[name]),
                                          np.asarray(head[name]))


# --------------------------------------------------------------------------
@_settings
@given(b=st.integers(1, 3), s=st.sampled_from([32, 64, 128]),
       hq=st.sampled_from([2, 4]), g=st.sampled_from([1, 2]),
       seed=st.integers(0, 99))
def test_chunked_sdpa_equals_sdpa(b, s, hq, g, seed):
    hkv = hq // g
    d = 16
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = 0.5 * jax.random.normal(ks[0], (b, s, hq, d))
    k = 0.5 * jax.random.normal(ks[1], (b, s, hkv, d))
    v = 0.5 * jax.random.normal(ks[2], (b, s, hkv, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    o1 = sdpa(q, k, v, pos, pos)
    o2 = chunked_sdpa(q, k, v, pos, pos, block_q=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


@_settings
@given(b=st.integers(1, 2), s=st.sampled_from([64, 128]),
       chunk=st.sampled_from([16, 32, 64]), seed=st.integers(0, 99))
def test_chunked_ce_matches_plain(b, s, chunk, seed):
    d, v = 32, 128
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    feats = jax.random.normal(ks[0], (b, s, d))
    w = 0.1 * jax.random.normal(ks[1], (d, v))
    labels = jax.random.randint(ks[2], (b, s), 0, v, dtype=jnp.int32)
    mask = (jax.random.uniform(ks[3], (b, s)) > 0.2).astype(jnp.float32)

    loss, acc = transformer.chunked_ce(feats, w, labels, mask, chunk=chunk)
    logits = (feats @ w).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    np.testing.assert_allclose(float(loss), float(want), rtol=1e-5)


# --------------------------------------------------------------------------
@_settings
@given(rounds=st.integers(0, 64), eval_every=st.integers(1, 70),
       warmup=st.integers(0, 70))
def test_segment_plan_properties(rounds, eval_every, warmup):
    """Invariants the scan engine's correctness rests on: every round is
    covered exactly once and in order; the plan cuts at every eval round
    and at the warmup boundary; the ``warmup`` flag is static per segment
    (no segment straddles the phase switch); ``eval_at_end`` marks exactly
    the legacy driver's eval schedule."""
    plan = segment_plan(rounds, eval_every, warmup_rounds=warmup)
    covered = [r for s in plan for r in range(s.start, s.start + s.length)]
    assert covered == list(range(rounds))          # exact, ordered coverage
    assert all(s.length >= 1 for s in plan)

    evals = set(range(eval_every, rounds + 1, eval_every))
    if rounds > 0:
        evals.add(rounds)                          # the final round evals
    ends = {s.start + s.length: s.eval_at_end for s in plan}
    for r in evals:
        assert ends.get(r) is True                 # cut + eval at each eval
    for end, evaled in ends.items():
        assert evaled == (end in evals)            # never a spurious eval

    for s in plan:
        assert s.warmup == (s.start < warmup)      # flag static per segment
        assert not (s.start < warmup < s.start + s.length)


_SPEC_FIELDS = st.fixed_dictionaries(dict(
    algo=st.sampled_from(["facade", "el", "dpsgd", "deprl", "dac"]),
    width=st.integers(2, 8),
    n=st.integers(2, 64),
    k=st.integers(1, 4),
    degree=st.integers(1, 6),
    local_steps=st.integers(1, 10),
    batch_size=st.integers(1, 16),
    lr=st.sampled_from([0.01, 0.05, 0.1]),
    warmup_rounds=st.integers(0, 20),
    head_jitter=st.sampled_from([0.0, 0.1]),
    preset=st.sampled_from([None, "lan", "wan", "edge-churn"]),
    eval_batch=st.sampled_from([64, 256]),
))

_PERTURB = {
    "algo": lambda v: "el" if v != "el" else "dac",
    "cfg": lambda v: v.replace(width=v.width + 1),
    "n": lambda v: v + 1,
    "k": lambda v: v + 1,
    "degree": lambda v: v + 1,
    "local_steps": lambda v: v + 1,
    "batch_size": lambda v: v + 1,
    "lr": lambda v: v + 0.001,
    "warmup_rounds": lambda v: v + 1,
    "head_jitter": lambda v: v + 0.5,
    "net": lambda v: (NetworkConfig.preset("hostile") if v is None
                      else None),
    "eval_batch": lambda v: v + 1,
}


def _spec_from(fields) -> EngineSpec:
    cfg = CNNConfig(name="lenet-prop", kind="lenet", image_size=8,
                    width=fields["width"], n_classes=4)
    net = (NetworkConfig.preset(fields["preset"])
           if fields["preset"] else None)
    return EngineSpec(algo=fields["algo"], cfg=cfg, n=fields["n"],
                      k=fields["k"], degree=fields["degree"],
                      local_steps=fields["local_steps"],
                      batch_size=fields["batch_size"], lr=fields["lr"],
                      warmup_rounds=fields["warmup_rounds"],
                      head_jitter=fields["head_jitter"], net=net,
                      eval_batch=fields["eval_batch"])


@_settings
@given(fields=_SPEC_FIELDS, perturb=st.sampled_from(sorted(_PERTURB)))
def test_engine_cache_key_properties(fields, perturb):
    """Equal configs -> the same key (and hash); perturbing ANY single
    static field -> a different key. A collision here would silently hand
    a sweep the wrong compiled programs."""
    a, b = _spec_from(fields), _spec_from(fields)
    assert a == b and hash(a) == hash(b)

    mutated = dataclasses.replace(
        a, **{perturb: _PERTURB[perturb](getattr(a, perturb))})
    assert mutated != a
    # the perturbed spec round-trips through dict lookup as its own key
    table = {a: "a", mutated: "m"}
    assert table[a] == "a" and table[mutated] == "m"


# --------------------------------------------------------------------------
@_settings
@given(dt=st.sampled_from(["f32", "bf16", "s32"]),
       dims=st.lists(st.integers(1, 64), min_size=0, max_size=4))
def test_parse_shape_bytes(dt, dims):
    nb = {"f32": 4, "bf16": 2, "s32": 4}[dt]
    text = f"{dt}[{','.join(map(str, dims))}]"
    want = nb * int(np.prod(dims)) if dims else nb
    assert parse_shape_list(text) == want


def test_collective_parse_on_synthetic_hlo():
    hlo = """
  %ag = f32[4,8]{1,0} all-gather(%x), dimensions={0}
  %ar.1 = bf16[16]{0} all-reduce(%y), to_apply=%sum
  %dot.5 = f32[2,2]{1,0} dot(%a, %b)
  %cp = f32[4]{0} collective-permute(%z)
  %tup = (f32[2,2]{1,0}, f32[4]{0}) all-reduce(%p, %q), to_apply=%sum
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 4 * 8 * 4
    assert out["all-reduce"] == 16 * 2 + (2 * 2 * 4 + 4 * 4)
    assert out["collective-permute"] == 4 * 4
    assert out["total"] == out["all-gather"] + out["all-reduce"] + \
        out["collective-permute"]
    assert out["count"] == 4
