"""Property-based tests (hypothesis) of system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core import split, topology
from repro.fairness.metrics import (demographic_parity, equalized_odds,
                                    fair_accuracy)
from repro.models import transformer
from repro.models.attention import chunked_sdpa, sdpa
from repro.roofline.analysis import (collective_bytes_from_hlo,
                                     parse_shape_list)

_settings = settings(max_examples=25, deadline=None)


# --------------------------------------------------------------------------
@_settings
@given(n=st.integers(4, 32), r=st.integers(1, 6), seed=st.integers(0, 999))
def test_topology_invariants(n, r, seed):
    r = min(r, n - 1)
    adj = np.asarray(topology.random_regular(jax.random.PRNGKey(seed), n, r))
    assert np.array_equal(adj, adj.T)
    assert np.all(np.diag(adj) == 0)
    assert np.all(adj.sum(1) >= 1)
    w = np.asarray(topology.mixing_matrix(jnp.asarray(adj)))
    np.testing.assert_allclose(w.sum(1), 1.0, rtol=1e-5)


# --------------------------------------------------------------------------
@_settings
@given(st.lists(st.floats(0.0, 1.0), min_size=1, max_size=6),
       st.floats(0.0, 1.0))
def test_fair_accuracy_bounds(accs, lam):
    fa = fair_accuracy(accs, lam=lam)
    assert -1e-9 <= fa <= 1.0 + 1e-9
    # equal accuracies maximize the penalty term
    fa_eq = fair_accuracy([accs[0]] * len(accs), lam=lam)
    assert fa_eq >= lam * accs[0] + (1 - lam) * 1.0 - 1e-9


@_settings
@given(n_classes=st.integers(2, 6), n=st.integers(10, 80),
       seed=st.integers(0, 99))
def test_dp_eo_bounds_and_perfect_case(n_classes, n, seed):
    rng = np.random.default_rng(seed)
    preds = [rng.integers(0, n_classes, n), rng.integers(0, n_classes, n)]
    labels = [rng.integers(0, n_classes, n), rng.integers(0, n_classes, n)]
    dp = demographic_parity(preds, n_classes)
    eo = equalized_odds(preds, labels, n_classes)
    assert 0.0 <= dp <= 2.0 + 1e-9   # sum over classes of |p0-p1| <= 2
    assert 0.0 <= eo <= 2.0 * n_classes + 1e-9
    # identical prediction distributions -> DP == 0
    assert demographic_parity([preds[0], preds[0]], n_classes) < 1e-9
    assert equalized_odds([preds[0], preds[0]], [labels[0], labels[0]],
                          n_classes) < 1e-9


# --------------------------------------------------------------------------
@_settings
@given(keys=st.integers(0, 999), k=st.integers(1, 5))
def test_split_partition_invariant(keys, k):
    key = jax.random.PRNGKey(keys)
    params = {"a": jax.random.normal(key, (3, 3)),
              "b": jax.random.normal(key, (2,)),
              "final_norm": jnp.ones((4,)),
              "lm_head": jax.random.normal(key, (4, 8))}
    core, head = split.split_params(params, ("final_norm", "lm_head"))
    assert set(core) | set(head) == set(params)
    assert not (set(core) & set(head))
    st_heads = split.stack_heads(head, k)
    for i in range(k):
        sel = split.select_head(st_heads, jnp.int32(i))
        for name in head:
            np.testing.assert_array_equal(np.asarray(sel[name]),
                                          np.asarray(head[name]))


# --------------------------------------------------------------------------
@_settings
@given(b=st.integers(1, 3), s=st.sampled_from([32, 64, 128]),
       hq=st.sampled_from([2, 4]), g=st.sampled_from([1, 2]),
       seed=st.integers(0, 99))
def test_chunked_sdpa_equals_sdpa(b, s, hq, g, seed):
    hkv = hq // g
    d = 16
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = 0.5 * jax.random.normal(ks[0], (b, s, hq, d))
    k = 0.5 * jax.random.normal(ks[1], (b, s, hkv, d))
    v = 0.5 * jax.random.normal(ks[2], (b, s, hkv, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    o1 = sdpa(q, k, v, pos, pos)
    o2 = chunked_sdpa(q, k, v, pos, pos, block_q=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


@_settings
@given(b=st.integers(1, 2), s=st.sampled_from([64, 128]),
       chunk=st.sampled_from([16, 32, 64]), seed=st.integers(0, 99))
def test_chunked_ce_matches_plain(b, s, chunk, seed):
    d, v = 32, 128
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    feats = jax.random.normal(ks[0], (b, s, d))
    w = 0.1 * jax.random.normal(ks[1], (d, v))
    labels = jax.random.randint(ks[2], (b, s), 0, v, dtype=jnp.int32)
    mask = (jax.random.uniform(ks[3], (b, s)) > 0.2).astype(jnp.float32)

    loss, acc = transformer.chunked_ce(feats, w, labels, mask, chunk=chunk)
    logits = (feats @ w).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    np.testing.assert_allclose(float(loss), float(want), rtol=1e-5)


# --------------------------------------------------------------------------
@_settings
@given(dt=st.sampled_from(["f32", "bf16", "s32"]),
       dims=st.lists(st.integers(1, 64), min_size=0, max_size=4))
def test_parse_shape_bytes(dt, dims):
    nb = {"f32": 4, "bf16": 2, "s32": 4}[dt]
    text = f"{dt}[{','.join(map(str, dims))}]"
    want = nb * int(np.prod(dims)) if dims else nb
    assert parse_shape_list(text) == want


def test_collective_parse_on_synthetic_hlo():
    hlo = """
  %ag = f32[4,8]{1,0} all-gather(%x), dimensions={0}
  %ar.1 = bf16[16]{0} all-reduce(%y), to_apply=%sum
  %dot.5 = f32[2,2]{1,0} dot(%a, %b)
  %cp = f32[4]{0} collective-permute(%z)
  %tup = (f32[2,2]{1,0}, f32[4]{0}) all-reduce(%p, %q), to_apply=%sum
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 4 * 8 * 4
    assert out["all-reduce"] == 16 * 2 + (2 * 2 * 4 + 4 * 4)
    assert out["collective-permute"] == 4 * 4
    assert out["total"] == out["all-gather"] + out["all-reduce"] + \
        out["collective-permute"]
    assert out["count"] == 4
