"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture instantiates its REDUCED config (<=4 layers,
d_model<=512, <=4 experts) and runs one forward + one train step on CPU,
asserting output shapes and finiteness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ALL_ARCHS, lm_smoke_batch
from repro.models import api
from repro.models.base import get_config


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_config_is_reduced(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.n_layers <= 4
    assert cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_loss(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = lm_smoke_batch(cfg)
    loss, metrics = api.loss_fn(cfg, params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert np.isfinite(float(metrics["acc"]))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    """One SGD step must change params and keep everything finite."""
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = lm_smoke_batch(cfg)

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(
            lambda q: api.loss_fn(cfg, q, batch)[0])(p)
        p2 = jax.tree.map(lambda w, gg: w - 0.01 * gg.astype(w.dtype), p, g)
        return loss, p2

    loss, params2 = step(params)
    assert np.isfinite(float(loss))
    leaves1, leaves2 = jax.tree.leaves(params), jax.tree.leaves(params2)
    changed = any(not np.allclose(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))
                  for a, b in zip(leaves1, leaves2))
    assert changed
    assert all(np.all(np.isfinite(np.asarray(l, np.float32)))
               for l in leaves2)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs must carry the exact assigned hyperparameters."""
    expected = {
        "minicpm3-4b": dict(n_layers=62, d_model=2560, n_heads=40,
                            n_kv_heads=40, d_ff=6400, vocab_size=73448),
        "grok-1-314b": dict(n_layers=64, d_model=6144, n_heads=48,
                            n_kv_heads=8, d_ff=32768, vocab_size=131072,
                            n_experts=8, experts_per_token=2),
        "deepseek-moe-16b": dict(n_layers=28, d_model=2048, n_heads=16,
                                 n_kv_heads=16, d_ff=1408,
                                 vocab_size=102400, n_experts=64,
                                 experts_per_token=6, n_shared_experts=2),
        "hymba-1.5b": dict(n_layers=32, d_model=1600, n_heads=25,
                           n_kv_heads=5, d_ff=5504, vocab_size=32001,
                           ssm_state=16),
        "stablelm-12b": dict(n_layers=40, d_model=5120, n_heads=32,
                             n_kv_heads=8, d_ff=13824, vocab_size=100352),
        "llava-next-34b": dict(n_layers=60, d_model=7168, n_heads=56,
                               n_kv_heads=8, d_ff=20480, vocab_size=64000),
        "whisper-tiny": dict(n_layers=4, d_model=384, n_heads=6,
                             n_kv_heads=6, d_ff=1536, vocab_size=51865),
        "qwen3-8b": dict(n_layers=36, d_model=4096, n_heads=32,
                         n_kv_heads=8, d_ff=12288, vocab_size=151936,
                         qk_norm=True),
        "llama3.2-1b": dict(n_layers=16, d_model=2048, n_heads=32,
                            n_kv_heads=8, d_ff=8192, vocab_size=128256),
        "rwkv6-1.6b": dict(n_layers=24, d_model=2048, d_ff=7168,
                           vocab_size=65536, rwkv=True),
    }[arch]
    cfg = get_config(arch)
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
