"""End-to-end system tests: the paper's headline claims, directionally,
at CPU scale (small synthetic clustered data, reduced LeNet).

  * Fig. 1/3: EL leaves a minority-cluster accuracy gap; FACADE closes it.
  * Fig. 9: nodes settle onto consistent heads per cluster.
  * Sec. V-E: FACADE per-round bytes == EL per-round bytes (+ 4-byte id).
  * Sec. V-F: overestimating k still trains well.
"""
import jax
import numpy as np
import pytest

from repro.configs.facade_paper import lenet
from repro.core.runner import run_experiment
from repro.data.synthetic import SynthSpec, make_clustered_data

SPEC = SynthSpec(n_classes=4, image_size=16, samples_per_class=16,
                 test_per_class=32, seed=3)
CFG = lenet(smoke=True).replace(n_classes=4)


@pytest.fixture(scope="module")
def imbalanced():
    return make_clustered_data(SPEC, (6, 2), ("rot0", "rot180"))


@pytest.fixture(scope="module")
def results(imbalanced):
    kw = dict(rounds=40, degree=2, local_steps=4, batch_size=8, lr=0.05,
              eval_every=10, seed=0)
    facade = run_experiment("facade", CFG, imbalanced, k=2, **kw)
    el = run_experiment("el", CFG, imbalanced, **kw)
    return facade, el


def test_facade_beats_el_on_minority(results):
    facade, el = results
    assert facade.final_acc[1] >= el.final_acc[1] - 0.02, (
        f"FACADE minority {facade.final_acc[1]} < EL {el.final_acc[1]}")
    assert facade.final_acc[1] > 0.5


def test_facade_fair_accuracy_highest(results):
    facade, el = results
    assert facade.best_fair_acc() >= el.best_fair_acc() - 0.02


def test_facade_comm_cost_matches_el_per_round(results):
    facade, el = results
    fb = facade.comm.bytes[0]
    eb = el.comm.bytes[0]
    n, deg = 8, 2
    # FACADE sends core+head+4-byte cluster id; EL sends the full model:
    # identical volume up to the id (paper Sec. V-E)
    assert abs(fb - eb) <= n * deg * 4 + 1e-6


def test_settlement(results):
    """All nodes of a cluster converge to one head; clusters differ."""
    facade, _ = results
    _, cid = facade.cluster_history[-1]
    cid = np.asarray(cid)
    maj, mino = cid[:6], cid[6:]
    assert len(set(maj.tolist())) == 1, f"majority split heads: {maj}"
    assert len(set(mino.tolist())) == 1, f"minority split heads: {mino}"


def test_overestimated_k_still_works(imbalanced):
    res = run_experiment("facade", CFG, imbalanced, k=4, rounds=40,
                         degree=2, local_steps=4, batch_size=8, lr=0.05,
                         eval_every=20, seed=0)
    assert min(res.final_acc) > 0.5, res.final_acc


def test_dp_eo_improve_over_el(results):
    facade, el = results
    # directional: FACADE should not be less fair than EL on skewed clusters
    assert facade.eo <= el.eo + 0.1
