"""fairness/metrics.py edge cases.

The metrics are the observatory's ground truth — every per-eval
``EvalFrame`` and every final ``RunResult.dp``/``eo`` scalar routes
through these three functions — so the degenerate inputs the imbalanced
cluster grids can produce (a single non-empty cluster, an empty
per-cluster prediction array) must come back defined, not crash or NaN.
The series-final-equals-RunResult parity pin lives in ``test_obs.py``
(``test_obs_never_perturbs_trajectory``), where the runs already exist.
"""
import numpy as np
import pytest

from repro.fairness import demographic_parity, equalized_odds, fair_accuracy

pytestmark = pytest.mark.tier0


# ------------------------------------------------- < 2 non-empty clusters --
def test_dp_single_cluster_is_zero():
    """A gap needs two groups: one cluster (or none) has no pair to
    compare, so the worst-case pairwise gap is 0 by definition."""
    assert demographic_parity([np.array([0, 1, 2])], n_classes=4) == 0.0
    assert demographic_parity([], n_classes=4) == 0.0


def test_eo_single_cluster_is_zero():
    assert equalized_odds([np.array([0, 1])], [np.array([0, 1])],
                          n_classes=4) == 0.0
    assert equalized_odds([], [], n_classes=4) == 0.0


# ------------------------------------------------- empty pred arrays ------
def test_dp_empty_pred_arrays():
    """An empty prediction vector yields the all-zeros distribution
    (max(len, 1) guard), never a divide-by-zero: empty-vs-empty gaps 0,
    empty-vs-nonempty gaps the nonempty cluster's total mass (1.0)."""
    empty = np.array([], np.int64)
    assert demographic_parity([empty, empty], n_classes=4) == 0.0
    got = demographic_parity([empty, np.array([1, 1])], n_classes=4)
    assert got == pytest.approx(1.0)
    assert np.isfinite(got)


def test_eo_empty_pred_and_label_arrays():
    """No labels of class y => TPR_y = 0 (the m.any() guard), so fully
    empty clusters compare as all-zero rate vectors."""
    empty = np.array([], np.int64)
    assert equalized_odds([empty, empty], [empty, empty], n_classes=4) == 0.0
    # one empty cluster vs a perfect one: gap = sum of the perfect TPRs
    got = equalized_odds([empty, np.array([0, 1])],
                         [empty, np.array([0, 1])], n_classes=4)
    assert got == pytest.approx(2.0)


# ------------------------------------------------- known values -----------
def test_dp_known_value_two_clusters():
    # cluster 0 predicts all-0, cluster 1 predicts all-1: L1 gap = 2
    dp = demographic_parity([np.zeros(4, np.int64), np.ones(4, np.int64)],
                            n_classes=2)
    assert dp == pytest.approx(2.0)


def test_dp_is_max_over_pairs():
    # three clusters; the worst pair defines the reported gap
    a, b = np.zeros(4, np.int64), np.ones(4, np.int64)
    mixed = np.array([0, 0, 1, 1], np.int64)
    assert demographic_parity([a, mixed, b], n_classes=2) == pytest.approx(
        demographic_parity([a, b], n_classes=2))


def test_fair_accuracy_equal_clusters_no_penalty():
    # equal accuracies: penalty term is 1, Eq. 5 gives lam*a + (1-lam)
    lam = 2.0 / 3.0
    assert fair_accuracy([0.8, 0.8]) == pytest.approx(lam * 0.8 + (1 - lam))


def test_fair_accuracy_penalizes_spread():
    assert fair_accuracy([0.9, 0.5]) < fair_accuracy([0.7, 0.7])
    # single cluster: spread is 0, reduces to lam*acc + (1-lam)
    lam = 2.0 / 3.0
    assert fair_accuracy([0.6]) == pytest.approx(lam * 0.6 + (1 - lam))
