"""Prefill + decode_step must reproduce full-forward logits.

The strongest correctness test of the serving path: for each cache family
(GQA, MLA absorbed, sliding-window ring buffer, RWKV state, hybrid
attn+mamba), decoding token-by-token after a prefill must match the logits
computed by one full forward pass.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import api, transformer
from repro.models.base import get_config

CASES = [
    ("llama3.2-1b", {}),                       # GQA
    ("qwen3-8b", {}),                          # GQA + qk_norm
    ("minicpm3-4b", {}),                       # MLA absorbed decode
    ("rwkv6-1.6b", {}),                        # state cache
    ("hymba-1.5b", {}),                        # hybrid attn+ssm
    ("llama3.2-1b", {"sliding_window": 16}),   # SWA ring buffer
]


def full_logits(cfg, params, tokens):
    feats, _ = transformer.forward(cfg, params, tokens)
    from repro.models import layers
    w = transformer.lm_head_weight(cfg, params)
    return (feats @ w).astype(jnp.float32)


@pytest.mark.parametrize("arch,overrides", CASES,
                         ids=[f"{a}{'-swa' if o else ''}" for a, o in CASES])
def test_prefill_decode_matches_forward(arch, overrides):
    cfg = get_config(arch, smoke=True)
    if overrides:
        cfg = cfg.replace(**overrides)
    key = jax.random.PRNGKey(3)
    params = api.init_params(cfg, key)
    b, s_pre, s_gen = 2, 24, 8
    s = s_pre + s_gen
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size,
                                dtype=jnp.int32)

    ref = full_logits(cfg, params, tokens)  # [B, S, V]

    logits, cache = transformer.prefill(cfg, params, tokens[:, :s_pre],
                                        cache_extra=s_gen)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(ref[:, s_pre - 1]),
                               rtol=2e-2, atol=2e-2)
    for t in range(s_pre, s):
        pos = jnp.full((b,), t, jnp.int32)
        logits, cache = transformer.decode_step(
            cfg, params, cache, tokens[:, t:t + 1], pos)
        if cfg.sliding_window and (t + 1) > cfg.sliding_window:
            continue  # ring buffer: full-forward ref sees the whole history
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref[:, t]),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch} decode diverges at position {t}")


def test_swa_ring_buffer_matches_windowed_forward():
    """After wraparound, decode must equal a forward pass restricted to the
    window — i.e. the ring buffer implements SWA, not truncation artifacts."""
    cfg = get_config("llama3.2-1b", smoke=True).replace(sliding_window=16)
    key = jax.random.PRNGKey(5)
    params = api.init_params(cfg, key)
    b, s = 1, 48
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size,
                                dtype=jnp.int32)
    ref = full_logits(cfg, params, tokens)  # forward applies the same window

    logits, cache = transformer.prefill(cfg, params, tokens[:, :32],
                                        cache_extra=0)
    for t in range(32, s):
        pos = jnp.full((b,), t, jnp.int32)
        logits, cache = transformer.decode_step(
            cfg, params, cache, tokens[:, t:t + 1], pos)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref[:, t]), rtol=3e-2, atol=3e-2,
            err_msg=f"ring-buffer decode diverges at pos {t}")
