"""Dry-run machinery on a miniature mesh, in a subprocess (so the forced
device count never leaks into other tests). Version-gated: skips when
this jax build lacks ``jax.set_mesh`` (the subprocess script needs it)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from conftest import requires_set_mesh

pytestmark = requires_set_mesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    import repro.configs
    from repro.launch import shardings, steps
    from repro.models.base import get_config
    from repro.roofline import analyze_compiled
    from repro.launch.mesh import HW

    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(4, 2),
                ("data", "model"))

    # smoke config so the mini-mesh compile is fast
    import repro.models.base as base
    cfg = get_config("llama3.2-1b", smoke=True)
    base._REGISTRY["llama3.2-1b"] = lambda smoke=False: cfg

    case = steps.build_case("llama3.2-1b", "train_4k", mesh)
    # shrink the batch to the smoke scale
    def shrink(sds):
        if not hasattr(sds, "shape"):
            return sds
        shape = tuple(min(d, 8) if i == 0 else min(d, 64)
                      for i, d in enumerate(sds.shape))
        return jax.ShapeDtypeStruct(shape, sds.dtype)
    batch = {k: shrink(v) for k, v in case.args_sds[2].items()}
    bspecs = shardings.batch_specs(batch, mesh)
    args = (case.args_sds[0], case.args_sds[1], batch)
    in_sh = shardings.named(mesh, (case.in_shardings[0],
                                   case.in_shardings[1], bspecs))
    with jax.set_mesh(mesh):
        compiled = jax.jit(case.step_fn, in_shardings=in_sh).lower(
            *args).compile()
    rep = analyze_compiled(compiled, arch="llama3.2-1b", shape="train_4k",
                           mesh_name="mini", chips=8, hw=HW,
                           n_params_active=1_000_000, n_tokens=8 * 64,
                           kind="train")
    print("RESULT " + json.dumps(rep.row()))
""")


@pytest.mark.slow
def test_mini_mesh_dryrun_compiles_and_analyzes():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout
    row = json.loads(line[0][7:])
    assert row["hlo_gflops_per_dev"] > 0
    assert row["t_compute_s"] >= 0
    assert row["dominant"] in ("compute", "memory", "collective")
