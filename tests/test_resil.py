"""repro.resil: node-fault injection, robust gossip, crash-safe resume.

Pins the subsystem's contracts:

* **off-switches are bit-for-bit**: ``FaultConfig()`` (all rates zero) and
  ``FaultConfig(robust=False)`` run the EXACT legacy trajectory for FACADE
  + all four baselines on BOTH drivers — injecting the fault machinery
  costs nothing until a rate is turned on;
* **engine/legacy parity under faults**: crashes, corruption and
  factory-reset restarts follow the shared ``resil.advance`` /
  ``resil.reset_nodes`` entry points, so the scan engine and the legacy
  loop stay bit-identical with faults ON, for every algorithm;
* **byte/time honesty**: a crashed node sends nothing (0 bytes) and never
  gates the round clock;
* **the robust guard**: non-finite senders are quarantined, honest mass
  renormalized, oversized payloads norm-clipped — and the guard is
  statically off at zero corruption;
* **crash-safe checkpoint/resume**: ``run_experiment(ckpt=...)`` resumes a
  killed run bit-for-bit (final carry, CommLog, eval histories, obs
  frames) for all five algorithms; stale checkpoints from another config
  are refused; ``repro.checkpoint.save`` is atomic and its loader turns
  garbage files into a clear ``CheckpointError``;
* **preemption-safe sweeps**: a failing cell is recorded and the grid
  continues (``RuntimeError`` only when ALL cells fail); with
  ``ckpt_dir=`` completed cells are skipped on rerun via their manifest
  fingerprint;
* **cache-key coverage**: every ``FaultConfig`` field forks the
  ``EngineSpec`` key through ``net.faults`` (perturbation table
  ``_FAULT_PERTURB`` + fields-coverage check; ``tests/test_property.py``
  imports the table for its hypothesis twin).
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint, netsim, resil
from repro.configs.facade_paper import lenet
from repro.core import engine as engine_mod
from repro.core.bindings import gossip_mix
from repro.core.cache import EngineSpec
from repro.core.runner import run_experiment
from repro.data.synthetic import SynthSpec, make_clustered_data
from repro.netsim import NetworkConfig
from repro.obs import Obs, ObsConfig
from repro.resil import FaultConfig, FaultState
from repro.sweep import SweepCell, run_sweep

pytestmark = pytest.mark.tier0

CFG = lenet(smoke=True).replace(n_classes=4)
ALL_ALGOS = ("facade", "el", "dpsgd", "deprl", "dac")
KW = dict(rounds=3, k=2, degree=2, local_steps=2, batch_size=4, lr=0.05,
          eval_every=3, seed=0)
NET = NetworkConfig.preset("edge-churn")


def _faulted(fcfg, net=NET):
    return dataclasses.replace(net, faults=fcfg)


@pytest.fixture(scope="module")
def tiny_ds():
    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    return make_clustered_data(spec, cluster_sizes=(3, 1),
                               transforms=("rot0", "rot180"))


def _assert_runs_identical(ref, got):
    assert ref.acc_per_cluster == got.acc_per_cluster
    assert ref.fair_acc == got.fair_acc
    assert ref.dp == got.dp and ref.eo == got.eo
    assert ref.final_acc == got.final_acc
    assert ref.comm.rounds == got.comm.rounds
    assert ref.comm.bytes == got.comm.bytes          # exact float equality
    assert ref.comm.seconds == got.comm.seconds
    np.testing.assert_array_equal(np.asarray(ref.node_acc),
                                  np.asarray(got.node_acc))
    for (r1, c1), (r2, c2) in zip(ref.cluster_history, got.cluster_history):
        assert r1 == r2
        np.testing.assert_array_equal(c1, c2)


# ------------------------------------------------- config validation ------
def test_fault_config_validates():
    with pytest.raises(ValueError):
        FaultConfig(restart_mode="reboot")
    with pytest.raises(ValueError):
        FaultConfig(corrupt_mode="bitflip")
    with pytest.raises(ValueError):
        FaultConfig(crash_rate=1.5)
    with pytest.raises(ValueError):
        FaultConfig(corrupt_rate=-0.1)
    with pytest.raises(ValueError):
        FaultConfig(clip=0.0)


# ------------------------------------------------- cache-key contract -----
# Every FaultConfig field forks the EngineSpec key (through net.faults).
# tests/test_property.py imports this table for its hypothesis twin, so
# the two suites can never drift.
_FAULT_PERTURB = {
    "crash_rate": lambda v: (v + 0.1) % 1.0,
    "restart_rate": lambda v: (v + 0.25) % 1.0,
    "restart_mode": lambda v: ("reset" if v == "rejoin-stale"
                               else "rejoin-stale"),
    "corrupt_rate": lambda v: (v + 0.1) % 1.0,
    "corrupt_mode": lambda v: "scale" if v == "noise" else "noise",
    "corrupt_scale": lambda v: v + 1.0,
    "robust": lambda v: not v,
    "clip": lambda v: v + 0.5,
}


def test_fault_perturb_covers_every_faultconfig_field():
    fields = {f.name for f in dataclasses.fields(FaultConfig)}
    assert fields == set(_FAULT_PERTURB)


def _spec(net):
    return EngineSpec(algo="facade", cfg=CFG, n=4, k=2, degree=2,
                      local_steps=2, batch_size=4, lr=0.05, net=net)


def test_every_faultconfig_field_forks_the_cache_key():
    faults = FaultConfig()
    base = _spec(_faulted(faults))
    assert base != _spec(NET)                        # attaching forks
    assert base == _spec(_faulted(FaultConfig()))    # equal configs share
    for name, fn in _FAULT_PERTURB.items():
        mutated = _spec(_faulted(dataclasses.replace(
            faults, **{name: fn(getattr(faults, name))})))
        assert mutated != base, name
        table = {base: "b", mutated: "m"}
        assert table[base] == "b" and table[mutated] == "m"


# ------------------------------------------------- off-switches -----------
@pytest.mark.parametrize("algo", ALL_ALGOS)
def test_zero_rate_faults_bit_identical(algo, tiny_ds):
    """The central off-switch contract: a FaultConfig with all rates zero
    (robust on OR off) runs the exact legacy trajectory, both drivers."""
    for engine in (True, False):
        ref = run_experiment(algo, CFG, tiny_ds, net=NET, engine=engine,
                             **KW)
        for fcfg in (FaultConfig(), FaultConfig(robust=False)):
            got = run_experiment(algo, CFG, tiny_ds, net=_faulted(fcfg),
                                 engine=engine, **KW)
            _assert_runs_identical(ref, got)


# ------------------------------------------------- engine/legacy parity ---
@pytest.mark.parametrize("algo", ALL_ALGOS)
def test_engine_legacy_parity_under_faults(algo, tiny_ds):
    """Crashes + corruption active: scan engine == legacy loop, and the
    trajectory actually differs from the fault-free one."""
    net = _faulted(FaultConfig(crash_rate=0.3, restart_rate=0.5,
                               corrupt_rate=0.3))
    eng = run_experiment(algo, CFG, tiny_ds, net=net, engine=True, **KW)
    leg = run_experiment(algo, CFG, tiny_ds, net=net, engine=False, **KW)
    _assert_runs_identical(eng, leg)
    ref = run_experiment(algo, CFG, tiny_ds, net=NET, engine=True, **KW)
    assert (eng.comm.bytes != ref.comm.bytes
            or eng.fair_acc != ref.fair_acc)


@pytest.mark.parametrize("algo", ["facade", "dac"])
def test_engine_legacy_parity_reset_restarts(algo, tiny_ds):
    """restart_mode="reset" factory-resets a rejoining node BEFORE the
    round, identically in both drivers (the stateful-extra algorithms are
    the hard cases: FACADE's cluster ids, DAC's similarity table)."""
    net = _faulted(FaultConfig(crash_rate=0.4, restart_rate=0.6,
                               restart_mode="reset"))
    eng = run_experiment(algo, CFG, tiny_ds, net=net, engine=True, **KW)
    leg = run_experiment(algo, CFG, tiny_ds, net=net, engine=False, **KW)
    _assert_runs_identical(eng, leg)


# ------------------------------------------------- byte/time honesty ------
def test_crashed_nodes_cost_zero_bytes_and_seconds(tiny_ds):
    """crash_rate=1, restart_rate=0: after round 1 every node is down —
    no bytes move and the round clock never waits on a corpse."""
    net = _faulted(FaultConfig(crash_rate=1.0, restart_rate=0.0))
    r = run_experiment("el", CFG, tiny_ds, net=net, **KW)
    per_round = np.diff(np.asarray([0.0] + list(r.comm.bytes)))
    assert (per_round == 0).all()
    per_s = np.diff(np.asarray([0.0] + list(r.comm.seconds)))
    assert (per_s == 0).all()


# ------------------------------------------------- guard unit tests -------
def _ring_w(n):
    from repro.core import topology
    return topology.mixing_matrix(topology.ring(n, 2))


def test_gossip_mix_guard_quarantines_nan_sender():
    n = 4
    w = _ring_w(n)
    key = jax.random.PRNGKey(0)
    tree = {"p": jax.random.normal(key, (n, 3))}
    poisoned = {"p": tree["p"].at[1].set(jnp.nan)}
    guard = FaultConfig(corrupt_rate=0.5, corrupt_mode="nan")
    out = gossip_mix(w, tree, poisoned, guard=resil.guard_of(guard))
    # receivers stay finite; the poisoned sender's row mixes only its own
    # (finite, local) state with honest neighbors
    assert bool(jnp.isfinite(out["p"]).all())
    # unguarded: NaN spreads to every neighbor of node 1
    bad = gossip_mix(w, tree, poisoned)
    assert not bool(jnp.isfinite(bad["p"]).all())


def test_gossip_mix_guard_clips_oversized_sender():
    n = 4
    w = _ring_w(n)
    tree = {"p": jnp.ones((n, 3))}
    blown = {"p": tree["p"].at[2].mul(1e6)}
    guard = resil.guard_of(FaultConfig(corrupt_rate=0.5, clip=3.0))
    out = gossip_mix(w, tree, blown, guard=guard)
    # the 1e6-norm payload is clipped to ~clip x receiver norm, so no
    # receiver can be dragged more than a few x its own scale
    assert float(jnp.abs(out["p"]).max()) < 1e3
    bad = gossip_mix(w, tree, blown)
    assert float(jnp.abs(bad["p"]).max()) > 1e4


def test_guard_of_statically_gates():
    assert resil.guard_of(None) is None
    assert resil.guard_of(FaultConfig()) is None                # rate 0
    assert resil.guard_of(FaultConfig(corrupt_rate=0.5,
                                      robust=False)) is None    # robust off
    g = resil.guard_of(FaultConfig(corrupt_rate=0.5))
    assert g is not None and g.clip == 3.0


# ------------------------------------------------- fault primitives -------
def test_corrupt_view_modes_and_masking():
    n = 3
    conds = netsim.RoundConditions(
        edge_mask=jnp.ones((n, n)), active=jnp.ones((n,)),
        straggler=jnp.zeros((n,)), stale=None,
        corrupt=jnp.asarray([0.0, 1.0, 0.0]),
        fault_key=jax.random.PRNGKey(7))
    tree = {"f": jnp.ones((n, 2)), "i": jnp.arange(n, dtype=jnp.int32)}
    for mode, check in [
        ("nan", lambda v: bool(jnp.isnan(v).all())),
        ("scale", lambda v: bool((v == 100.0).all())),
        ("noise", lambda v: bool((jnp.abs(v - 1.0) > 1.0).all())),
    ]:
        out = resil.corrupt_view(
            FaultConfig(corrupt_rate=0.5, corrupt_mode=mode), conds, tree)
        assert check(out["f"][1]), mode               # masked row mangled
        np.testing.assert_array_equal(out["f"][0], tree["f"][0])
        np.testing.assert_array_equal(out["f"][2], tree["f"][2])
        np.testing.assert_array_equal(out["i"], tree["i"])  # ints shielded


def test_reset_nodes_restores_only_restarted_rows():
    n = 2
    init = {"p": jnp.zeros((n, 3)), "rng": jnp.zeros((2,), jnp.uint32),
            "round": jnp.asarray(0)}
    live = {"p": jnp.ones((n, 3)), "rng": jnp.ones((2,), jnp.uint32),
            "round": jnp.asarray(9)}
    out = resil.reset_nodes(n, jnp.asarray([1.0, 0.0]), init, live)
    np.testing.assert_array_equal(out["p"][0], np.zeros(3))   # reset
    np.testing.assert_array_equal(out["p"][1], np.ones(3))    # untouched
    # PRNG keys (uint32, shape (2,) == n here!) and scalars pass through
    np.testing.assert_array_equal(out["rng"], live["rng"])
    assert int(out["round"]) == 9


def test_init_state_gating():
    assert resil.init_state(None, 4) is None
    assert resil.init_state(NET, 4) is None
    assert resil.init_state(_faulted(FaultConfig(corrupt_rate=0.5)),
                            4) is None                # corruption: stateless
    st = resil.init_state(_faulted(FaultConfig(crash_rate=0.5)), 4)
    assert isinstance(st, FaultState) and st.init is None
    with pytest.raises(ValueError):
        resil.init_state(_faulted(FaultConfig(crash_rate=0.5,
                                              restart_mode="reset")), 4)
    st = resil.init_state(
        _faulted(FaultConfig(crash_rate=0.5, restart_mode="reset")), 4,
        state={"p": jnp.ones((4, 2))})
    assert st.init is not None


# ------------------------------------------------- checkpoint io ----------
def test_checkpoint_roundtrip_bf16_none_namedtuple(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "bf": jnp.ones((3,), jnp.bfloat16) * 1.5,
            "none": None,
            "nt": FaultState(down=np.zeros(4, np.float32), init=None),
            "nested": [np.asarray(2), (np.asarray(3.0), None)]}
    p = tmp_path / "ck.npz"
    checkpoint.save(str(p), tree, meta={"k": 1})
    got, meta = checkpoint.load(str(p))
    assert meta == {"k": 1}
    np.testing.assert_array_equal(got["a"], tree["a"])
    assert got["bf"].dtype == np.dtype("bfloat16")
    np.testing.assert_array_equal(np.asarray(got["bf"], np.float32),
                                  np.asarray(tree["bf"], np.float32))
    assert got["none"] is None
    # NamedTuples come back as plain tuples (container survives, class
    # doesn't) — resume unflattens onto a typed template treedef
    assert got["nt"] == (pytest.approx(np.zeros(4)), None)
    assert got["nested"][1] == (pytest.approx(3.0), None)
    assert not p.with_name(p.name + ".tmp").exists()  # atomic: no tmp left


def test_checkpoint_save_is_atomic_over_existing(tmp_path):
    p = tmp_path / "ck.npz"
    checkpoint.save(str(p), {"v": np.asarray(1)})
    checkpoint.save(str(p), {"v": np.asarray(2)})    # overwrite, atomically
    got, _ = checkpoint.load(str(p))
    assert int(got["v"]) == 2


def test_checkpoint_load_errors_name_the_path(tmp_path):
    with pytest.raises(FileNotFoundError, match="no checkpoint"):
        checkpoint.load(str(tmp_path / "missing.npz"))
    bad = tmp_path / "garbage.npz"
    bad.write_bytes(b"this is not a zip archive")
    with pytest.raises(checkpoint.CheckpointError, match="garbage.npz"):
        checkpoint.load(str(bad))
    # truncated: a real checkpoint cut in half
    p = tmp_path / "trunc.npz"
    checkpoint.save(str(p), {"v": np.arange(1000)})
    p.write_bytes(p.read_bytes()[: p.stat().st_size // 2])
    with pytest.raises(checkpoint.CheckpointError, match="trunc.npz"):
        checkpoint.load(str(p))


# ------------------------------------------------- kill + resume ----------
class _Killed(Exception):
    pass


def _run_killed_then_resume(algo, ds, net, ck, kw, obs_cfg=None):
    """Run with ckpt, kill after the first segment, then resume. Returns
    the resumed result and its Obs."""
    orig = engine_mod.SegmentEngine.run_segment
    calls = {"n": 0}

    def killer(self, *a, **k):
        if calls["n"] >= 1:
            raise _Killed()
        calls["n"] += 1
        return orig(self, *a, **k)

    obs = Obs(config=obs_cfg) if obs_cfg is not None else None
    engine_mod.SegmentEngine.run_segment = killer
    try:
        with pytest.raises(_Killed):
            run_experiment(algo, CFG, ds, net=net, ckpt=ck, obs=obs, **kw)
    finally:
        engine_mod.SegmentEngine.run_segment = orig
    obs2 = Obs(config=obs_cfg) if obs_cfg is not None else None
    got = run_experiment(algo, CFG, ds, net=net, ckpt=ck, obs=obs2, **kw)
    return got, obs2


@pytest.mark.parametrize("algo", ALL_ALGOS)
def test_kill_and_resume_bit_parity(algo, tiny_ds, tmp_path):
    """The headline resume contract: kill after segment 1, resume with the
    same call, and the run is indistinguishable from an uninterrupted one
    — metrics, CommLog, cluster history, per-node accuracy, obs frames,
    and the FINAL CARRY (params and all) down to the last bit."""
    kw = {**KW, "rounds": 4, "eval_every": 2}
    net = _faulted(FaultConfig(crash_rate=0.3, corrupt_rate=0.3))
    ocfg = ObsConfig()
    obs_ref = Obs(config=ocfg)
    ref_ck = str(tmp_path / f"{algo}-ref.npz")
    ref = run_experiment(algo, CFG, tiny_ds, net=net, ckpt=ref_ck,
                         obs=obs_ref, **kw)
    ck = str(tmp_path / f"{algo}.npz")
    got, obs_got = _run_killed_then_resume(algo, tiny_ds, net, ck, kw,
                                           obs_cfg=ocfg)
    _assert_runs_identical(ref, got)
    # final carries (params, PRNG, channel, gossip, crash chain) match
    # leaf-for-leaf across the interrupted and uninterrupted runs
    pr, _ = checkpoint.load(ref_ck)
    pg, _ = checkpoint.load(ck)
    for a, b in zip(jax.tree.leaves(pr["carry"]),
                    jax.tree.leaves(pg["carry"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # obs frame streams match
    fr, fg = obs_ref.frames_table(), obs_got.frames_table()
    assert set(fr) == set(fg)
    for k in fr:
        np.testing.assert_array_equal(np.asarray(fr[k]), np.asarray(fg[k]))


def test_resume_of_finished_run_is_a_noop_replay(tiny_ds, tmp_path):
    kw = {**KW, "rounds": 4, "eval_every": 2}
    ck = str(tmp_path / "done.npz")
    ref = run_experiment("el", CFG, tiny_ds, net=NET, ckpt=ck, **kw)
    again = run_experiment("el", CFG, tiny_ds, net=NET, ckpt=ck, **kw)
    _assert_runs_identical(ref, again)


def test_resume_refuses_foreign_checkpoint(tiny_ds, tmp_path):
    kw = {**KW, "rounds": 4, "eval_every": 2}
    ck = str(tmp_path / "ck.npz")
    run_experiment("el", CFG, tiny_ds, net=NET, ckpt=ck, **kw)
    with pytest.raises(ValueError, match="fingerprint"):
        run_experiment("el", CFG, tiny_ds, net=NET, ckpt=ck,
                       **{**kw, "seed": 1})


def test_ckpt_requires_engine(tiny_ds, tmp_path):
    with pytest.raises(ValueError, match="engine"):
        run_experiment("el", CFG, tiny_ds, net=NET, engine=False,
                       ckpt=str(tmp_path / "x.npz"), **KW)


# ------------------------------------------------- obs integration --------
def test_frames_carry_fault_counters(tiny_ds):
    obs = Obs(config=ObsConfig())
    # corrupt_rate=1.0: with only 4 nodes x 3 rounds, and churn + crashes
    # already benching half the fleet, a 0.5 coin can miss every live
    # sender for the whole run — rate 1 makes "some corrupted sender
    # existed" deterministic (any round with a live node)
    net = _faulted(FaultConfig(crash_rate=0.5, corrupt_rate=1.0,
                               corrupt_mode="nan"))
    run_experiment("el", CFG, tiny_ds, net=net, obs=obs, **KW)
    t = obs.frames_table()
    for f in ("crashed", "corrupted", "quarantined"):
        assert f in t
    assert np.asarray(t["crashed"]).sum() > 0
    assert np.asarray(t["corrupted"]).sum() > 0
    assert np.asarray(t["quarantined"]).sum() > 0
    # gated off: the fields exist but stay zero
    obs0 = Obs(config=ObsConfig(faults=False))
    run_experiment("el", CFG, tiny_ds, net=net, obs=obs0, **KW)
    t0 = obs0.frames_table()
    assert np.asarray(t0["crashed"]).sum() == 0
    assert np.asarray(t0["quarantined"]).sum() == 0


def test_robust_mix_keeps_params_finite_under_nan_storm(tiny_ds):
    """Run-level guard story: at 20% NaN corruption the unguarded mix
    poisons the model; the robust mix never lets a non-finite parameter
    through (the benchmark's headline, pinned at smoke scale)."""
    obs_r, obs_u = Obs(config=ObsConfig()), Obs(config=ObsConfig())
    base = FaultConfig(corrupt_rate=0.2, corrupt_mode="nan")
    run_experiment("dpsgd", CFG, tiny_ds, obs=obs_r,
                   net=_faulted(base), **KW)
    run_experiment("dpsgd", CFG, tiny_ds, obs=obs_u,
                   net=_faulted(dataclasses.replace(base, robust=False)),
                   **KW)
    assert np.isfinite(np.asarray(obs_r.frames_table()["param_norm"])).all()
    assert not np.isfinite(
        np.asarray(obs_u.frames_table()["param_norm"])).all()


# ------------------------------------------------- sweep resilience -------
def test_sweep_survives_failing_cell(tiny_ds):
    kw = dict(k=2, degree=2, local_steps=2, batch_size=4, lr=0.05,
              eval_every=2)
    cells = [
        SweepCell("ok", "el", CFG, tiny_ds, rounds=2, kwargs=dict(kw)),
        SweepCell("bad", "el", CFG, tiny_ds, rounds=2,
                  kwargs={**kw, "degree": 99}),
        SweepCell("ok2", "dpsgd", CFG, tiny_ds, rounds=2, kwargs=dict(kw)),
    ]
    obs = Obs()
    res = run_sweep(cells, seeds=[0], obs=obs)
    assert res.cell("bad").error is not None
    assert res.cell("ok").error is None and res.cell("ok2").error is None
    assert [e for e in obs.tracer.events
            if e.get("name") == "sweep.cell_failed"]
    j = res.to_json()
    assert j["cells"]["bad"]["error"] is not None
    assert j["cells"]["ok"]["error"] is None


def test_sweep_raises_only_when_all_cells_fail(tiny_ds):
    kw = dict(k=2, degree=99, local_steps=2, batch_size=4, lr=0.05,
              eval_every=2)
    cells = [SweepCell("bad1", "el", CFG, tiny_ds, rounds=2,
                       kwargs=dict(kw)),
             SweepCell("bad2", "dpsgd", CFG, tiny_ds, rounds=2,
                       kwargs=dict(kw))]
    with pytest.raises(RuntimeError, match="every sweep cell failed"):
        run_sweep(cells, seeds=[0])


def test_sweep_ckpt_dir_skips_completed_cells(tiny_ds, tmp_path):
    kw = dict(k=2, degree=2, local_steps=2, batch_size=4, lr=0.05,
              eval_every=2)
    cells = [SweepCell("c1", "el", CFG, tiny_ds, rounds=2,
                       kwargs=dict(kw))]
    ckd = tmp_path / "grid"
    res1 = run_sweep(cells, seeds=[0, 1], ckpt_dir=ckd)
    assert not res1.cell("c1").skipped
    assert (ckd / "c1.summary.json").exists()
    assert (ckd / "c1.manifest.json").exists()
    assert (ckd / "c1-s0.npz").exists()              # per-run checkpoints
    obs = Obs()
    res2 = run_sweep(cells, seeds=[0, 1], ckpt_dir=ckd, obs=obs)
    assert res2.cell("c1").skipped
    assert [e for e in obs.tracer.events
            if e.get("name") == "sweep.cell_skipped"]
    assert (json.loads(json.dumps(res1.cell("c1").summary, default=float))
            == json.loads(json.dumps(res2.cell("c1").summary,
                                     default=float)))
    # a different sweep axis (seeds) forks the fingerprint: no false skip
    res3 = run_sweep(cells, seeds=[5], ckpt_dir=ckd)
    assert not res3.cell("c1").skipped


def test_sweep_owns_ckpt_kwarg(tiny_ds, tmp_path):
    cells = [SweepCell("c", "el", CFG, tiny_ds, rounds=2,
                       kwargs={"ckpt": "x.npz"})]
    with pytest.raises(ValueError, match="ckpt"):
        run_sweep(cells, seeds=[0], ckpt_dir=tmp_path)
