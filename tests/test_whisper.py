"""Whisper (enc-dec) backbone: encoder determinism, loss, decode parity
with the full teacher-forced forward."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs  # noqa: F401
from repro.models import api, whisper
from repro.models.base import get_config


def _setup(b=2, s=16):
    cfg = get_config("whisper-tiny", smoke=True)
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    frames = 0.1 * jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model),
                                     cfg.dt)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    return cfg, params, frames, tokens


def test_encoder_shapes_and_determinism():
    cfg, params, frames, _ = _setup()
    e1 = whisper.encode(cfg, params, frames)
    e2 = whisper.encode(cfg, params, frames)
    assert e1.shape == frames.shape
    np.testing.assert_array_equal(np.asarray(e1, np.float32),
                                  np.asarray(e2, np.float32))


def test_loss_finite_and_grads_flow():
    cfg, params, frames, tokens = _setup()
    batch = {"tokens": tokens, "labels": tokens,
             "mask": jnp.ones(tokens.shape, jnp.float32), "frames": frames}
    loss, g = jax.value_and_grad(
        lambda p: whisper.loss_fn(cfg, p, batch)[0])(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(l.astype(jnp.float32))))
                for l in jax.tree.leaves(g))
    assert gnorm > 0


def test_decode_matches_teacher_forced_forward():
    cfg, params, frames, tokens = _setup(b=2, s=12)
    b, s = tokens.shape
    feats, _ = whisper.forward(cfg, params, tokens, frames)
    w = whisper.lm_head_weight(params)
    ref = (feats @ w).astype(jnp.float32)              # [B,S,V]

    cache = whisper.init_cache(cfg, params, frames, b, s)
    for t in range(s):
        pos = jnp.full((b,), t, jnp.int32)
        logits, cache = whisper.decode_step(cfg, params, cache,
                                            tokens[:, t:t + 1], pos)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref[:, t]), rtol=3e-2, atol=3e-2,
            err_msg=f"whisper decode diverges at {t}")
