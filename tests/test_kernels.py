"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode).

Assignment: "For each Pallas kernel, sweep shapes/dtypes and
assert_allclose against the ref.py pure-jnp oracle."

Capability-gated: the whole module skips (with the probe's reason) when
Pallas interpret-mode lowering — or the ``pallas.tpu`` API surface the
kernels are written against — is unavailable on this box; where it works
the sweeps run in interpret mode as before.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import requires_pallas

from repro.kernels.flash_attention import ops as fa
from repro.kernels.head_select import ops as hs
from repro.kernels.head_select.ref import head_losses_ref
from repro.kernels.rwkv6 import ops as rw

pytestmark = requires_pallas


# --------------------------------------------------------------------------
FA_SHAPES = [
    # (B, Hq, Hkv, S, D)
    (1, 4, 4, 128, 64),      # MHA
    (2, 8, 2, 256, 64),      # GQA group 4
    (1, 4, 1, 128, 128),     # MQA, wide head
    (2, 2, 2, 512, 64),      # longer seq
]


@pytest.mark.parametrize("b,hq,hkv,s,d", FA_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, hq, hkv, s, d, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = (0.3 * jax.random.normal(ks[0], (b, hq, s, d))).astype(dtype)
    k = (0.3 * jax.random.normal(ks[1], (b, hkv, s, d))).astype(dtype)
    v = (0.3 * jax.random.normal(ks[2], (b, hkv, s, d))).astype(dtype)
    out = fa.flash_attention_op(q, k, v, interpret=True)
    ref = fa.attention_ref(q, k, v)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    b, hq, hkv, s, d = 1, 2, 2, 256, 64
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = 0.3 * jax.random.normal(ks[0], (b, hq, s, d))
    k = 0.3 * jax.random.normal(ks[1], (b, hkv, s, d))
    v = 0.3 * jax.random.normal(ks[2], (b, hkv, s, d))
    out = fa.flash_attention_op(q, k, v, window=window, interpret=True,
                                block_q=64, block_kv=64)
    ref = fa.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("block_q,block_kv", [(64, 64), (128, 256)])
def test_flash_attention_block_shape_invariance(block_q, block_kv):
    b, hq, hkv, s, d = 1, 2, 1, 512, 64
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 3)
    q = 0.3 * jax.random.normal(ks[0], (b, hq, s, d))
    k = 0.3 * jax.random.normal(ks[1], (b, hkv, s, d))
    v = 0.3 * jax.random.normal(ks[2], (b, hkv, s, d))
    out = fa.flash_attention_op(q, k, v, block_q=block_q, block_kv=block_kv,
                                interpret=True)
    ref = fa.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-6, atol=2e-6)


# --------------------------------------------------------------------------
HS_SHAPES = [
    # (K, T, D, V)
    (2, 128, 64, 256),
    (3, 256, 64, 512),
    (5, 128, 128, 1024),
]


@pytest.mark.parametrize("k,t,d,v", HS_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_head_select_matches_ref(k, t, d, v, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    feats = (0.5 * jax.random.normal(ks[0], (t, d))).astype(dtype)
    heads = (0.05 * jax.random.normal(ks[1], (k, d, v))).astype(dtype)
    labels = jax.random.randint(ks[2], (t,), 0, v, dtype=jnp.int32)
    mask = (jax.random.uniform(ks[2], (t,)) > 0.1).astype(jnp.float32)
    got = hs.facade_head_losses(feats, heads, labels, mask, interpret=True)
    want = head_losses_ref(feats, heads, labels, mask)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)
    # argmin (the FACADE selection decision) must agree exactly
    assert int(jnp.argmin(got)) == int(jnp.argmin(want))


def test_head_select_negative_labels_excluded():
    k, t, d, v = 2, 64, 32, 128
    key = jax.random.PRNGKey(3)
    feats = 0.5 * jax.random.normal(key, (t, d))
    heads = 0.05 * jax.random.normal(key, (k, d, v))
    labels = jax.random.randint(key, (t,), 0, v, dtype=jnp.int32)
    labels = labels.at[:10].set(-1)
    got = hs.facade_head_losses(feats, heads, labels, None, interpret=True)
    want = head_losses_ref(feats, heads, labels, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# --------------------------------------------------------------------------
RW_SHAPES = [
    # (B, T, H, hd)
    (1, 64, 1, 32),
    (2, 128, 2, 32),
    (1, 256, 4, 64),
]


@pytest.mark.parametrize("b,t,h,hd", RW_SHAPES)
def test_rwkv6_wkv_matches_ref(b, t, h, hd):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    r = 0.3 * jax.random.normal(ks[0], (b, t, h, hd))
    k = 0.3 * jax.random.normal(ks[1], (b, t, h, hd))
    v = 0.3 * jax.random.normal(ks[2], (b, t, h, hd))
    w = jnp.exp(-jnp.exp(0.3 * jax.random.normal(ks[3], (b, t, h, hd))))
    u = 0.3 * jax.random.normal(ks[4], (h, hd))
    y1, s1 = rw.wkv_op(r, k, v, w, u, interpret=True)
    y2, s2 = rw.wkv_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_t", [16, 64])
def test_rwkv6_block_invariance(block_t):
    b, t, h, hd = 1, 128, 2, 32
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 5)
    r = 0.3 * jax.random.normal(ks[0], (b, t, h, hd))
    k = 0.3 * jax.random.normal(ks[1], (b, t, h, hd))
    v = 0.3 * jax.random.normal(ks[2], (b, t, h, hd))
    w = jnp.exp(-jnp.exp(0.3 * jax.random.normal(ks[3], (b, t, h, hd))))
    u = 0.3 * jax.random.normal(ks[4], (h, hd))
    y1, _ = rw.wkv_op(r, k, v, w, u, block_t=block_t, interpret=True)
    y2, _ = rw.wkv_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
