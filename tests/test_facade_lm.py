"""FACADE over an LM backbone: the core/head machinery must work for the
assigned transformer architectures, and the fused head-select kernel must
agree with the binding's per-head losses (the decision both paths feed is
the paper's cluster identification step)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import requires_pallas

from repro.core import facade as facade_mod
from repro.core.bindings import make_binding
from repro.core.state import init_facade_state
from repro.kernels.head_select.ops import facade_head_losses
from repro.models.base import get_config


@pytest.mark.parametrize("arch", ["llama3.2-1b", "rwkv6-1.6b"])
def test_facade_round_on_lm(arch):
    cfg = get_config(arch, smoke=True)
    binding = make_binding(cfg)
    n, k, H, B, S = 2, 2, 1, 2, 32
    fcfg = facade_mod.FacadeConfig(n_nodes=n, k=k, degree=1, local_steps=H,
                                   lr=1e-2)
    state = init_facade_state(binding, jax.random.PRNGKey(0), n, k,
                              head_jitter=1e-3)
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (n, H, B, S + 1), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    batches = {"tokens": toks[..., :-1], "labels": toks[..., 1:],
               "mask": jnp.ones((n, H, B, S), jnp.float32)}
    state2, info = facade_mod.facade_round(fcfg, binding, state, batches)
    assert info["selection_losses"].shape == (n, k)
    assert np.all(np.isfinite(np.asarray(info["selection_losses"])))
    for leaf in jax.tree.leaves(state2.cores):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


@requires_pallas
def test_head_select_kernel_agrees_with_binding():
    """The Pallas fused-CE kernel and the binding's head_loss must rank the
    k candidate heads identically (same argmin -> same clustering)."""
    cfg = get_config("llama3.2-1b", smoke=True)
    binding = make_binding(cfg)
    k = 3
    key = jax.random.PRNGKey(0)
    params = binding.init(key)
    from repro.core import split
    core, head = split.split_params(params, binding.head_keys)
    heads_k = split.stack_heads(head, k, key=jax.random.PRNGKey(1),
                                jitter=0.02)

    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "mask": jnp.ones((B, S), jnp.float32)}

    feats = binding.features(core, batch)

    # path 1: binding loop (what facade_round uses on CPU)
    losses_binding = jnp.stack([
        binding.head_loss(jax.tree.map(lambda l: l[i], heads_k), feats,
                          batch) for i in range(k)])

    # path 2: fused Pallas kernel on the flattened token stream
    from repro.models import layers
    normed = jnp.stack([
        layers.rms_norm(feats, heads_k["final_norm"][i], cfg.norm_eps)
        for i in range(k)])                                # [k,B,S,D]
    w = heads_k["lm_head"]                                 # [k,D,V]
    t = B * S
    # kernel wants one shared feature stream; here the norm differs per
    # head, so feed each head its own normed stream via vmap
    losses_kernel = jax.vmap(
        lambda f, wh: facade_head_losses(
            f.reshape(t, -1), wh[None], batch["labels"].reshape(t),
            batch["mask"].reshape(t), interpret=True)[0])(normed, w)

    np.testing.assert_allclose(np.asarray(losses_kernel),
                               np.asarray(losses_binding),
                               rtol=1e-4, atol=1e-5)
    assert int(jnp.argmin(losses_kernel)) == int(jnp.argmin(losses_binding))
