"""Mixed-precision master weights, grad clipping, grad accumulation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim


def test_master_weights_avoid_bf16_drift():
    """1000 tiny updates on bf16 params: with master weights the value
    tracks fp32 reference; without, bf16 rounding freezes progress."""
    lr, n = 1e-4, 1000

    def run(opt, dtype):
        params = {"w": jnp.ones((), dtype)}
        state = opt.init(params)

        @jax.jit
        def step(p, s):
            g = {"w": jnp.ones((), dtype)}  # constant gradient
            ups, s = opt.update(g, s, p)
            return optim.apply_updates(p, ups), s

        for _ in range(n):
            params, state = step(params, state)
        return float(params["w"])

    ref = 1.0 - lr * n                                # exact fp32 answer
    plain = run(optim.sgd(lr), jnp.bfloat16)
    master = run(optim.master_weights(optim.sgd(lr)), jnp.bfloat16)
    # returned params are bf16-cast of the fp32 master: error <= bf16 ulp
    assert abs(master - ref) <= 2 ** -8, (master, ref)
    # plain bf16: 1.0 - 1e-4 rounds back to 1.0 -> no progress at all
    assert abs(plain - 1.0) < 1e-3, plain


def test_clip_by_global_norm():
    opt = optim.clip_by_global_norm(optim.sgd(1.0), max_norm=1.0)
    params = {"a": jnp.zeros((3,)), "b": jnp.zeros((4,))}
    state = opt.init(params)
    g = {"a": jnp.full((3,), 100.0), "b": jnp.full((4,), 100.0)}
    ups, _ = opt.update(g, state, params)
    norm = np.sqrt(sum(float(jnp.sum(jnp.square(u)))
                       for u in jax.tree.leaves(ups)))
    assert norm <= 1.0 + 1e-5
    # small grads pass through unclipped
    g2 = {"a": jnp.full((3,), 0.01), "b": jnp.full((4,), 0.01)}
    ups2, _ = opt.update(g2, opt.init(params), params)
    np.testing.assert_allclose(np.asarray(ups2["a"]), 0.01, rtol=1e-5)


def test_accumulate_gradients_matches_full_batch():
    key = jax.random.PRNGKey(0)
    w = {"w": jax.random.normal(key, (8, 4))}
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    y = jax.random.normal(jax.random.PRNGKey(2), (16, 4))

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"]
        l = jnp.mean(jnp.square(pred - batch["y"]))
        return l, {"l": l}

    (full_loss, _), full_g = jax.value_and_grad(loss_fn, has_aux=True)(
        w, {"x": x, "y": y})
    micro = {"x": x.reshape(4, 4, 8), "y": y.reshape(4, 4, 4)}
    (acc_loss, _), acc_g = optim.accumulate_gradients(loss_fn, w, micro)
    np.testing.assert_allclose(float(acc_loss), float(full_loss), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(acc_g["w"]),
                               np.asarray(full_g["w"]), rtol=1e-4, atol=1e-6)
