"""Unit tests of the FACADE machinery: split, topology, aggregation (Eq 3/4),
head selection, settlement mechanics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import facade as facade_mod
from repro.core import split, topology
from repro.core.bindings import gossip_mix, make_binding
from repro.core.state import init_facade_state
from repro.configs.facade_paper import lenet

pytestmark = pytest.mark.tier0


# --------------------------------------------------------------------------
def test_split_merge_roundtrip():
    params = {"a": jnp.ones((3,)), "b": jnp.zeros((2, 2)),
              "head": jnp.full((4,), 2.0)}
    core, head = split.split_params(params, ("head",))
    assert set(core) == {"a", "b"} and set(head) == {"head"}
    merged = split.merge_params(core, head)
    assert jax.tree.all(jax.tree.map(jnp.array_equal, merged, params))


def test_stack_select_set_head():
    head = {"w": jnp.arange(6.0).reshape(2, 3)}
    st = split.stack_heads(head, k=4)
    assert st["w"].shape == (4, 2, 3)
    picked = split.select_head(st, jnp.int32(2))
    assert picked["w"].shape == (2, 3)
    new = {"w": jnp.full((2, 3), 9.0)}
    st2 = split.set_head(st, jnp.int32(1), new)
    assert float(st2["w"][1].sum()) == 9.0 * 6
    assert float(st2["w"][0, 0, 1]) == 1.0  # others untouched


# --------------------------------------------------------------------------
def test_random_regular_topology():
    key = jax.random.PRNGKey(0)
    n, r = 16, 4
    adj = np.asarray(topology.random_regular(key, n, r))
    assert adj.shape == (n, n)
    assert np.array_equal(adj, adj.T), "undirected"
    assert np.all(np.diag(adj) == 0), "no self loops"
    deg = adj.sum(1)
    assert np.all(deg >= 1), "no isolated nodes"
    assert abs(deg.mean() - r) <= 1.0, f"mean degree {deg.mean()} != ~{r}"


def test_mixing_matrix_rows_stochastic():
    key = jax.random.PRNGKey(1)
    adj = topology.random_regular(key, 12, 4)
    w = np.asarray(topology.mixing_matrix(adj))
    np.testing.assert_allclose(w.sum(1), 1.0, rtol=1e-6)
    assert np.all(w >= 0)


# --------------------------------------------------------------------------
def test_head_aggregation_matches_naive_loop():
    """Eq. 4 (vectorized einsum) vs a literal per-node loop."""
    key = jax.random.PRNGKey(2)
    n, k, d = 6, 3, 5
    adj = np.asarray(topology.random_regular(key, n, 2), np.float32)
    cid = np.array([0, 1, 2, 0, 1, 2], np.int32)
    heads = np.asarray(jax.random.normal(key, (n, k, d)))

    got = facade_mod._aggregate_heads(
        jnp.asarray(adj), jnp.asarray(cid), {"w": jnp.asarray(heads)}, k)
    got = np.asarray(got["w"])

    want = np.empty_like(heads)
    for i in range(n):
        for c in range(k):
            acc = heads[i, c].copy()
            cnt = 1.0
            for j in range(n):
                if adj[i, j] and cid[j] == c:
                    acc += heads[j, cid[j]]
                    cnt += 1
            want[i, c] = acc / cnt
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_core_mixing_matches_naive_loop():
    key = jax.random.PRNGKey(3)
    n, d = 5, 7
    adj = np.asarray(topology.random_regular(key, n, 2), np.float32)
    w = np.asarray(topology.mixing_matrix(jnp.asarray(adj)))
    cores = np.asarray(jax.random.normal(key, (n, d)))
    got = np.asarray(gossip_mix(
        jnp.asarray(w), {"p": jnp.asarray(cores)})["p"])
    want = w @ cores
    np.testing.assert_allclose(got, want, rtol=1e-5)


# --------------------------------------------------------------------------
def test_facade_round_shapes_and_selection():
    cfg = lenet(smoke=True).replace(n_classes=4)
    binding = make_binding(cfg)
    n, k, H, B = 4, 2, 2, 4
    fcfg = facade_mod.FacadeConfig(n_nodes=n, k=k, degree=2, local_steps=H,
                                   lr=0.05)
    state = init_facade_state(binding, jax.random.PRNGKey(0), n, k)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (n, H, B, cfg.image_size, cfg.image_size, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (n, H, B), 0, 4,
                           dtype=jnp.int32)
    state2, info = facade_mod.facade_round(fcfg, binding, state,
                                           {"x": x, "y": y})
    assert info["selection_losses"].shape == (n, k)
    assert info["cluster_id"].shape == (n,)
    assert state2.round == 1
    assert np.all(np.asarray(info["cluster_id"]) >= 0)
    assert np.all(np.asarray(info["cluster_id"]) < k)
    # comm accounting: degree * n * (core + head + id)
    assert float(info["round_bytes"]) > 0


def test_warmup_round_trains_all_heads_identically():
    cfg = lenet(smoke=True).replace(n_classes=4)
    binding = make_binding(cfg)
    n, k = 3, 3
    fcfg = facade_mod.FacadeConfig(n_nodes=n, k=k, degree=1, local_steps=1,
                                   lr=0.05, warmup_rounds=1)
    state = init_facade_state(binding, jax.random.PRNGKey(0), n, k)
    x = jax.random.normal(jax.random.PRNGKey(1), (n, 1, 2, 16, 16, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (n, 1, 2), 0, 4,
                           dtype=jnp.int32)
    state2, _ = facade_mod.facade_round(fcfg, binding, state,
                                        {"x": x, "y": y}, warmup=True)
    # all k head slots equal after a warmup round (App. F shared training)
    for leaf in jax.tree.leaves(state2.heads):
        leaf = np.asarray(leaf, np.float32)
        for c in range(1, k):
            np.testing.assert_allclose(leaf[:, c], leaf[:, 0], rtol=1e-6)


def test_final_allreduce_reaches_clusterwise_consensus():
    cfg = lenet(smoke=True).replace(n_classes=4)
    binding = make_binding(cfg)
    n, k = 4, 2
    fcfg = facade_mod.FacadeConfig(n_nodes=n, k=k, degree=2, local_steps=1,
                                   lr=0.05)
    state = init_facade_state(binding, jax.random.PRNGKey(0), n, k)
    # give nodes distinct cores
    state = state._replace(
        cores=jax.tree.map(
            lambda l: l + jnp.arange(n, dtype=jnp.float32).reshape(
                (n,) + (1,) * (l.ndim - 1)).astype(l.dtype), state.cores))
    out = facade_mod.final_allreduce(fcfg, state)
    for leaf in jax.tree.leaves(out.cores):
        leaf = np.asarray(leaf, np.float32)
        for i in range(1, n):
            np.testing.assert_allclose(leaf[i], leaf[0], rtol=1e-5,
                                       atol=1e-5)
