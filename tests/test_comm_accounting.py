"""CommLog: the time axis and the eval-only target-crossing semantics
(backfilled accuracies on eval-less rounds must never satisfy a target)."""
import pytest

from repro.comm.accounting import CommLog, gb

pytestmark = pytest.mark.tier0


def test_backfilled_rounds_never_cross_target():
    log = CommLog()
    log.record(1, 100)              # no eval ran; acc backfills to 0.0
    log.record(2, 100)
    # old semantics would return bytes for any target <= 0.0 here
    assert log.bytes_to_target(0.0) is None
    log.record(3, 100, acc=0.9)
    assert log.bytes_to_target(0.8) == 300
    # later eval-less rounds inherit 0.9 for plotting but must not
    # re-attribute the crossing
    log.record(4, 100)
    assert log.acc[-1] == 0.9 and log.evaled[-1] is False
    assert log.bytes_to_target(0.8) == 300


def test_crossing_attributed_to_measured_round_only():
    log = CommLog()
    log.record(1, 100, acc=0.5)
    log.record(2, 100)              # carries 0.5 at 200 cumulative bytes
    log.record(3, 100, acc=0.7)
    # target between the two evals: credit the round that measured >= 0.6,
    # not the backfilled middle round
    assert log.bytes_to_target(0.6) == 300
    assert log.bytes_to_target(0.5) == 100


def test_time_axis_accumulates_and_queries():
    log = CommLog()
    log.record(1, 1000, acc=0.2, round_s=10.0)
    log.record(2, 1000, round_s=5.0)
    log.record(3, 1000, acc=0.9, round_s=5.0)
    assert log.seconds == [10.0, 15.0, 20.0]
    assert log.seconds_to_target(0.9) == 20.0
    assert log.seconds_to_target(0.95) is None
    assert log.total_hours == pytest.approx(20.0 / 3600.0)
    assert log.total_gb == pytest.approx(3000 / 1e9)
    assert gb(2e9) == 2.0


def test_default_round_s_keeps_clock_at_zero():
    log = CommLog()
    log.record(1, 100, acc=0.1)
    log.record(2, 100, acc=0.2)
    assert log.seconds == [0.0, 0.0]
    assert log.total_hours == 0.0


# ------------------------------------------- never-reached sentinel -------
def test_sentinel_on_every_never_reached_path():
    """The sentinel contract (module docstring): a target the log never
    measurably crossed answers None from BOTH queries on EVERY path —
    empty log, record_bulk-only log (eval-less by construction), and a
    log whose measured accuracies all fall short."""
    empty = CommLog()
    assert empty.bytes_to_target(0.0) is None
    assert empty.seconds_to_target(0.0) is None

    bulk = CommLog()
    bulk.record_bulk([1, 2, 3], [100.0, 100.0, 100.0],
                     [1.0, 1.0, 1.0])
    assert bulk.evaled == [False, False, False]
    assert bulk.bytes_to_target(0.0) is None
    assert bulk.seconds_to_target(0.0) is None

    short = CommLog()
    short.record(1, 100, acc=0.4, round_s=2.0)
    short.record(2, 100, acc=0.5, round_s=2.0)
    assert short.bytes_to_target(0.6) is None
    assert short.seconds_to_target(0.6) is None
    # ...and both answer together once a measured eval crosses
    short.record(3, 100, acc=0.7, round_s=2.0)
    assert short.bytes_to_target(0.6) == 300
    assert short.seconds_to_target(0.6) == 6.0


def test_sentinel_helpers_render_and_propagate():
    """The shared None-safe consumers: tables render "not reached"
    instead of crashing a float format, and speedup ratios propagate the
    sentinel (a run that never got there has no finite speedup)."""
    import pathlib
    import sys
    root = str(pathlib.Path(__file__).resolve().parents[1])
    if root not in sys.path:      # bare `pytest` has no cwd on sys.path
        sys.path.insert(0, root)
    from benchmarks.common import fmt_to_target, to_target_ratio

    assert fmt_to_target(None) == "not reached"
    assert fmt_to_target(None, "{:.2f} MB") == "not reached"
    assert fmt_to_target(12.5) == "12.5 s"
    assert fmt_to_target(1.5, "{:.2f} MB") == "1.50 MB"
    assert to_target_ratio(None, 2.0) is None
    assert to_target_ratio(2.0, None) is None
    assert to_target_ratio(None, None) is None
    assert to_target_ratio(2.0, 0.0) is None         # no div-by-zero
    assert to_target_ratio(6.0, 2.0) == pytest.approx(3.0)
