"""Always-warm engine (ROADMAP Open Item 5a): the pipelined segment
driver, the persistent/LRU-bounded ``EngineCache``, and the validation
fixes that rode along.

* ``run_experiment(pipeline=True)`` double-buffers the segment loop —
  dispatch ``t+1`` before draining ``t`` — and must stay bit-for-bit
  identical to the serialized driver for every algorithm: metrics,
  CommLog, obs frames, cluster history and the FINAL CARRY.
* kill + resume under ``pipeline=True`` lands on the same trajectory.
* ``EngineCache(persist_dir=...)`` persists XLA executables on disk
  without perturbing results; ``max_entries`` LRU-evicts, but never an
  entry pinned by a live run.
* ``eval_every <= 0`` is refused up front on BOTH drivers (it used to
  divide by zero in the engine plan and silently degrade in the legacy
  loop); zero-node clusters are skipped by the evaluator instead of
  raising IndexError; checkpoint frame writes are per-segment sidecars,
  O(segments) total instead of O(segments^2).
"""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs.facade_paper import lenet
from repro.core import engine as engine_mod
from repro.core.bindings import make_binding
from repro.core.cache import (EngineCache, EngineSpec, attach_persist_dir,
                              detach_persist_dir)
from repro.core.runner import algo_setup, make_evaluator, run_experiment
from repro.data.synthetic import SynthSpec, make_clustered_data
from repro.netsim import NetworkConfig
from repro.obs import Obs, ObsConfig

pytestmark = pytest.mark.tier0

CFG = lenet(smoke=True).replace(n_classes=4)
ALL_ALGOS = ("facade", "el", "dpsgd", "deprl", "dac")
KW = dict(rounds=6, k=2, degree=2, local_steps=2, batch_size=4, lr=0.05,
          eval_every=2, seed=0)


@pytest.fixture(scope="module")
def tiny_ds():
    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    return make_clustered_data(spec, cluster_sizes=(3, 1),
                               transforms=("rot0", "rot180"))


def _assert_runs_identical(ref, got):
    assert ref.acc_per_cluster == got.acc_per_cluster
    assert ref.fair_acc == got.fair_acc
    assert ref.dp == got.dp and ref.eo == got.eo
    assert ref.final_acc == got.final_acc
    assert ref.comm.rounds == got.comm.rounds
    assert ref.comm.bytes == got.comm.bytes          # exact float equality
    assert ref.comm.seconds == got.comm.seconds
    assert ref.comm.evaled == got.comm.evaled
    np.testing.assert_array_equal(np.asarray(ref.node_acc),
                                  np.asarray(got.node_acc))
    assert len(ref.cluster_history) == len(got.cluster_history)
    for (r1, c1), (r2, c2) in zip(ref.cluster_history, got.cluster_history):
        assert r1 == r2
        np.testing.assert_array_equal(c1, c2)


def _assert_frames_identical(obs_a: Obs, obs_b: Obs):
    fa, fb = obs_a.frames_table(), obs_b.frames_table()
    assert set(fa) == set(fb)
    for k in fa:
        np.testing.assert_array_equal(np.asarray(fa[k]),
                                      np.asarray(fb[k]))


# --------------------------------------------------- pipeline parity ------
@pytest.mark.parametrize("algo", ALL_ALGOS)
def test_pipeline_matches_serialized_bitforbit(algo, tiny_ds):
    """The headline contract: pipeline=True is a pure scheduling change.
    edge-v2 carries channel state + async gossip through the overlap and
    obs frames ride in the same drained outs — everything must agree down
    to the last bit, including the frame stream."""
    net = NetworkConfig.preset("edge-v2")
    cache = EngineCache()
    ocfg = ObsConfig()
    obs_ref, obs_got = Obs(config=ocfg), Obs(config=ocfg)
    ref = run_experiment(algo, CFG, tiny_ds, net=net, cache=cache,
                         obs=obs_ref, pipeline=False, **KW)
    got = run_experiment(algo, CFG, tiny_ds, net=net, cache=cache,
                         obs=obs_got, pipeline=True, **KW)
    _assert_runs_identical(ref, got)
    _assert_frames_identical(obs_ref, obs_got)


def test_pipeline_final_carry_parity(tiny_ds, tmp_path):
    """The checkpointed final carry (params, PRNG, netsim channel) is
    leaf-for-leaf identical across the serialized and pipelined drivers —
    the pipelined checkpoint snapshots the carry BEFORE the speculative
    next dispatch donates it."""
    net = NetworkConfig.preset("edge-v2")
    cache = EngineCache()
    ck_ref = str(tmp_path / "serial.npz")
    ck_got = str(tmp_path / "pipe.npz")
    ref = run_experiment("facade", CFG, tiny_ds, net=net, cache=cache,
                         ckpt=ck_ref, pipeline=False, **KW)
    got = run_experiment("facade", CFG, tiny_ds, net=net, cache=cache,
                         ckpt=ck_got, pipeline=True, **KW)
    _assert_runs_identical(ref, got)
    pr, _ = checkpoint.load(ck_ref)
    pg, _ = checkpoint.load(ck_got)
    for a, b in zip(jax.tree.leaves(pr["carry"]),
                    jax.tree.leaves(pg["carry"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pipeline_target_acc_stops_at_same_round(tiny_ds):
    """target_acc discards at most the one speculatively dispatched
    segment: the recorded trajectory still stops at the same eval round
    as the serialized driver."""
    kw = {**KW, "rounds": 8, "target_acc": 0.0}
    ref = run_experiment("el", CFG, tiny_ds, pipeline=False, **kw)
    got = run_experiment("el", CFG, tiny_ds, pipeline=True, **kw)
    _assert_runs_identical(ref, got)
    assert got.comm.rounds[-1] == 2          # stopped at the first eval


def test_pipeline_requires_engine(tiny_ds):
    with pytest.raises(ValueError, match="engine"):
        run_experiment("el", CFG, tiny_ds, engine=False, pipeline=True,
                       **KW)


# ------------------------------------------------ pipelined kill+resume ---
class _Killed(Exception):
    pass


def test_pipeline_kill_and_resume_bit_parity(tiny_ds, tmp_path):
    """Kill the pipelined driver mid-flight (on the speculative dispatch
    of segment 2, after segment 0's checkpoint landed) and resume with
    the same pipelined call: indistinguishable from an uninterrupted
    serialized run — metrics, frames, and the final checkpointed carry."""
    net = NetworkConfig.preset("edge-churn")
    ocfg = ObsConfig()
    obs_ref = Obs(config=ocfg)
    ck_ref = str(tmp_path / "ref.npz")
    ref = run_experiment("facade", CFG, tiny_ds, net=net, ckpt=ck_ref,
                         obs=obs_ref, pipeline=False, **KW)

    orig = engine_mod.SegmentEngine.dispatch_segment
    calls = {"n": 0}

    def killer(self, *a, **k):
        if calls["n"] >= 2:
            raise _Killed()
        calls["n"] += 1
        return orig(self, *a, **k)

    ck = str(tmp_path / "killed.npz")
    obs_dead = Obs(config=ocfg)
    engine_mod.SegmentEngine.dispatch_segment = killer
    try:
        with pytest.raises(_Killed):
            run_experiment("facade", CFG, tiny_ds, net=net, ckpt=ck,
                           obs=obs_dead, pipeline=True, **KW)
    finally:
        engine_mod.SegmentEngine.dispatch_segment = orig
    assert pathlib.Path(ck).exists()     # segment 0 landed before the kill

    obs_got = Obs(config=ocfg)
    got = run_experiment("facade", CFG, tiny_ds, net=net, ckpt=ck,
                         obs=obs_got, pipeline=True, **KW)
    _assert_runs_identical(ref, got)
    _assert_frames_identical(obs_ref, obs_got)
    pr, _ = checkpoint.load(ck_ref)
    pg, _ = checkpoint.load(ck)
    for a, b in zip(jax.tree.leaves(pr["carry"]),
                    jax.tree.leaves(pg["carry"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pipeline_resume_across_driver_variants(tiny_ds, tmp_path):
    """The ckpt fingerprint deliberately excludes ``pipeline`` (identical
    trajectory => identical resume schedule): a checkpoint written by the
    serialized driver resumes under the pipelined one."""
    ck = str(tmp_path / "cross.npz")
    ref = run_experiment("el", CFG, tiny_ds, ckpt=ck, pipeline=False, **KW)
    again = run_experiment("el", CFG, tiny_ds, ckpt=ck, pipeline=True,
                           **KW)                  # finished: no-op replay
    _assert_runs_identical(ref, again)


# ----------------------------------------------------- persist_dir --------
def test_persist_dir_populates_disk_and_stays_bit_identical(tiny_ds,
                                                            tmp_path):
    """EngineCache(persist_dir=...) must (a) leave serialized executables
    on disk, (b) not perturb results, and (c) let a FRESH EngineCache
    over the same dir reproduce the run bit-for-bit (the cross-process
    warm-start story, in-process: benchmarks/warm_start.py measures the
    actual second-process speedup)."""
    ref = run_experiment("el", CFG, tiny_ds, **KW)
    pdir = tmp_path / "xla-cache"
    try:
        cache = EngineCache(persist_dir=str(pdir))
        assert cache.persist_dir == str(pdir)
        assert cache.stats()["persist_dir"] == str(pdir)
        got = run_experiment("el", CFG, tiny_ds, cache=cache, **KW)
        n_files = len(list(pdir.iterdir()))
        assert n_files > 0
        # a fresh cache over the same dir: XLA deserializes instead of
        # compiling, and the trajectory is still bit-identical
        cache2 = EngineCache(persist_dir=str(pdir))
        again = run_experiment("el", CFG, tiny_ds, cache=cache2, **KW)
    finally:
        # the persist dir is process-global jax config: detach so later
        # tests don't keep writing executables into this tmp_path
        detach_persist_dir()
    _assert_runs_identical(ref, got)
    _assert_runs_identical(ref, again)


def test_attach_persist_dir_creates_and_returns(tmp_path):
    target = tmp_path / "nested" / "cache"
    try:
        got = attach_persist_dir(target)
    finally:
        detach_persist_dir()
    assert got == str(target)
    assert target.is_dir()


def test_cache_close_detaches_before_dir_deletion(tiny_ds, tmp_path):
    """Regression: a temp persist dir deleted while still attached
    poisons every later compile in the process (XLA persists into the
    void). ``close()`` detaches, is idempotent, and a post-close compile
    in the same process works with the directory gone."""
    import shutil

    pdir = tmp_path / "xla-tmp"
    cache = EngineCache(persist_dir=str(pdir))
    run_experiment("el", CFG, tiny_ds, cache=cache, **KW)
    cache.close()
    assert cache.persist_dir is None
    cache.close()                                    # idempotent
    shutil.rmtree(pdir)
    # dir is gone AND detached: a fresh compile must still succeed
    fresh = EngineCache()
    got = run_experiment("el", CFG, tiny_ds, cache=fresh,
                         **{**KW, "local_steps": 3})
    assert np.isfinite(got.final_acc).all()


def test_cache_context_manager_detaches(tmp_path):
    import jax

    pdir = str(tmp_path / "xla-cm")
    try:
        with EngineCache(persist_dir=pdir) as cache:
            assert cache.persist_dir == pdir
            assert jax.config.jax_compilation_cache_dir == pdir
        assert cache.persist_dir is None
        assert jax.config.jax_compilation_cache_dir is None
    finally:
        detach_persist_dir()


def test_cache_close_never_stomps_a_newer_attach(tmp_path):
    """Attach is process-global, last-attach-wins: closing an OLDER cache
    must leave a newer cache's directory attached."""
    import jax

    old = EngineCache(persist_dir=str(tmp_path / "old"))
    new = EngineCache(persist_dir=str(tmp_path / "new"))
    try:
        old.close()                   # old's dir is no longer attached:
        assert jax.config.jax_compilation_cache_dir == new.persist_dir
        new.close()
        assert jax.config.jax_compilation_cache_dir is None
    finally:
        detach_persist_dir()


def test_cache_close_without_persist_dir_is_noop():
    cache = EngineCache()
    cache.close()                                    # nothing to detach
    assert cache.persist_dir is None
    with EngineCache() as cm:                        # context form too
        assert cm.persist_dir is None


# ------------------------------------------------------- LRU bound --------
def _spec(lr: float) -> EngineSpec:
    return EngineSpec(algo="el", cfg=CFG, n=4, k=2, degree=2,
                      local_steps=2, batch_size=4, lr=lr)


def test_lru_bound_evicts_oldest_and_counts():
    cache = EngineCache(max_entries=2)
    s1, s2, s3 = _spec(0.01), _spec(0.02), _spec(0.03)
    cache.entry(s1)
    cache.entry(s2)
    cache.entry(s1)                       # s1 -> MRU; s2 is now oldest
    assert cache.entry(s3) is not None    # evicts s2, not s1
    assert len(cache) == 2
    assert s1 in cache and s3 in cache and s2 not in cache
    st = cache.stats()
    assert st["evictions"] == 1 and st["max_entries"] == 2
    # compile_count stays monotone across evictions (sweep smokes assert
    # it plateaus; an eviction must never make it drop)
    before = cache.compile_count
    cache.entry(s2)                       # evicts s1, rebuilds s2
    assert cache.compile_count >= before


def test_pinned_entry_is_never_evicted():
    cache = EngineCache(max_entries=1)
    s1, s2 = _spec(0.01), _spec(0.02)
    cache.entry(s1)
    with cache.pin(s1):
        assert cache.pinned(s1)
        cache.entry(s2)                   # bound=1 but s1 is pinned:
        assert s1 in cache                # overshoot instead of breaking
        assert s2 in cache and len(cache) == 2
        assert cache.evictions == 0
    assert not cache.pinned(s1)
    cache.entry(s2)                       # unpinned now: bound enforced
    assert s1 not in cache and len(cache) == 1
    assert cache.evictions == 1


def test_max_entries_validation():
    with pytest.raises(ValueError, match="max_entries"):
        EngineCache(max_entries=0)


def test_lru_bounded_run_stays_bit_identical(tiny_ds):
    """An LRU-bounded cache thrashing across algorithms still reproduces
    the unbounded runs exactly — eviction only drops compiled programs,
    never affects a trajectory (the run's own entry is pinned)."""
    refs = {a: run_experiment(a, CFG, tiny_ds, **KW)
            for a in ("el", "dac")}
    cache = EngineCache(max_entries=1)
    for algo in ("el", "dac", "el"):      # second el rebuilds after evict
        got = run_experiment(algo, CFG, tiny_ds, cache=cache, **KW)
        _assert_runs_identical(refs[algo], got)
    assert cache.evictions >= 2
    assert len(cache) == 1


# ------------------------------------------- eval_every validation --------
@pytest.mark.parametrize("engine", [True, False], ids=["engine", "legacy"])
@pytest.mark.parametrize("bad", [0, -3])
def test_eval_every_must_be_positive_on_both_drivers(bad, engine, tiny_ds):
    """eval_every=0 used to die in segment_plan's range() step (engine)
    and silently degrade to a single final eval (legacy); both now refuse
    up front with the same error."""
    with pytest.raises(ValueError, match="eval_every"):
        run_experiment("el", CFG, tiny_ds, engine=engine,
                       **{**KW, "eval_every": bad})


# ------------------------------------------------ empty clusters ----------
@pytest.fixture(scope="module")
def lopsided_ds():
    """k=2 splits/test sets but every node in cluster 0 — the shape a
    skewed node_cluster map (or a down-scaled sweep) produces."""
    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    return make_clustered_data(spec, cluster_sizes=(4, 0),
                               transforms=("rot0", "rot180"))


def test_evaluator_skips_zero_node_clusters(lopsided_ds):
    """make_evaluator used to index p[0] of an empty gather and raise
    IndexError; empty clusters are now skipped and cluster_ids names the
    survivors."""
    binding = make_binding(CFG)
    setup = algo_setup("el", binding, jax.random.PRNGKey(0),
                       lopsided_ds.n_nodes, 2, degree=2, local_steps=2,
                       lr=0.05)
    evaluate = make_evaluator(binding, lopsided_ds.node_cluster,
                              lopsided_ds.test_x, lopsided_ds.test_y,
                              batch=5)
    assert evaluate.cluster_ids == (0,)
    accs, preds_c, labels_c, node_acc = evaluate(
        setup.models_of(setup.state))
    assert len(accs) == 1 and len(preds_c) == 1 and len(labels_c) == 1
    assert np.asarray(node_acc).shape == (4,)
    assert np.isfinite(accs[0])


@pytest.mark.parametrize("engine", [True, False], ids=["engine", "legacy"])
def test_run_with_empty_cluster_end_to_end(engine, lopsided_ds):
    res = run_experiment("el", CFG, lopsided_ds, engine=engine, **KW)
    assert len(res.final_acc) == 1
    assert all(np.isfinite(a) for a in res.final_acc)
    assert np.isfinite(res.dp) and np.isfinite(res.eo)
    assert all(len(accs) == 1 for _, accs in res.acc_per_cluster)


# ------------------------------------------- flat checkpoint writes -------
def test_ckpt_frame_writes_are_per_segment_sidecars(tiny_ds, tmp_path):
    """Obs frames go to append-only per-segment sidecar files: each holds
    exactly its segment's rounds (never the accumulated history, the old
    O(segments^2) layout), sizes stay flat, and the main archive carries
    only carry+hist."""
    ck = str(tmp_path / "run.npz")
    kw = {**KW, "rounds": 8, "eval_every": 1}     # 8 segments, 8 sidecars
    obs = Obs(config=ObsConfig())
    run_experiment("el", CFG, tiny_ds, ckpt=ck, obs=obs, **kw)

    payload, meta = checkpoint.load(ck)
    assert set(payload) == {"carry", "hist"}      # frames never in main
    assert meta["frame_files"] == 8
    sizes = []
    for j in range(8):
        fpath = pathlib.Path(f"{ck}.frames-{j}.npz")
        assert fpath.exists()
        rec, fmeta = checkpoint.load(str(fpath))
        assert fmeta["index"] == j
        # one segment's rounds only — the flat-write contract
        np.testing.assert_array_equal(np.asarray(rec["rounds"]), [j + 1])
        sizes.append(fpath.stat().st_size)
    # per-segment bytes ~flat: the last sidecar is the same size as the
    # first (a cumulative rewrite would make it ~8x)
    assert sizes[-1] <= 2 * sizes[0]

    # the resume guarantee survives the layout: a fresh Obs replays every
    # sidecar and matches the live frame stream exactly
    obs2 = Obs(config=ObsConfig())
    run_experiment("el", CFG, tiny_ds, ckpt=ck, obs=obs2, **kw)
    _assert_frames_identical(obs, obs2)
