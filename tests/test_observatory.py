"""The fairness observatory: the health rule engine, manifest schema
tolerance, crash-tolerant JSONL replay, run/sweep reports, and the
benchmark regression gate.

Unit tests drive :func:`repro.obs.evaluate_health` over hand-built
tables (every rule fires and stays quiet on demand); the acceptance
pins run real experiments — an unguarded NaN-corruption run must come
back ``fail`` while a fault-free run stays a quiet ``ok``, and a
kill+resume run must preserve the per-eval fairness trajectory
bit-for-bit.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.configs.facade_paper import lenet
from repro.core.runner import run_experiment
from repro.data.synthetic import SynthSpec, make_clustered_data
from repro.obs import (HealthConfig, HealthContext, HealthReport, Obs,
                       ObsConfig, RunManifest, evaluate_health, read_jsonl,
                       worst_verdict)
from repro.obs.report import build_report, settlement_round
from repro.obs.report import main as report_main

pytestmark = pytest.mark.tier0

CFG = lenet(smoke=True).replace(n_classes=4)
KW = dict(rounds=4, k=2, degree=2, local_steps=2, batch_size=4,
          lr=0.05, eval_every=2, seed=0)


@pytest.fixture(scope="module")
def tiny_ds():
    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    return make_clustered_data(spec, (3, 1), ("rot0", "rot180"))


# ---------------------------------------------------- health: helpers ----
def _frames(rounds, **cols):
    """A healthy frames_table() dict over ``rounds``, columns overridable
    per test (only the columns the rules read)."""
    n = len(rounds)
    table = {"round": np.asarray(rounds, np.int64),
             "update_norm": np.full(n, 0.5),
             "param_norm": np.full(n, 1.0),
             "crashed": np.zeros(n),
             "quarantined": np.zeros(n),
             "inclusion": np.ones(n),
             "cluster_switches": np.zeros(n)}
    for k, v in cols.items():
        table[k] = np.asarray(v, np.float64)
    return table


def _evals(rounds, mean_acc):
    return {"round": np.asarray(rounds, np.int64),
            "mean_acc": np.asarray(mean_acc, np.float64)}


CTX = HealthContext(n=4)


def _judge(frames=None, evals=None, ctx=CTX, cfg=HealthConfig(),
           tracer=None):
    return evaluate_health(
        cfg, ctx,
        _frames([]) if frames is None else frames,
        _evals([], []) if evals is None else evals, tracer=tracer)


class _FakeTracer:
    def __init__(self):
        self.events = []

    def event(self, name, **kw):
        self.events.append({"name": name, **kw})


# ------------------------------------------------------- health: rules ---
def test_clean_tables_verdict_ok():
    rep = _judge(_frames(range(1, 7)), _evals([2, 4, 6], [0.3, 0.5, 0.7]))
    assert rep.verdict == "ok" and rep.issues == []
    assert rep.rounds_seen == 6 and rep.evals_seen == 3


def test_empty_tables_verdict_ok():
    # a run without a device ObsConfig has no metrics frames; a
    # target_acc run may stop after one eval — rules must stay silent
    rep = _judge()
    assert rep.verdict == "ok" and rep.issues == []
    assert rep.rounds_seen == 0 and rep.evals_seen == 0


def test_nonfinite_fires_per_contiguous_range():
    un = [0.5, np.nan, np.inf, 0.5, 0.5, np.nan]
    rep = _judge(_frames([1, 2, 3, 4, 5, 6], update_norm=un))
    assert rep.verdict == "fail"
    assert [(i.rule, i.round_start, i.round_end) for i in rep.issues] == [
        ("nonfinite", 2, 3), ("nonfinite", 6, 6)]


def test_divergence_finite_but_runaway():
    pn = [1.0, 1.0, 2e6, 1.0]
    rep = _judge(_frames([1, 2, 3, 4], param_norm=pn))
    assert [i.rule for i in rep.issues] == ["divergence"]
    assert rep.verdict == "fail"
    assert rep.issues[0].value == pytest.approx(2e6)


def test_quarantine_spike():
    rep = _judge(_frames([1, 2, 3, 4], crashed=[0, 3, 3, 0]))
    (issue,) = rep.issues
    assert issue.rule == "quarantine_spike" and issue.severity == "warn"
    assert (issue.round_start, issue.round_end) == (2, 3)
    assert issue.value == pytest.approx(0.75)
    assert rep.verdict == "warn"


def test_inclusion_floor_needs_context():
    frames = _frames(range(1, 9), inclusion=np.full(8, 0.5))
    # no adaptive-topo floor in context: the rule has nothing to check
    assert _judge(frames).issues == []
    ctx = HealthContext(n=4, warmup_rounds=2, inclusion_floor=0.9)
    rep = _judge(frames, ctx=ctx)
    (issue,) = rep.issues
    assert issue.rule == "inclusion_floor" and issue.severity == "warn"
    assert issue.round_start == 3        # first post-warmup round
    # within inclusion_slack of the floor: delivered as promised
    ok = _frames(range(1, 9), inclusion=np.full(8, 0.88))
    assert _judge(ok, ctx=ctx).issues == []


def test_cluster_flapping_past_warmup_grace():
    switches = np.full(16, 4.0)          # every node flips, every round
    rep = _judge(_frames(range(1, 17), cluster_switches=switches))
    (issue,) = rep.issues
    assert issue.rule == "cluster_flapping"
    assert issue.round_start == 9        # default flap_grace=8, warmup=0
    assert issue.value == pytest.approx(1.0)
    # settled assignment: quiet
    assert _judge(_frames(range(1, 17))).issues == []


def test_accuracy_stall_low_and_flat_only():
    rounds = list(range(2, 22, 2))
    (issue,) = _judge(evals=_evals(rounds, [0.3] * 10)).issues
    assert issue.rule == "accuracy_stall" and issue.severity == "warn"
    # improving: quiet
    assert _judge(evals=_evals(rounds, np.linspace(0.1, 0.8, 10))).issues == []
    # flat but already accurate: quiet
    assert _judge(evals=_evals(rounds, [0.8] * 10)).issues == []
    # too few evals for the window: quiet
    assert _judge(evals=_evals([2, 4], [0.3, 0.3])).issues == []


def test_accuracy_collapse_from_peak():
    rep = _judge(evals=_evals([2, 4, 6, 8], [0.1, 0.5, 0.6, 0.2]))
    (issue,) = rep.issues
    assert issue.rule == "accuracy_collapse" and rep.verdict == "fail"
    assert (issue.round_start, issue.round_end) == (8, 8)
    assert issue.value == pytest.approx(0.4)
    # peak never cleared collapse_min_peak: a bad run, not a collapse
    assert _judge(evals=_evals([2, 4, 6], [0.1, 0.35, 0.05])).issues == []


def test_disable_and_unknown_rule_names():
    frames = _frames([1, 2], update_norm=[np.nan, np.nan])
    assert _judge(frames).verdict == "fail"
    quiet = _judge(frames, cfg=HealthConfig(disable=("nonfinite",)))
    assert quiet.verdict == "ok"
    with pytest.raises(ValueError, match="unknown health rules"):
        HealthConfig(disable=("no_such_rule",))


def test_worst_verdict_ordering():
    assert worst_verdict([]) == "ok"
    assert worst_verdict(["ok", "ok"]) == "ok"
    assert worst_verdict(["ok", "warn", "ok"]) == "warn"
    assert worst_verdict(["warn", "fail", "warn"]) == "fail"
    # a garbled verdict is not a clean one
    assert worst_verdict(["ok", "borked"]) == "fail"


def test_health_events_fired_on_tracer():
    tracer = _FakeTracer()
    _judge(_frames([1, 2], update_norm=[np.nan, 0.5]),
           _evals([2, 4, 6, 8], [0.1, 0.5, 0.6, 0.2]), tracer=tracer)
    names = [e["name"] for e in tracer.events]
    assert names == ["health.nonfinite", "health.accuracy_collapse"]
    assert all({"severity", "round_start", "round_end", "value",
                "detail"} <= set(e) for e in tracer.events)


def test_health_report_json_roundtrip():
    rep = _judge(_frames([1, 2], update_norm=[np.nan, 0.5]))
    back = HealthReport.from_json(json.loads(json.dumps(rep.to_json())))
    assert back == rep


# ----------------------------------------- acceptance: real-run verdicts --
def test_nan_storm_flagged_clean_run_quiet(tiny_ds, tmp_path):
    from repro.netsim import NetworkConfig
    from repro.resil import FaultConfig

    ideal = NetworkConfig.preset("ideal")
    clean_obs = Obs(ObsConfig(), out_dir=tmp_path)
    run_experiment("facade", CFG, tiny_ds, net=ideal, obs=clean_obs, **KW)
    clean = clean_obs.manifests[-1].health
    assert clean["verdict"] == "ok" and clean["issues"] == []
    assert not [e for e in clean_obs.tracer.events
                if e["name"].startswith("health.")]

    storm = dataclasses.replace(ideal, faults=FaultConfig(
        corrupt_rate=0.6, corrupt_mode="nan", robust=False))
    storm_obs = Obs(ObsConfig(), out_dir=tmp_path)
    run_experiment("facade", CFG, tiny_ds, net=storm, obs=storm_obs, **KW)
    health = storm_obs.manifests[-1].health
    assert health["verdict"] == "fail"
    assert "nonfinite" in {i["rule"] for i in health["issues"]}
    fired = {e["name"] for e in storm_obs.tracer.events
             if e["name"].startswith("health.")}
    assert "health.nonfinite" in fired
    # the verdict survives the manifest round-trip on disk
    back = RunManifest.load(tmp_path / "manifest_facade-seed0.json")
    assert back.health["verdict"] == "fail"


def test_resume_preserves_eval_frames(tiny_ds, tmp_path):
    from repro.core import engine as engine_mod

    ref = run_experiment("facade", CFG, tiny_ds,
                         ckpt=str(tmp_path / "ref.npz"), **KW)
    assert len(ref.eval_frames) == 2     # rounds 2 and 4

    class _Killed(Exception):
        pass

    ck = str(tmp_path / "killed.npz")
    orig = engine_mod.SegmentEngine.run_segment
    calls = {"n": 0}

    def killer(self, *a, **k):
        if calls["n"] >= 1:
            raise _Killed()
        calls["n"] += 1
        return orig(self, *a, **k)

    engine_mod.SegmentEngine.run_segment = killer
    try:
        with pytest.raises(_Killed):
            run_experiment("facade", CFG, tiny_ds, obs=Obs(ObsConfig()),
                           ckpt=ck, **KW)
    finally:
        engine_mod.SegmentEngine.run_segment = orig

    obs = Obs(ObsConfig())
    got = run_experiment("facade", CFG, tiny_ds, obs=obs, ckpt=ck, **KW)
    # the restored half was replayed, the finished half recorded live —
    # the stitched trajectory is bit-for-bit the uninterrupted one
    assert got.eval_frames == ref.eval_frames
    table = obs.eval_table()
    assert table["round"].tolist() == [f.round for f in ref.eval_frames]
    assert table["dp"].tolist() == [f.dp for f in ref.eval_frames]
    assert table["eo"].tolist() == [f.eo for f in ref.eval_frames]


# ------------------------------------------------ manifest & jsonl I/O ---
def test_manifest_schema_growth_both_directions(tmp_path):
    m = RunManifest.build(kind="run", name="x", spec="spec",
                          settings={"preset": "ideal"},
                          health={"verdict": "warn", "issues": []})
    p = m.save(tmp_path / "m.json")
    data = json.loads(p.read_text())
    data["from_the_future"] = {"new": True}   # a newer writer's extra key
    del data["jax_version"]                   # an older writer's missing key
    p.write_text(json.dumps(data))
    back = RunManifest.load(p)
    assert back.name == "x" and back.settings == {"preset": "ideal"}
    assert back.health == {"verdict": "warn", "issues": []}
    assert back.jax_version == ""             # defaulted, no TypeError
    assert not hasattr(back, "from_the_future")


def test_read_jsonl_skips_truncated_final_line(tmp_path):
    p = tmp_path / "t.jsonl"
    p.write_text('{"a": 1}\n{"b": 2}\n{"c": 3')   # killed mid-write
    with pytest.warns(RuntimeWarning, match="truncated final line 3"):
        assert read_jsonl(p) == [{"a": 1}, {"b": 2}]


def test_read_jsonl_midfile_corruption_raises(tmp_path):
    p = tmp_path / "t.jsonl"
    p.write_text('{"a": 1}\nnot json\n{"c": 3}\n')
    with pytest.raises(json.JSONDecodeError):
        read_jsonl(p)
    assert read_jsonl(tmp_path / "never_written.jsonl") == []


# -------------------------------------------------------------- reports --
def _fake_run_artifacts(tmp_path, churn_last=0.0):
    """A manifest + JSONL trace shaped like run_experiment's output."""
    def ev(rnd, dp, churn):
        return {"type": "eval", "round": rnd, "mean_acc": 0.5, "fair_acc":
                0.6, "dp": dp, "eo": dp, "worst_cluster_acc": 0.4,
                "cluster_churn": churn}
    events = [
        {"type": "event", "name": "run.begin", "run": "facade-seed0"},
        ev(2, 0.4, 1.0), ev(4, 0.2, churn_last),
        {"type": "event", "name": "health.nonfinite", "severity": "fail",
         "round_start": 3, "round_end": 4, "value": 2.0, "detail": "x"},
        {"type": "event", "name": "run.end", "run": "facade-seed0"},
    ]
    trace = tmp_path / "trace.jsonl"
    trace.write_text("".join(json.dumps(e) + "\n" for e in events))
    manifest = RunManifest.build(
        kind="run", name="facade-seed0", spec="spec",
        settings={"jsonl": str(trace)},
        timing={"spans": {"engine.segment": {"count": 2, "total_s": 1.5}}},
        cache={"compiles": 3},
        health={"verdict": "fail", "rounds_seen": 4, "evals_seen": 2,
                "issues": [{"rule": "nonfinite", "severity": "fail",
                            "round_start": 3, "round_end": 4,
                            "value": 2.0, "detail": "poisoned"}]})
    return manifest.save(tmp_path / "manifest.json"), trace


def test_run_report_build_and_render(tmp_path):
    path, _ = _fake_run_artifacts(tmp_path)
    report, md = build_report(path)
    assert report["n_evals"] == 2
    assert report["trajectory"]["dp"] == [0.4, 0.2]
    assert report["settlement_round"] == 4    # churn at 2, settled by 4
    assert [e["name"] for e in report["health_events"]] == [
        "health.nonfinite"]
    for section in ("# Run report: facade-seed0", "**verdict: fail**",
                    "## Health", "## Fairness trajectory",
                    "settlement round: 4", "## Timing", "## Compile cache"):
        assert section in md


def test_report_settlement_and_missing_trace(tmp_path):
    # still churning at the last eval: settlement is honestly n/a
    path, trace = _fake_run_artifacts(tmp_path, churn_last=2.0)
    report, md = build_report(path)
    assert report["settlement_round"] is None
    assert "still churning" in md
    assert settlement_round([]) is None
    # a lost trace degrades to a manifest-only report, never raises
    trace.unlink()
    report, md = build_report(path)
    assert report["n_evals"] == 0 and "no eval records" in md


def test_report_cli_out_and_json(tmp_path, capsys):
    path, _ = _fake_run_artifacts(tmp_path)
    out = tmp_path / "report.md"
    assert report_main([str(path), "--out", str(out)]) == 0
    assert "# Run report: facade-seed0" in out.read_text()
    assert report_main([str(path), "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["name"] == "facade-seed0" and payload["n_evals"] == 2


def test_sweep_report_render(tmp_path):
    sweep = {"seeds": [0, 1], "wall_s": 1.0, "cells": {
        "facade/ideal": {"algo": "facade", "net": "ideal", "error": None,
                         "skipped": False,
                         "health": {"verdict": "warn"},
                         "summary": {"best_fair_acc": {"mean": 0.8},
                                     "dp": {"mean": 0.1},
                                     "eo": {"mean": 0.2}}},
        "el/ideal": {"algo": "el", "net": "ideal", "error": "boom",
                     "skipped": False, "health": None, "summary": {}},
    }}
    path = tmp_path / "sweep.json"
    path.write_text(json.dumps(sweep))
    report, md = build_report(path)
    assert report["kind"] == "sweep" and len(report["cells"]) == 2
    assert "# Sweep report" in md and "warn" in md and "ERROR" in md


# ------------------------------------------- sweep health + trajectory ---
def test_sweep_cell_health_and_fairness_trajectory(tiny_ds, tmp_path):
    from repro.sweep import SweepCell, run_sweep

    obs = Obs(ObsConfig())
    kw = {k: v for k, v in KW.items() if k not in ("rounds", "seed")}
    cell = SweepCell(name="facade-ideal", algo="facade", cfg=CFG,
                     dataset=tiny_ds, rounds=KW["rounds"], kwargs=kw)
    sweep = run_sweep([cell], seeds=(0, 1), obs=obs,
                      json_path=tmp_path / "sweep.json")
    c = sweep.to_json()["cells"]["facade-ideal"]
    assert c["health"]["verdict"] == "ok"
    assert set(c["health"]["runs"]) == {"facade-seed0", "facade-seed1"}
    traj = c["summary"]["fairness_trajectory"]
    assert [row["round"] for row in traj] == [2, 4]
    assert all(row["n"] == 2 for row in traj)
    assert {"dp_mean", "dp_std", "eo_mean", "worst_cluster_acc_mean",
            "cluster_churn_mean"} <= set(traj[0])
    # the sweep manifest rolls the per-cell verdicts up...
    man = RunManifest.load(tmp_path / "sweep.json.manifest.json")
    assert man.health == {"verdict": "ok",
                          "cells": {"facade-ideal": "ok"}}
    # ...and the sweep JSON renders through the same report CLI path
    _, md = build_report(tmp_path / "sweep.json")
    assert "# Sweep report" in md and "facade-ideal" in md


# ------------------------------------------------- the regression gate ---
def _traj_rec(name, payload):
    return {"name": name, "payload": payload}


def test_write_bench_appends_trajectory(tmp_path, monkeypatch):
    from benchmarks import common as bcommon

    monkeypatch.setattr(bcommon, "RESULTS_DIR", tmp_path)
    bcommon.write_bench("demo", {"metric": 1.0})
    bcommon.write_bench("demo", {"metric": 2.0})
    recs = read_jsonl(bcommon.trajectory_path())
    assert [r["name"] for r in recs] == ["demo", "demo"]
    assert [r["payload"]["metric"] for r in recs] == [1.0, 2.0]
    assert all("manifest" in r["payload"] for r in recs)  # bench_stamp'd
    assert (tmp_path / "BENCH_demo.json").exists()


def test_check_regress_semantics():
    from benchmarks import check_regress

    gates = {"demo": (check_regress.Gate("results.*.rps", "higher",
                                         rel_tol=0.1),)}
    good = {"results": {"a": {"rps": 100.0}, "b": {"rps": 50.0}}}
    # one record: baseline, nothing to diff, never fails
    v = check_regress.check([_traj_rec("demo", good)], gates)
    assert v["baselines"] == ["demo"] and not v["rows"]
    # identical back-to-back records pass every gate
    v = check_regress.check([_traj_rec("demo", good),
                             _traj_rec("demo", dict(good))], gates)
    assert len(v["rows"]) == 2 and not v["failures"]
    # a doctored regression on one leaf fails exactly that leaf
    bad = {"results": {"a": {"rps": 50.0}, "b": {"rps": 50.0}}}
    v = check_regress.check([_traj_rec("demo", good),
                             _traj_rec("demo", bad)], gates)
    assert [f["metric"] for f in v["failures"]] == ["results.a.rps"]
    # schema growth (a leaf absent on either side) is not a regression
    grown = {"results": {"a": {"rps": 100.0}, "c": {"rps": 1.0}}}
    v = check_regress.check([_traj_rec("demo", good),
                             _traj_rec("demo", grown)], gates)
    assert not v["failures"]
    with pytest.raises(ValueError, match="higher|lower"):
        check_regress.Gate("x", "sideways")


def test_check_regress_run_gates_the_trajectory(tmp_path, monkeypatch):
    from benchmarks import check_regress
    from benchmarks import common as bcommon

    monkeypatch.setattr(bcommon, "RESULTS_DIR", tmp_path)
    traj = bcommon.trajectory_path()
    traj.parent.mkdir(parents=True, exist_ok=True)
    good = json.dumps(_traj_rec("throughput", {"min_speedup": 2.0}))
    traj.write_text(good + "\n" + good + "\n")
    payload = check_regress.run()
    assert payload["n_failed"] == 0 and payload["n_checked"] == 1
    with traj.open("a") as fh:
        fh.write(json.dumps(_traj_rec("throughput",
                                      {"min_speedup": 0.5})) + "\n")
    with pytest.raises(RuntimeError, match="regression gate failed"):
        check_regress.run()
