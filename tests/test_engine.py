"""Scan-fused segment engine (core/engine.py): bit-for-bit parity with the
legacy per-round driver for all 5 algorithms, with and without netsim; the
FACADE warmup->main segment boundary; segment planning; bulk CommLog
recording; and the vmapped padded evaluator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.accounting import CommLog
from repro.configs.facade_paper import lenet
from repro.core.engine import Segment, SegmentEngine, segment_plan
from repro.core.runner import algo_setup, make_evaluator, run_experiment
from repro.core.bindings import make_binding
from repro.core.state import EngineCarry
from repro.data import pipeline
from repro.data.synthetic import SynthSpec, make_clustered_data
from repro.netsim import NetworkConfig

CFG = lenet(smoke=True).replace(n_classes=4)
ALGOS = ("facade", "el", "dpsgd", "deprl", "dac")


@pytest.fixture(scope="module")
def tiny_ds():
    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    return make_clustered_data(spec, cluster_sizes=(3, 1),
                               transforms=("rot0", "rot180"))


def _assert_runs_identical(ref, eng):
    assert ref.acc_per_cluster == eng.acc_per_cluster
    assert ref.fair_acc == eng.fair_acc
    assert ref.dp == eng.dp and ref.eo == eng.eo
    assert ref.final_acc == eng.final_acc
    assert ref.comm.rounds == eng.comm.rounds
    assert ref.comm.bytes == eng.comm.bytes          # exact float equality
    assert ref.comm.seconds == eng.comm.seconds
    assert ref.comm.evaled == eng.comm.evaled
    assert len(ref.cluster_history) == len(eng.cluster_history)
    for (r1, c1), (r2, c2) in zip(ref.cluster_history, eng.cluster_history):
        assert r1 == r2
        np.testing.assert_array_equal(c1, c2)


# ------------------------------------------------------------- parity ----
@pytest.mark.parametrize("netname", [None, "edge-churn", "edge-v2"],
                         ids=["ideal", "edge-churn", "edge-v2"])
@pytest.mark.parametrize("algo", ALGOS)
def test_engine_matches_legacy_bitforbit(algo, netname, tiny_ds):
    """rounds=5, eval_every=2 exercises full spans AND a trailing partial
    segment; edge-churn exercises in-scan conditions + the timing model;
    edge-v2 exercises all three netsim-v2 axes at once — the bursty
    channel state and async staleness buffers carried through the scan
    (vs threaded through the legacy Python loop) plus the heterogeneous
    link matrices in the in-scan timing feed."""
    kw = dict(rounds=5, k=2, degree=2, local_steps=2, batch_size=4,
              lr=0.05, eval_every=2, seed=0,
              net=NetworkConfig.preset(netname) if netname else None)
    ref = run_experiment(algo, CFG, tiny_ds, engine=False, **kw)
    eng = run_experiment(algo, CFG, tiny_ds, engine=True, **kw)
    _assert_runs_identical(ref, eng)


def test_facade_warmup_boundary_parity(tiny_ds):
    """Warmup->main switch mid-run: the engine must cut the segment at the
    boundary (two compiled variants), matching the legacy per-round branch
    bit for bit — including a boundary that falls inside an eval span."""
    kw = dict(rounds=6, k=2, degree=2, local_steps=2, batch_size=4,
              lr=0.05, eval_every=4, seed=0, warmup_rounds=3)
    ref = run_experiment("facade", CFG, tiny_ds, engine=False, **kw)
    eng = run_experiment("facade", CFG, tiny_ds, engine=True, **kw)
    _assert_runs_identical(ref, eng)


def test_target_acc_stops_at_same_round(tiny_ds):
    """target_acc early exit now fires at segment granularity — the same
    eval rounds the legacy driver checked, so both stop identically."""
    kw = dict(rounds=8, k=2, degree=2, local_steps=2, batch_size=4,
              lr=0.05, eval_every=2, seed=0, target_acc=0.0)
    ref = run_experiment("el", CFG, tiny_ds, engine=False, **kw)
    eng = run_experiment("el", CFG, tiny_ds, engine=True, **kw)
    _assert_runs_identical(ref, eng)
    assert ref.comm.rounds[-1] == 2          # stopped at the first eval


def test_engine_final_state_matches_python_loop(tiny_ds):
    """State-level bit parity: drive SegmentEngine directly vs a hand
    Python loop over the same stepper, and compare every leaf."""
    binding = make_binding(CFG)
    n = tiny_ds.n_nodes
    train_x = jnp.asarray(tiny_ds.train_x)
    train_y = jnp.asarray(tiny_ds.train_y)
    key = jax.random.PRNGKey(0)
    k_init, k_data = jax.random.split(key)
    kw = dict(degree=2, local_steps=2, lr=0.05)

    setup = algo_setup("el", binding, k_init, n, 2, **kw)
    state, kd = setup.state, k_data
    for rnd in range(4):
        kd, kb = jax.random.split(kd)
        batches = pipeline.sample_round_batches(kb, train_x, train_y, 2, 4)
        state, _ = setup.round_fn(state, batches, net=None)

    setup2 = algo_setup("el", binding, k_init, n, 2, **kw)
    eng = SegmentEngine(setup2.round_fn, n=n, local_steps=2, batch_size=4)
    carry = EngineCarry(setup2.state, k_data)
    carry, _ = eng.run_segment(carry, 0, 4, train_x, train_y)

    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(carry.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(kd), np.asarray(carry.k_data))


# ------------------------------------------------------ segment planning --
def test_segment_plan_cuts_at_evals_and_warmup():
    plan = segment_plan(10, 4, warmup_rounds=3)
    assert plan == [Segment(0, 3, True, False),    # warmup cut, no eval
                    Segment(3, 1, False, True),    # eval at round 4
                    Segment(4, 4, False, True),    # eval at round 8
                    Segment(8, 2, False, True)]    # final partial + eval
    # no warmup: spans are exactly the eval strides
    assert segment_plan(8, 4) == [Segment(0, 4, False, True),
                                  Segment(4, 4, False, True)]
    # warmup covering everything: every segment is warmup
    assert all(s.warmup for s in segment_plan(4, 2, warmup_rounds=9))
    assert segment_plan(0, 4) == []


# ------------------------------------------------------------ record_bulk --
def test_record_bulk_matches_per_round_records():
    a, b = CommLog(), CommLog()
    rb = np.asarray([100.0, 250.0, 50.0], np.float32)
    rs = np.asarray([1.0, 2.0, 0.5], np.float32)
    for i in range(3):
        a.record(i + 1, float(rb[i]), round_s=float(rs[i]))
    b.record_bulk(np.arange(1, 4), rb, rs)
    assert a.rounds == b.rounds
    assert a.bytes == b.bytes
    assert a.seconds == b.seconds
    assert a.acc == b.acc and a.evaled == b.evaled


def test_record_bulk_backfills_and_never_crosses_target():
    log = CommLog()
    log.record(1, 100, acc=0.4, round_s=1.0)
    log.record_bulk(np.arange(2, 5), np.full(3, 100.0), np.full(3, 1.0))
    assert log.acc[-1] == 0.4 and log.evaled[-1] is False
    assert log.bytes_to_target(0.3) == 100      # only the measured round
    assert log.bytes_to_target(0.4) == 100
    assert log.bytes_to_target(0.5) is None
    log.record(5, 100, acc=0.9, round_s=1.0)
    assert log.bytes_to_target(0.5) == 500
    assert log.seconds == [1.0, 2.0, 3.0, 4.0, 5.0]
    # empty bulk append is a no-op
    log.record_bulk(np.asarray([]), np.asarray([]), np.asarray([]))
    assert len(log.rounds) == 5
    with pytest.raises(ValueError):
        log.record_bulk(np.arange(2), np.zeros(3), np.zeros(3))


# ------------------------------------------------------- padded evaluator --
def test_padded_eval_batches_shape_stable():
    x = np.arange(10 * 3, dtype=np.float32).reshape(10, 3)
    xb, mask = pipeline.padded_eval_batches(x, 4)
    assert xb.shape == (3, 4, 3) and mask.shape == (3, 4)
    assert mask.sum() == 10
    np.testing.assert_array_equal(xb.reshape(-1, 3)[mask.reshape(-1) > 0], x)
    # exact multiple: no padding
    xb2, mask2 = pipeline.padded_eval_batches(x[:8], 4)
    assert xb2.shape == (2, 4, 3) and mask2.sum() == 8
    # the old ragged-slice generator is gone: padded is the only eval API
    assert not hasattr(pipeline, "eval_batches")


def test_vectorized_evaluator_matches_per_node_loop(tiny_ds):
    """The one-jit-per-cluster evaluator reproduces the legacy per-node,
    ragged-batch evaluation exactly (same preds, same accuracy)."""
    binding = make_binding(CFG)
    setup = algo_setup("el", binding, jax.random.PRNGKey(0),
                       tiny_ds.n_nodes, 2, degree=2, local_steps=2, lr=0.05)
    models = setup.models_of(setup.state)
    evaluate = make_evaluator(binding, tiny_ds.node_cluster,
                              tiny_ds.test_x, tiny_ds.test_y, batch=5)
    accs, preds_c, labels_c, node_acc = evaluate(models)

    from repro.models import cnn as cnn_mod
    node_cluster = np.asarray(tiny_ds.node_cluster)
    for c, y in enumerate(tiny_ds.test_y):
        nodes = np.where(node_cluster == c)[0]
        per_node = []
        for i in nodes:
            p_i = jax.tree.map(lambda l: l[i], models)
            logits = cnn_mod.forward(CFG, p_i, jnp.asarray(tiny_ds.test_x[c]))
            per_node.append(np.asarray(jnp.argmax(logits, -1)))
        ref_acc = float(np.mean([(p == np.asarray(y)).mean()
                                 for p in per_node]))
        assert accs[c] == pytest.approx(ref_acc, abs=1e-12)
        np.testing.assert_array_equal(preds_c[c], per_node[0])
        np.testing.assert_array_equal(labels_c[c], np.asarray(y))
        # the per-node accuracy vector (per-tier fairness tables) agrees
        # with the per-node reference loop, at the node's global index
        for i, p in zip(nodes, per_node):
            assert node_acc[i] == pytest.approx(
                float((p == np.asarray(y)).mean()), abs=1e-12)
