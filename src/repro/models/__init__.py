from .base import CNNConfig, ModelConfig, get_config, list_archs, register  # noqa: F401
