"""Whisper-style encoder-decoder transformer backbone [arXiv:2212.04356].

Per the assignment carve-out, the mel-spectrogram + conv feature extractor is
a STUB: ``input_specs()`` hands the encoder precomputed frame embeddings
``[B, S_enc, d_model]``. This module implements the transformer itself:
bidirectional encoder, causal decoder with cross-attention, tied lm head,
prefill/decode with self- and cross-attention caches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention, layers
from .base import ModelConfig


def sinusoids(length: int, channels: int):
    t = jnp.arange(length, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-jnp.arange(channels // 2, dtype=jnp.float32)
                  * (jnp.log(10000.0) / (channels // 2 - 1)))
    ang = t * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_mha(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {"wq": layers.dense_init(ks[0], d, d, cfg.dt),
            "wk": layers.dense_init(ks[1], d, d, cfg.dt),
            "wv": layers.dense_init(ks[2], d, d, cfg.dt),
            "wo": layers.dense_init(ks[3], d, d, cfg.dt)}


def _mha(cfg: ModelConfig, p, xq, xkv, q_pos, kv_pos, causal: bool):
    b, sq, d = xq.shape
    h = cfg.n_heads
    hd = d // h
    q = (xq @ p["wq"]).reshape(b, sq, h, hd)
    k = (xkv @ p["wk"]).reshape(b, xkv.shape[1], h, hd)
    v = (xkv @ p["wv"]).reshape(b, xkv.shape[1], h, hd)
    if not causal:  # bidirectional: make every kv slot visible
        kv_pos = jnp.zeros_like(kv_pos)
        q_pos = jnp.ones_like(q_pos)
    out = attention.sdpa(q, k, v, q_pos, kv_pos)
    return out.reshape(b, sq, d).astype(xq.dtype) @ p["wo"]


def _ln(cfg, x, p):
    return layers.layer_norm(x, p["g"], p["b"], cfg.norm_eps)


def _init_ln(cfg):
    return {"g": jnp.ones((cfg.d_model,), cfg.dt),
            "b": jnp.zeros((cfg.d_model,), cfg.dt)}


def init_enc_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": _init_ln(cfg), "attn": _init_mha(k1, cfg),
            "ln2": _init_ln(cfg),
            "mlp": layers.init_gelu_mlp(k2, cfg.d_model, cfg.d_ff, cfg.dt)}


def init_dec_layer(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": _init_ln(cfg), "self_attn": _init_mha(k1, cfg),
            "ln2": _init_ln(cfg), "cross_attn": _init_mha(k2, cfg),
            "ln3": _init_ln(cfg),
            "mlp": layers.init_gelu_mlp(k3, cfg.d_model, cfg.d_ff, cfg.dt)}


def init_params(cfg: ModelConfig, key):
    ke, kd, kemb, kp = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "encoder": {
            "layers": jax.vmap(lambda k: init_enc_layer(k, cfg))(enc_keys),
            "ln_post": _init_ln(cfg),
        },
        "decoder": {
            "pos_embed": (jax.random.normal(
                kp, (cfg.max_decoder_len, cfg.d_model)) * 0.01).astype(cfg.dt),
            "layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(dec_keys),
        },
        "embed": layers.embed_init(kemb, cfg.vocab_size, cfg.d_model, cfg.dt),
        "final_norm": _init_ln(cfg),
    }


# ==========================================================================
def encode(cfg: ModelConfig, params, frames):
    """frames [B, S_enc, D] (stubbed conv features) -> [B, S_enc, D]."""
    b, s, d = frames.shape
    h = frames.astype(cfg.dt) + sinusoids(s, d).astype(cfg.dt)[None]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(h, lp):
        a = _ln(cfg, h, lp["ln1"])
        h = h + _mha(cfg, lp["attn"], a, a, pos, pos, causal=False)
        m = _ln(cfg, h, lp["ln2"])
        return h + layers.gelu_mlp(lp["mlp"], m), None

    h, _ = jax.lax.scan(body, h, params["encoder"]["layers"],
                        unroll=cfg.scan_unroll)
    return _ln(cfg, h, params["encoder"]["ln_post"])


def lm_head_weight(params):
    return params["lm_head"] if "lm_head" in params else params["embed"].T


def forward(cfg: ModelConfig, params, tokens, frames, remat: bool = False,
            apply_final_norm: bool = True):
    """Teacher-forced decode over full target. -> (features, aux=0)."""
    enc = encode(cfg, params, frames)
    b, s = tokens.shape
    h = params["embed"][tokens] + params["decoder"]["pos_embed"][None, :s]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    epos = jnp.broadcast_to(
        jnp.arange(enc.shape[1], dtype=jnp.int32)[None], enc.shape[:2])

    def body(h, lp):
        a = _ln(cfg, h, lp["ln1"])
        h = h + _mha(cfg, lp["self_attn"], a, a, pos, pos, causal=True)
        c = _ln(cfg, h, lp["ln2"])
        h = h + _mha(cfg, lp["cross_attn"], c, enc, pos, epos, causal=False)
        m = _ln(cfg, h, lp["ln3"])
        return h + layers.gelu_mlp(lp["mlp"], m), None

    body = jax.checkpoint(body, prevent_cse=False) if remat else body
    h, _ = jax.lax.scan(body, h, params["decoder"]["layers"],
                        unroll=cfg.scan_unroll)
    if apply_final_norm:
        h = _ln(cfg, h, params["final_norm"])
    return h, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params, batch, remat: bool = False):
    feats, aux = forward(cfg, params, batch["tokens"], batch["frames"],
                         remat=remat)
    from .transformer import chunked_ce
    loss, acc = chunked_ce(feats, lm_head_weight(params), batch["labels"],
                           batch["mask"].astype(jnp.float32),
                           unroll=cfg.scan_unroll)
    return loss, {"ce": loss, "aux": aux, "acc": acc}


# ==========================================================================
# serving: cross k/v precomputed once; decoder self-attn cache per layer
def init_cache(cfg: ModelConfig, params, frames, batch: int, cache_len: int):
    enc = encode(cfg, params, frames)
    d, h = cfg.d_model, cfg.n_heads

    def cross_kv(lp):
        k = (enc @ lp["cross_attn"]["wk"]).reshape(
            batch, enc.shape[1], h, d // h)
        v = (enc @ lp["cross_attn"]["wv"]).reshape(
            batch, enc.shape[1], h, d // h)
        return {"k": k, "v": v}

    cross = jax.vmap(cross_kv)(params["decoder"]["layers"])
    self_c = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape),
        {"k": jnp.zeros((batch, cache_len, h, d // h), cfg.dt),
         "v": jnp.zeros((batch, cache_len, h, d // h), cfg.dt),
         "slot_pos": jnp.full((batch, cache_len), -1, jnp.int32)})
    return {"self": self_c, "cross": cross}


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """tokens [B,1], pos [B] -> (logits [B,V], new cache)."""
    b = tokens.shape[0]
    d, nh = cfg.d_model, cfg.n_heads
    hd = d // nh
    pe = params["decoder"]["pos_embed"][
        jnp.minimum(pos, cfg.max_decoder_len - 1)]
    h = params["embed"][tokens] + pe[:, None, :]

    def body(h, xs):
        lp, sc, cc = xs
        a = _ln(cfg, h, lp["ln1"])
        q = (a @ lp["self_attn"]["wq"]).reshape(b, 1, nh, hd)
        k = (a @ lp["self_attn"]["wk"]).reshape(b, 1, nh, hd)
        v = (a @ lp["self_attn"]["wv"]).reshape(b, 1, nh, hd)
        cache_len = sc["k"].shape[1]
        slot = (pos % cache_len).astype(jnp.int32)
        onehot = jax.nn.one_hot(slot, cache_len, dtype=cfg.dt)
        ck = sc["k"] * (1 - onehot)[:, :, None, None] + onehot[:, :, None, None] * k
        cv = sc["v"] * (1 - onehot)[:, :, None, None] + onehot[:, :, None, None] * v
        sp = jnp.where(onehot.astype(bool), pos[:, None], sc["slot_pos"])
        out = attention.sdpa(q, ck, cv, pos[:, None], sp)
        h = h + out.reshape(b, 1, d).astype(h.dtype) @ lp["self_attn"]["wo"]

        c = _ln(cfg, h, lp["ln2"])
        qc = (c @ lp["cross_attn"]["wq"]).reshape(b, 1, nh, hd)
        epos = jnp.zeros((b, cc["k"].shape[1]), jnp.int32)
        out = attention.sdpa(qc, cc["k"], cc["v"],
                             jnp.ones((b, 1), jnp.int32), epos)
        h = h + out.reshape(b, 1, d).astype(h.dtype) @ lp["cross_attn"]["wo"]

        m = _ln(cfg, h, lp["ln3"])
        h = h + layers.gelu_mlp(lp["mlp"], m)
        return h, {"k": ck, "v": cv, "slot_pos": sp}

    h, new_self = jax.lax.scan(
        body, h, (params["decoder"]["layers"], cache["self"], cache["cross"]))
    feats = _ln(cfg, h, params["final_norm"])
    logits = (feats[:, 0] @ lm_head_weight(params)).astype(jnp.float32)
    return logits, {"self": new_self, "cross": cache["cross"]}
