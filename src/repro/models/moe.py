"""Mixture-of-experts FFN with capacity-based (GShard-style) dispatch.

Design notes (TPU adaptation):
  * dispatch/combine are expressed as scatter/gather into a dense
    ``[E, C, D]`` buffer; with the expert axis sharded on the ``model`` mesh
    axis and tokens sharded on ``data``, GSPMD lowers the scatter into the
    all-to-all the paper's MoE baselines would issue by hand.
  * compute cost is ``K * capacity_factor`` x the active-expert FLOPs —
    NOT ``E`` x — so the roofline "useful FLOPs" ratio stays honest for
    grok-1 (8e top-2) and deepseek-moe (64e top-6).
  * router math in fp32; aux load-balance loss per Switch Transformer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import hooks, layers
from .base import ModelConfig


def init_moe(key, cfg: ModelConfig):
    e = cfg.n_experts
    ff = cfg.moe_d_ff or cfg.d_ff
    d = cfg.d_model
    ks = jax.random.split(key, 5)

    def expert_stack(k, d_in, d_out):
        kk = jax.random.split(k, e)
        return jnp.stack([layers.dense_init(ki, d_in, d_out, cfg.dt)
                          for ki in kk])

    p = {
        "router": layers.dense_init(ks[0], d, e, jnp.float32),
        "w_gate": expert_stack(ks[1], d, ff),
        "w_up": expert_stack(ks[2], d, ff),
        "w_down": expert_stack(ks[3], ff, d),
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = layers.init_swiglu(
            ks[4], d, cfg.n_shared_experts * ff, cfg.dt)
    return p


def moe_capacity(cfg: ModelConfig, n_tokens: int,
                 capacity_factor: float | None = None) -> int:
    cf = capacity_factor if capacity_factor is not None else cfg.capacity_factor
    k = cfg.experts_per_token
    c = int(cf * n_tokens * k / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8, floor 8


def moe_forward(cfg: ModelConfig, p, x, capacity_factor: float | None = None):
    """x [B,S,D] -> (out [B,S,D], aux_loss scalar fp32).

    GShard-style GROUPED dispatch: tokens are split into G groups (G = the
    data-axis size when the sharding hooks are active, else 1) and the
    capacity rank is a cumsum WITHIN each group. A global cumsum would
    serialize the token axis and force GSPMD to replicate the [E,C,D]
    dispatch buffer on every device (measured: 21 GB/device f32 on
    grok-1-314b train_4k, EXPERIMENTS.md §Perf pair B). With groups, every
    dispatch tensor carries the group dim and shards on 'data'.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.experts_per_token

    g_n = hooks.data_axis_size()
    if t % g_n:
        g_n = 1
    tg = t // g_n                                               # tokens/group
    xt = hooks.shard_batch(x.reshape(g_n, tg, d))               # [G,Tg,D]

    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)                     # [G,Tg,E]
    topw, topi = jax.lax.top_k(probs, k)                        # [G,Tg,K]
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # ---- aux load-balance loss (Switch): E * sum_e f_e * P_e ----
    sel = jax.nn.one_hot(topi, e, dtype=jnp.float32).sum(2)     # [G,Tg,E]
    f_e = sel.mean((0, 1)) / k
    p_e = probs.mean((0, 1))
    aux = e * jnp.sum(f_e * p_e)

    # ---- dispatch: (token,k) -> [G, E, Cg, D], rank within (group,expert)
    cap = moe_capacity(cfg, tg, capacity_factor)
    eid = topi.reshape(g_n, tg * k)                             # [G,TgK]
    oh = jax.nn.one_hot(eid, e, dtype=jnp.int32)                # [G,TgK,E]
    pos = (jnp.cumsum(oh, axis=1) - oh)
    pos = (pos * oh).sum(-1)                                    # [G,TgK]
    tok = jnp.repeat(xt, k, axis=1)                             # [G,TgK,D]

    # vmap over groups: GSPMD partitions a BATCHED scatter on the group dim
    # cleanly; a leading broadcast-index scatter gets replicated (measured
    # 14x temp difference at 256 devices — EXPERIMENTS.md §Perf pair B)
    def scatter_group(tok_g, eid_g, pos_g):
        return jnp.zeros((e, cap, d), x.dtype).at[eid_g, pos_g].set(
            tok_g, mode="drop")

    buf = jax.vmap(scatter_group)(tok, eid, pos)                # [G,E,Cg,D]
    buf = hooks.shard_batch(buf)

    # ---- expert FFN (batched einsum over experts) ----
    gg = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
    uu = jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    h = jax.nn.silu(gg.astype(jnp.float32)).astype(x.dtype) * uu
    ob = jnp.einsum("gecf,efd->gecd", h, p["w_down"])           # [G,E,Cg,D]

    # ---- combine ----
    keep = (pos < cap).astype(x.dtype)                          # [G,TgK]
    pos_c = jnp.minimum(pos, cap - 1)
    back = jax.vmap(lambda ob_g, e_g, p_g: ob_g[e_g, p_g])(
        ob, eid, pos_c)                                         # [G,TgK,D]
    w_flat = topw.reshape(g_n, tg * k).astype(x.dtype) * keep
    out = (back * w_flat[..., None]).reshape(g_n, tg, k, d).sum(2)

    if "shared" in p:
        out = out + layers.swiglu(p["shared"], xt)
    return out.reshape(b, s, d), aux


def moe_forward_dense(cfg: ModelConfig, p, x):
    """Oracle: compute every expert on every token, weight by sparse gates.

    Exponentially more FLOPs; used only in tests to validate the capacity
    dispatch (with capacity_factor large enough that nothing drops, the two
    must agree to float tolerance).
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.experts_per_token
    xt = x.reshape(t, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    gates = jnp.zeros((t, e), jnp.float32)
    gates = gates.at[jnp.arange(t)[:, None], topi].set(topw)

    g = jnp.einsum("td,edf->etf", xt, p["w_gate"])
    u = jnp.einsum("td,edf->etf", xt, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("etf,efd->etd", h, p["w_down"])            # [E,T,D]
    out = jnp.einsum("te,etd->td", gates.astype(x.dtype), ye)
    if "shared" in p:
        out = out + layers.swiglu(p["shared"], xt)
    sel = jax.nn.one_hot(topi, e, dtype=jnp.float32).sum(1)
    aux = e * jnp.sum((sel.mean(0) / k) * probs.mean(0))
    return out.reshape(b, s, d), aux
