"""Uniform model API over the three backbones (decoder LM, enc-dec, CNN).

Everything downstream (FACADE trainer, launcher, dry-run) talks to models
through this module only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import cnn, transformer, whisper
from .base import CNNConfig, ModelConfig


def is_encdec(cfg) -> bool:
    return isinstance(cfg, ModelConfig) and cfg.encoder_layers > 0


def is_cnn(cfg) -> bool:
    return isinstance(cfg, CNNConfig)


def init_params(cfg, key):
    if is_cnn(cfg):
        return cnn.init_params(cfg, key)
    if is_encdec(cfg):
        return whisper.init_params(cfg, key)
    return transformer.init_params(cfg, key)


def loss_fn(cfg, params, batch, remat: bool = False):
    """-> (scalar loss, metrics dict). Works for all backbones."""
    if is_cnn(cfg):
        return cnn.loss_fn(cfg, params, batch)
    if is_encdec(cfg):
        return whisper.loss_fn(cfg, params, batch, remat=remat)
    return transformer.loss_fn(cfg, params, batch, remat=remat)


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def param_bytes(params) -> int:
    return sum(int(x.size * x.dtype.itemsize) for x in jax.tree.leaves(params))


# --------------------------------------------------------------------------
# FACADE core/head split metadata
def head_key_names(cfg) -> tuple:
    if is_cnn(cfg):
        return cnn.head_keys(cfg)
    return cfg.head_keys  # ("final_norm", "lm_head") by default


def facade_features(cfg, params, batch):
    """Core forward pass shared by all k heads (paper III-E: compute core
    activations once, feed each head)."""
    if is_cnn(cfg):
        return cnn.features(cfg, params, batch["x"])
    if is_encdec(cfg):
        raise NotImplementedError  # handled via full loss per head
    feats, aux = transformer.forward(cfg, params, batch["tokens"],
                                     img_embeds=batch.get("img_embeds"))
    return feats


def facade_head_loss(cfg, core_feats, head_params, batch):
    """Loss of one candidate head on precomputed core features."""
    if is_cnn(cfg):
        logits = cnn.head_apply(cfg, head_params, core_feats)
        from . import layers
        loss = layers.softmax_xent(logits, batch["y"])
        return loss
    # LM: head = final_norm + lm_head
    from . import layers
    feats = core_feats
    if "final_norm" in head_params:
        # core forward already applied final_norm with *core* gamma; for the
        # LM split the final_norm belongs to the head, so recompute with the
        # head's gamma. transformer.forward returns normed feats with the
        # params' own final_norm; callers pass pre-norm features instead.
        pass
    w = head_params.get("lm_head")
    if w is None:  # tied embeddings: head owns only final_norm; reuse embed
        w = batch["_tied_embed"].T
    loss, _ = transformer.chunked_ce(
        feats, w, batch["labels"], batch["mask"].astype(jnp.float32))
    return loss
