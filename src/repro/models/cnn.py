"""The paper's experimental models: GN-LeNet (CIFAR-10/Imagenette runs) and
ResNet8 (Flickr-Mammals runs), both with GroupNorm as in Hsieh et al. [41].

FACADE head split (paper Sec. V-A "Models"):
  * GN-LeNet  — head = final fully-connected layer.
  * ResNet8   — head = last two basic blocks + final FC.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .base import CNNConfig


def conv_init(key, kh, kw, cin, cout, dtype):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout)) * jnp.sqrt(2.0 / fan_in)
    return w.astype(dtype)


def conv2d(x, w, stride: int = 1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def _gn_params(c, dtype):
    return {"g": jnp.ones((c,), dtype), "b": jnp.zeros((c,), dtype)}


# ==========================================================================
# GN-LeNet
def init_lenet(cfg: CNNConfig, key):
    w = cfg.width
    ks = jax.random.split(key, 4)
    feat = (cfg.image_size // 8) ** 2 * w
    return {
        "conv1": {"w": conv_init(ks[0], 3, 3, cfg.channels, w, cfg.dt),
                  "gn": _gn_params(w, cfg.dt)},
        "conv2": {"w": conv_init(ks[1], 3, 3, w, w, cfg.dt),
                  "gn": _gn_params(w, cfg.dt)},
        "conv3": {"w": conv_init(ks[2], 3, 3, w, w, cfg.dt),
                  "gn": _gn_params(w, cfg.dt)},
        "fc": {"w": layers.dense_init(ks[3], feat, cfg.n_classes, cfg.dt),
               "b": jnp.zeros((cfg.n_classes,), cfg.dt)},
    }


def lenet_features(cfg: CNNConfig, params, x):
    """x [B,H,W,C] -> flattened conv features (the FACADE *core*)."""
    for name in ("conv1", "conv2", "conv3"):
        p = params[name]
        x = conv2d(x, p["w"])
        x = layers.group_norm(x, p["gn"]["g"], p["gn"]["b"], cfg.groups)
        x = jax.nn.relu(x)
        x = maxpool2(x)
    return x.reshape(x.shape[0], -1)


def lenet_head(cfg: CNNConfig, head_params, feats):
    return feats @ head_params["fc"]["w"] + head_params["fc"]["b"]


def lenet_forward(cfg: CNNConfig, params, x):
    return lenet_head(cfg, {"fc": params["fc"]}, lenet_features(cfg, params, x))


LENET_HEAD_KEYS = ("fc",)


# ==========================================================================
# ResNet8 (GN): stem + 3 basic blocks (16,32,64) + FC
def _init_block(key, cin, cout, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"conv1": conv_init(k1, 3, 3, cin, cout, dtype),
         "gn1": _gn_params(cout, dtype),
         "conv2": conv_init(k2, 3, 3, cout, cout, dtype),
         "gn2": _gn_params(cout, dtype)}
    if cin != cout:
        p["proj"] = conv_init(k3, 1, 1, cin, cout, dtype)
    return p


def _block(cfg: CNNConfig, p, x, stride: int):
    h = conv2d(x, p["conv1"], stride)
    h = jax.nn.relu(layers.group_norm(h, p["gn1"]["g"], p["gn1"]["b"],
                                      cfg.groups))
    h = conv2d(h, p["conv2"])
    h = layers.group_norm(h, p["gn2"]["g"], p["gn2"]["b"], cfg.groups)
    if "proj" in p:
        x = conv2d(x, p["proj"], stride)
    elif stride != 1:
        x = x[:, ::stride, ::stride]
    return jax.nn.relu(h + x)


def init_resnet8(cfg: CNNConfig, key):
    w = cfg.width // 2  # stem width 16 for width=32
    ks = jax.random.split(key, 5)
    return {
        "stem": {"w": conv_init(ks[0], 3, 3, cfg.channels, w, cfg.dt),
                 "gn": _gn_params(w, cfg.dt)},
        "block1": _init_block(ks[1], w, w, cfg.dt),
        "block2": _init_block(ks[2], w, 2 * w, cfg.dt),
        "block3": _init_block(ks[3], 2 * w, 4 * w, cfg.dt),
        "fc": {"w": layers.dense_init(ks[4], 4 * w, cfg.n_classes, cfg.dt),
               "b": jnp.zeros((cfg.n_classes,), cfg.dt)},
    }


def resnet8_features(cfg: CNNConfig, params, x):
    """Core: stem + block1 (head owns block2, block3, fc)."""
    p = params["stem"]
    x = jax.nn.relu(layers.group_norm(conv2d(x, p["w"]), p["gn"]["g"],
                                      p["gn"]["b"], cfg.groups))
    return _block(cfg, params["block1"], x, stride=1)


def resnet8_head(cfg: CNNConfig, head_params, feats):
    h = _block(cfg, head_params["block2"], feats, stride=2)
    h = _block(cfg, head_params["block3"], h, stride=2)
    h = h.mean(axis=(1, 2))
    return h @ head_params["fc"]["w"] + head_params["fc"]["b"]


def resnet8_forward(cfg: CNNConfig, params, x):
    head = {k: params[k] for k in RESNET8_HEAD_KEYS}
    return resnet8_head(cfg, head, resnet8_features(cfg, params, x))


RESNET8_HEAD_KEYS = ("block2", "block3", "fc")


# ==========================================================================
# uniform API used by the FACADE trainer
def init_params(cfg: CNNConfig, key):
    return init_lenet(cfg, key) if cfg.kind == "lenet" else init_resnet8(cfg, key)


def features(cfg: CNNConfig, params, x):
    return (lenet_features(cfg, params, x) if cfg.kind == "lenet"
            else resnet8_features(cfg, params, x))


def head_apply(cfg: CNNConfig, head_params, feats):
    return (lenet_head(cfg, head_params, feats) if cfg.kind == "lenet"
            else resnet8_head(cfg, head_params, feats))


def head_keys(cfg: CNNConfig):
    return LENET_HEAD_KEYS if cfg.kind == "lenet" else RESNET8_HEAD_KEYS


def forward(cfg: CNNConfig, params, x):
    return (lenet_forward(cfg, params, x) if cfg.kind == "lenet"
            else resnet8_forward(cfg, params, x))


def loss_fn(cfg: CNNConfig, params, batch):
    logits = forward(cfg, params, batch["x"])
    loss = layers.softmax_xent(logits, batch["y"])
    acc = (jnp.argmax(logits, -1) == batch["y"]).mean()
    return loss, {"ce": loss, "acc": acc}
