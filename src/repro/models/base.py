"""Model configuration dataclasses and the architecture registry.

Every assigned architecture is described by a single ``ModelConfig``; the
backbone in ``transformer.py`` (and ``whisper.py`` for enc-dec) interprets it.
Configs are frozen dataclasses so they can be used as static args to jit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | hybrid | ssm | vlm | audio | cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- attention variant ------------------------------------------------
    attention: str = "gqa"  # gqa | mla | none (rwkv)
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0  # 0 = full attention; >0 enables SWA variant

    # --- MLA (multi-head latent attention, MiniCPM3/DeepSeek-V2 style) ----
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # per-expert ffn width (fine-grained MoE)
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25

    # --- hybrid (hymba: parallel attention + mamba heads) -------------------
    ssm_state: int = 0
    ssm_expand: int = 1  # d_inner = ssm_expand * d_model
    ssm_conv: int = 4

    # --- rwkv6 ---------------------------------------------------------------
    rwkv: bool = False

    # --- encoder-decoder (whisper) -------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0  # number of (stubbed) audio frames
    cross_attention: bool = False
    max_decoder_len: int = 0  # whisper caps ctx at 448

    # --- vlm -----------------------------------------------------------------
    n_image_tokens: int = 0  # stubbed patch embeddings prepended to text

    # --- FACADE head split ----------------------------------------------------
    # which top-level param groups constitute the FACADE "head"
    head_keys: tuple = ("final_norm", "lm_head")

    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    # --- dry-run cost accounting -------------------------------------------
    # XLA's cost_analysis counts a while-loop body ONCE; unrolling the layer
    # scan (scan_unroll = n_layers) makes HLO_FLOPs/bytes/collectives exact.
    # Roofline dry-runs set this; training/tests keep the compact scan.
    scan_unroll: int = 1

    # ---------------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def dt(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    """Configs for the paper's own experimental models (GN-LeNet, ResNet8)."""

    name: str
    kind: str  # lenet | resnet8
    image_size: int = 32
    channels: int = 3
    n_classes: int = 10
    width: int = 32  # base conv width
    groups: int = 2  # group-norm groups
    head_blocks: int = 0  # resnet8: how many trailing blocks join the head
    dtype: str = "float32"

    @property
    def dt(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "CNNConfig":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# registry: populated by repro.configs
_REGISTRY: dict = {}


def register(arch_id: str, fn) -> None:
    _REGISTRY[arch_id] = fn


def get_config(arch_id: str, smoke: bool = False):
    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id](smoke=smoke)


def list_archs():
    return sorted(_REGISTRY)
