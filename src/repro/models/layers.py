"""Common neural-net layers as pure functions (init + apply).

Convention: params are nested dicts of jnp arrays; every ``init_*`` takes a
PRNG key and returns the param subtree; every ``apply``-style function takes
(params, inputs). Matmuls run in the param dtype (bf16 on TPU); norms,
softmax and losses accumulate in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# init helpers
def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    return w.astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    w = jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02
    return w.astype(dtype)


# --------------------------------------------------------------------------
# norms
def rms_norm(x, gamma, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return out.astype(x.dtype)


def group_norm(x, gamma, beta, groups: int, eps: float = 1e-5):
    """GroupNorm over the channel (last) axis of NHWC activations."""
    xf = x.astype(jnp.float32)
    c = x.shape[-1]
    g = xf.reshape(x.shape[:-1] + (groups, c // groups))
    mu = jnp.mean(g, axis=(-1, -2, -3, -4) if x.ndim == 4 else (-1,),
                  keepdims=True)
    # NHWC: normalize over (H, W, channels-in-group)
    if x.ndim == 4:
        mu = jnp.mean(g, axis=(1, 2, 4), keepdims=True)
        var = jnp.var(g, axis=(1, 2, 4), keepdims=True)
    else:
        mu = jnp.mean(g, axis=-1, keepdims=True)
        var = jnp.var(g, axis=-1, keepdims=True)
    g = (g - mu) * jax.lax.rsqrt(var + eps)
    out = g.reshape(x.shape)
    out = out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings
def rope_freqs(positions, dim: int, theta: float):
    """cos/sin tables for given integer positions. positions [...,S]."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv  # [..., S, dim/2]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., S, H, D]; cos/sin [..., S, D/2] broadcast over heads."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    xf1 = x1.astype(jnp.float32)
    xf2 = x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs
def init_swiglu(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu(params, x):
    g = x @ params["w_gate"]
    u = x @ params["w_up"]
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return h @ params["w_down"]


def init_gelu_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w_in": dense_init(k1, d_model, d_ff, dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(k2, d_ff, d_model, dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params, x):
    h = x @ params["w_in"] + params["b_in"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return h @ params["w_out"] + params["b_out"]


# --------------------------------------------------------------------------
# losses
def chunked_softmax_xent(logits_fn, features, w_head, labels, mask,
                         chunk: int = 2048):
    """Cross-entropy over a huge vocab without materializing all logits twice.

    features [B,S,D] (fp any), w_head [D,V]; labels [B,S]; mask [B,S] float.
    Computes logits in fp32 via one matmul but reduces immediately; for
    memory-constrained cases the Pallas head_select kernel does true
    vocab-chunked CE. Returns mean loss over masked tokens.
    """
    del logits_fn, chunk
    logits = (features.astype(jnp.float32) @ w_head.astype(jnp.float32))
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def softmax_xent(logits, labels, mask=None):
    """Standard CE; logits [..., V] fp-any, labels int, mask float."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return nll.mean()
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
