"""The decoder backbone: dense / MoE / hybrid(attn+mamba) / rwkv / VLM,
all driven by ``ModelConfig``.

API (all pure functions):
    init_params(cfg, key)                         -> params pytree
    forward(cfg, params, tokens, img_embeds=None) -> (features, aux)
    loss_fn(cfg, params, batch)                   -> (loss, metrics)
    init_cache(cfg, batch, cache_len)             -> cache pytree (leading L)
    prefill(cfg, params, tokens, ...)             -> (last_logits, cache)
    decode_step(cfg, params, cache, tokens, pos)  -> (logits, cache)

Layers are *stacked* (leading L axis) and traversed with ``lax.scan`` so that
a 64-layer model compiles as one loop — essential for the 512-device dry-run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import attention, hooks, layers, moe, rwkv, ssm
from .base import ModelConfig


# ==========================================================================
# init
def init_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    p = {"norm1": jnp.ones((cfg.d_model,), cfg.dt),
         "norm2": jnp.ones((cfg.d_model,), cfg.dt)}
    if cfg.rwkv:
        p["time_mix"] = rwkv.init_time_mix(ks[0], cfg)
        p["channel_mix"] = rwkv.init_channel_mix(ks[1], cfg)
        return p
    if cfg.attention == "mla":
        p["attn"] = attention.init_mla(ks[0], cfg)
    else:
        p["attn"] = attention.init_gqa(ks[0], cfg)
    if cfg.arch_type == "hybrid":
        p["ssm"] = ssm.init_ssm(ks[1], cfg)
        p["branch_norm_attn"] = jnp.ones((cfg.d_model,), cfg.dt)
        p["branch_norm_ssm"] = jnp.ones((cfg.d_model,), cfg.dt)
    if cfg.is_moe:
        p["moe"] = moe.init_moe(ks[2], cfg)
    else:
        p["mlp"] = layers.init_swiglu(ks[2], cfg.d_model, cfg.d_ff, cfg.dt)
    return p


def init_params(cfg: ModelConfig, key):
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    params = {
        "embed": layers.embed_init(k_emb, cfg.vocab_size, cfg.d_model, cfg.dt),
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), cfg.dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(
            k_head, cfg.d_model, cfg.vocab_size, cfg.dt, scale=0.02)
    return params


def lm_head_weight(cfg: ModelConfig, params):
    if "lm_head" in params:  # explicit head (incl. FACADE-untied variants)
        return params["lm_head"]
    return params["embed"].T  # tied embeddings


# ==========================================================================
# blocks
def block_forward(cfg: ModelConfig, lp, h, positions, attn_fn=None,
                  force_window: int = 0):
    """One layer, full sequence. Returns (h, aux)."""
    aux = jnp.zeros((), jnp.float32)
    window = force_window or cfg.sliding_window
    if cfg.rwkv:
        a = layers.rms_norm(h, lp["norm1"], cfg.norm_eps)
        tm, _, _ = rwkv.time_mix(cfg, lp["time_mix"], a)
        h = h + tm
        m = layers.rms_norm(h, lp["norm2"], cfg.norm_eps)
        cm, _ = rwkv.channel_mix(cfg, lp["channel_mix"], m)
        return h + cm, aux

    a = layers.rms_norm(h, lp["norm1"], cfg.norm_eps)
    if cfg.attention == "mla":
        attn_out = attention.mla_forward(cfg, lp["attn"], a, positions,
                                         window=window)
    else:
        attn_out = attention.gqa_forward(cfg, lp["attn"], a, positions,
                                         window=window, attn_fn=attn_fn)
    if cfg.arch_type == "hybrid":
        ssm_out = ssm.ssm_forward(cfg, lp["ssm"], a)
        attn_out = 0.5 * (
            layers.rms_norm(attn_out, lp["branch_norm_attn"], cfg.norm_eps)
            + layers.rms_norm(ssm_out, lp["branch_norm_ssm"], cfg.norm_eps))
    h = h + attn_out

    m = layers.rms_norm(h, lp["norm2"], cfg.norm_eps)
    if cfg.is_moe:
        mo, a_loss = moe.moe_forward(cfg, lp["moe"], m)
        aux = aux + a_loss
        h = h + mo
    else:
        h = h + layers.swiglu(lp["mlp"], m)
    return h, aux


# ==========================================================================
# full-sequence forward
def embed_inputs(cfg: ModelConfig, params, tokens, img_embeds=None):
    x = params["embed"][tokens]
    if img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(x.dtype), x], axis=1)
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
    return x, positions


def forward(cfg: ModelConfig, params, tokens, img_embeds=None,
            remat: bool = False, attn_fn=None, apply_final_norm: bool = True):
    """-> (features [B,S,D], aux). S includes image tokens for VLMs.
    ``apply_final_norm=False`` returns pre-norm features (the FACADE core
    output; the per-cluster head owns the final norm)."""
    h, positions = embed_inputs(cfg, params, tokens, img_embeds)

    def body(carry, lp):
        h, aux = carry
        h = hooks.shard_batch(h)
        h, a = block_forward(cfg, lp, h, positions, attn_fn=attn_fn)
        return (h, aux + a), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                               params["layers"], unroll=cfg.scan_unroll)
    if apply_final_norm:
        h = layers.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, aux


# ==========================================================================
# loss (sequence-chunked CE so [B,S,V] fp32 logits never materialize)
def chunked_ce(features, w_head, labels, mask, chunk: int = 512,
               unroll: int = 1):
    """features [B,S,D]; labels/mask [B,S]. Mean NLL over masked tokens,
    plus accuracy. Chunks the sequence axis; each chunk is rematerialized in
    the backward pass (jax.checkpoint) so logit residuals never exceed
    [B,chunk,V]."""
    b, s, d = features.shape
    n_chunks = max(1, s // chunk)
    chunk = s // n_chunks if s % n_chunks == 0 else s  # fallback: one chunk
    n_chunks = s // chunk

    fc = features.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def one(f, l, m):
        logits = (f @ w_head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via compare-mask reduction, NOT take_along_axis: a
        # gather on the (model-sharded) vocab dim makes GSPMD all-gather
        # full [B,chunk,V] logits; the masked sum partitions cleanly.
        onehot = l[..., None] == jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1)
        gold = jnp.where(onehot, logits, 0.0).sum(axis=-1)
        correct = (jnp.max(logits, axis=-1) <= gold).astype(jnp.float32)
        return ((lse - gold) * m).sum(), (correct * m).sum()

    def body(carry, xs):
        nll, acc = carry
        f, l, m = xs
        dn, da = one(f, l, m)
        return (nll + dn, acc + da), None

    (nll, acc), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (fc, lc, mc), unroll=unroll)
    denom = jnp.maximum(mask.sum(), 1.0)
    return nll / denom, acc / denom


def loss_fn(cfg: ModelConfig, params, batch, remat: bool = False,
            attn_fn=None):
    """batch: {tokens [B,S], labels [B,S], mask [B,S], img_embeds?}."""
    feats, aux = forward(cfg, params, batch["tokens"],
                         img_embeds=batch.get("img_embeds"),
                         remat=remat, attn_fn=attn_fn)
    n_img = 0 if batch.get("img_embeds") is None else batch["img_embeds"].shape[1]
    feats = feats[:, n_img:]
    loss, acc = chunked_ce(feats, lm_head_weight(cfg, params),
                           batch["labels"], batch["mask"].astype(jnp.float32),
                           unroll=cfg.scan_unroll)
    total = loss + cfg.router_aux_coef * aux
    return total, {"ce": loss, "aux": aux, "acc": acc}


# ==========================================================================
# caches
def _layer_cache(cfg: ModelConfig, batch: int, cache_len: int):
    if cfg.rwkv:
        return rwkv.rwkv_init_cache(cfg, batch)
    if cfg.attention == "mla":
        c = attention.mla_init_cache(cfg, batch, cache_len)
    else:
        c = attention.gqa_init_cache(cfg, batch, cache_len)
    if cfg.arch_type == "hybrid":
        c = {"attn": c, "ssm": ssm.ssm_init_cache(cfg, batch)}
    return c


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    one = _layer_cache(cfg, batch, cache_len)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one)


def extend_cache(cfg: ModelConfig, caches, extra: int):
    """Append ``extra`` empty slots to a prefilled cache so subsequent
    decode steps have somewhere to write. No-op for ring-buffer (sliding
    window) caches, where wraparound eviction is the semantics, and for
    state-only (rwkv) caches."""
    if extra <= 0 or cfg.rwkv:
        return caches

    def pad(leaf, slot_axis, fill):
        pads = [(0, 0)] * leaf.ndim
        pads[slot_axis] = (0, extra)
        return jnp.pad(leaf, pads, constant_values=fill)

    def pad_attn(c):
        if cfg.sliding_window and c["slot_pos"].shape[-1] == cfg.sliding_window:
            return c  # ring buffer: leave alone
        out = {}
        for name, leaf in c.items():
            if name == "slot_pos":
                out[name] = pad(leaf, leaf.ndim - 1, -1)
            else:
                out[name] = pad(leaf, 2, 0)  # [L,B,slots,...]
        return out

    if cfg.arch_type == "hybrid":
        return {"attn": pad_attn(caches["attn"]), "ssm": caches["ssm"]}
    return pad_attn(caches)


def cache_physical_len(cfg: ModelConfig, seq_len: int) -> int:
    """Sliding-window archs store the ring-buffer window as the physical
    cache (production SWA representation); others store seq_len slots."""
    if cfg.rwkv:
        return 1  # state-only; attn cache unused
    if cfg.sliding_window and seq_len > cfg.sliding_window:
        return cfg.sliding_window
    return seq_len


# ==========================================================================
# decode
def block_decode(cfg: ModelConfig, lp, h, pos, cache):
    window = cfg.sliding_window
    if cfg.rwkv:
        a = layers.rms_norm(h, lp["norm1"], cfg.norm_eps)
        tm, s_new, tmx = rwkv.time_mix(cfg, lp["time_mix"], a,
                                       state=cache["s"], last_x=cache["tm_x"])
        h = h + tm
        m = layers.rms_norm(h, lp["norm2"], cfg.norm_eps)
        cm, cmx = rwkv.channel_mix(cfg, lp["channel_mix"], m,
                                   last_x=cache["cm_x"])
        return h + cm, {"s": s_new, "tm_x": tmx, "cm_x": cmx}

    a = layers.rms_norm(h, lp["norm1"], cfg.norm_eps)
    attn_cache = cache["attn"] if cfg.arch_type == "hybrid" else cache
    if cfg.attention == "mla":
        attn_out, new_attn = attention.mla_decode(cfg, lp["attn"], a, pos,
                                                  attn_cache, window=window)
    else:
        attn_out, new_attn = attention.gqa_decode(cfg, lp["attn"], a, pos,
                                                  attn_cache, window=window)
    if cfg.arch_type == "hybrid":
        ssm_out, new_ssm = ssm.ssm_decode(cfg, lp["ssm"], a, cache["ssm"])
        attn_out = 0.5 * (
            layers.rms_norm(attn_out, lp["branch_norm_attn"], cfg.norm_eps)
            + layers.rms_norm(ssm_out, lp["branch_norm_ssm"], cfg.norm_eps))
        new_cache = {"attn": new_attn, "ssm": new_ssm}
    else:
        new_cache = new_attn
    h = h + attn_out

    m = layers.rms_norm(h, lp["norm2"], cfg.norm_eps)
    if cfg.is_moe:
        mo, _ = moe.moe_forward(cfg, lp["moe"], m)
        h = h + mo
    else:
        h = h + layers.swiglu(lp["mlp"], m)
    return h, new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """tokens [B,1] int32; pos [B] int32 -> (logits [B,V], new cache)."""
    h = params["embed"][tokens]

    def body(h, xs):
        lp, lc = xs
        h, nc = block_decode(cfg, lp, h, pos, lc)
        return h, nc

    h, new_caches = jax.lax.scan(body, h, (params["layers"], cache),
                                 unroll=cfg.scan_unroll)
    feats = layers.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = (feats[:, 0] @ lm_head_weight(cfg, params)).astype(jnp.float32)
    return logits, new_caches


# ==========================================================================
# prefill: full forward that also materializes the decode cache
def prefill(cfg: ModelConfig, params, tokens, img_embeds=None,
            cache_extra: int = 0):
    """-> (last-token logits [B,V], cache ready for decode at pos=S).
    ``cache_extra`` reserves empty slots for tokens generated afterwards."""
    h, positions = embed_inputs(cfg, params, tokens, img_embeds)
    b, s = h.shape[:2]
    cache_len = cache_physical_len(cfg, s)

    def body(h, lp):
        h = hooks.shard_batch(h)
        a = layers.rms_norm(h, lp["norm1"], cfg.norm_eps)
        if cfg.rwkv:
            tm, s_new, tmx = rwkv.time_mix(cfg, lp["time_mix"], a)
            h = h + tm
            m = layers.rms_norm(h, lp["norm2"], cfg.norm_eps)
            cm, cmx = rwkv.channel_mix(cfg, lp["channel_mix"], m)
            return h + cm, {"s": s_new, "tm_x": tmx, "cm_x": cmx}

        window = cfg.sliding_window
        if cfg.attention == "mla":
            c_kv, k_rope = attention._mla_ckv(cfg, lp["attn"], a, positions)
            attn_out = attention.mla_forward(cfg, lp["attn"], a, positions,
                                             window=window)
            kv = {"c_kv": c_kv, "k_rope": k_rope}
        else:
            q, k, v = attention._gqa_qkv(cfg, lp["attn"], a, positions)
            attn_out = attention.sdpa_auto(q, k, v, positions, positions,
                                           window=window,
                                           unroll=cfg.scan_unroll)
            attn_out = (attn_out.reshape(b, s, -1).astype(h.dtype)
                        @ lp["attn"]["wo"])
            kv = {"k": k, "v": v}

        # ring-buffer placement: slot j holds position start + ((j-start)%W)
        start = s - cache_len
        slots = jnp.arange(cache_len, dtype=jnp.int32)
        src = start + ((slots - start) % cache_len)
        kv = jax.tree.map(lambda a_: a_[:, src], kv)
        kv["slot_pos"] = jnp.broadcast_to(src[None], (b, cache_len))

        if cfg.arch_type == "hybrid":
            ssm_out = ssm.ssm_forward(cfg, lp["ssm"], a)
            # re-run scan pieces to extract final ssm state
            u, _ = jnp.split(a @ lp["ssm"]["w_in"], 2, axis=-1)
            uc, _ = ssm._conv_causal(lp["ssm"], u)
            uc = jax.nn.silu(uc.astype(jnp.float32)).astype(a.dtype)
            _, h_ssm = ssm.ssm_scan(cfg, lp["ssm"], uc)
            conv_tail = jnp.concatenate(
                [jnp.zeros((b, cfg.ssm_conv - 1, u.shape[-1]), u.dtype),
                 u], axis=1)[:, -(cfg.ssm_conv - 1):]
            attn_out = 0.5 * (
                layers.rms_norm(attn_out, lp["branch_norm_attn"], cfg.norm_eps)
                + layers.rms_norm(ssm_out, lp["branch_norm_ssm"], cfg.norm_eps))
            cache_l = {"attn": kv, "ssm": {"h": h_ssm, "conv": conv_tail}}
        else:
            cache_l = kv
        h = h + attn_out

        m = layers.rms_norm(h, lp["norm2"], cfg.norm_eps)
        if cfg.is_moe:
            mo, _ = moe.moe_forward(cfg, lp["moe"], m)
            h = h + mo
        else:
            h = h + layers.swiglu(lp["mlp"], m)
        return h, cache_l

    h, caches = jax.lax.scan(body, h, params["layers"],
                             unroll=cfg.scan_unroll)
    caches = extend_cache(cfg, caches, cache_extra)
    feats = layers.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = (feats[:, -1] @ lm_head_weight(cfg, params)).astype(jnp.float32)
    return logits, caches
