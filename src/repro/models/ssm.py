"""Selective SSM (Mamba-style) branch used by the hymba hybrid architecture.

Hymba [arXiv:2411.13676] runs attention heads and mamba heads *in parallel*
within each layer and fuses their (per-branch normalized) outputs. This
module implements the mamba branch:

    x -> in_proj -> (u, z); u -> causal depthwise conv -> silu
    dt, B, C = proj(u);  h_t = exp(A*dt_t) . h_{t-1} + dt_t * (B_t  u_t)
    y_t = (h_t C_t) + D . u_t;  out = (y * silu(z)) @ out_proj

State is [B, d_inner, N] (N = ssm_state), carried by ``lax.scan`` during
training/prefill and as an O(1) cache during decode — which is what makes
hymba runnable at the 500k-token decode shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .base import ModelConfig


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def init_ssm(key, cfg: ModelConfig):
    di, n = d_inner(cfg), cfg.ssm_state
    ks = jax.random.split(key, 6)
    dt_rank = max(1, cfg.d_model // 16)
    p = {
        "w_in": layers.dense_init(ks[0], cfg.d_model, 2 * di, cfg.dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di)) * 0.1
                   ).astype(cfg.dt),
        "w_xproj": layers.dense_init(ks[2], di, dt_rank + 2 * n, cfg.dt),
        "w_dt": layers.dense_init(ks[3], dt_rank, di, cfg.dt),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        # A stored as log of negated continuous-time decay
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": layers.dense_init(ks[4], di, cfg.d_model, cfg.dt),
    }
    return p


def _dbc(cfg: ModelConfig, p, u):
    """u [..., di] -> dt [..., di], b [..., N], c [..., N] (all fp32)."""
    n = cfg.ssm_state
    dt_rank = p["w_dt"].shape[0]
    proj = (u @ p["w_xproj"]).astype(jnp.float32)
    dt_r, b, c = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["w_dt"].astype(jnp.float32) + p["dt_bias"])
    return dt, b, c


def _conv_causal(p, u, conv_cache=None):
    """Depthwise causal conv over time. u [B,S,di]."""
    kw = p["conv_w"].shape[0]
    if conv_cache is not None:  # decode: cache holds last kw-1 inputs
        window = jnp.concatenate([conv_cache, u], axis=1)  # [B,kw,di]
        out = jnp.einsum("bkd,kd->bd", window, p["conv_w"])[:, None, :]
        return out, window[:, 1:]
    pad = jnp.zeros(u.shape[:1] + (kw - 1,) + u.shape[2:], u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    idx = jnp.arange(u.shape[1])[:, None] + jnp.arange(kw)[None, :]
    win = up[:, idx]  # [B,S,kw,di]
    return jnp.einsum("bskd,kd->bsd", win, p["conv_w"]), None


SSM_CHUNK = 512  # remat granularity of the selective scan


def _scan_chunk(cfg: ModelConfig, p, h0, u_chunk):
    """One rematerialized chunk: recomputes dt/B/C and the [B,s,di,N]
    discretized tensors inside, so the backward pass never stores them for
    the whole sequence — only the per-chunk boundary state h [B,di,N]."""
    a = -jnp.exp(p["a_log"])  # [di,N]
    dt, bb, cc = _dbc(cfg, p, u_chunk)
    uf = u_chunk.astype(jnp.float32)
    da = jnp.exp(dt[..., None] * a)                          # [B,s,di,N]
    dbu = dt[..., None] * bb[:, :, None, :] * uf[..., None]  # [B,s,di,N]

    def step(h, inp):
        da_t, dbu_t, c_t = inp
        h = da_t * h + dbu_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    hf, ys = jax.lax.scan(
        step, h0,
        (da.transpose(1, 0, 2, 3), dbu.transpose(1, 0, 2, 3),
         cc.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2) + uf * p["d_skip"]
    return hf, y


def ssm_scan(cfg: ModelConfig, p, u, h0=None, chunk: int = SSM_CHUNK):
    """Selective scan. u [B,S,di] -> (y [B,S,di], h_final [B,di,N]).

    The sequence is processed in rematerialized chunks (jax.checkpoint):
    backward memory is O(S/chunk boundary states + one chunk's
    intermediates) instead of O(S) discretized [B,S,di,N] tensors —
    measured on hymba-1.5b train_4k in EXPERIMENTS.md §Perf fleet notes.
    """
    b, s, di = u.shape
    n = cfg.ssm_state
    h0 = jnp.zeros((b, di, n), jnp.float32) if h0 is None else h0
    if s % chunk or s <= chunk:
        hf, y = _scan_chunk(cfg, p, h0, u)
        return y.astype(u.dtype), hf

    nc = s // chunk
    uc = u.reshape(b, nc, chunk, di).transpose(1, 0, 2, 3)  # [nc,B,c,di]

    @jax.checkpoint
    def body(h, u_c):
        hf, y = _scan_chunk(cfg, p, h, u_c)
        return hf, y

    hf, ys = jax.lax.scan(body, h0, uc)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)
    return y.astype(u.dtype), hf


def ssm_forward(cfg: ModelConfig, p, x):
    """Full-sequence mamba branch. x [B,S,D] -> [B,S,D]."""
    u, z = jnp.split(x @ p["w_in"], 2, axis=-1)
    u, _ = _conv_causal(p, u)
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)
    y, _ = ssm_scan(cfg, p, u)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return y @ p["w_out"]


def ssm_init_cache(cfg: ModelConfig, batch: int):
    di, n = d_inner(cfg), cfg.ssm_state
    return {
        "h": jnp.zeros((batch, di, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), cfg.dt),
    }


def ssm_decode(cfg: ModelConfig, p, x, cache):
    """One-token step. x [B,1,D]."""
    u, z = jnp.split(x @ p["w_in"], 2, axis=-1)
    u, conv = _conv_causal(p, u, conv_cache=cache["conv"])
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)

    dt, bb, cc = _dbc(cfg, p, u[:, 0])
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt[..., None] * a)
    uf = u[:, 0].astype(jnp.float32)
    h = da * cache["h"] + dt[..., None] * bb[:, None, :] * uf[..., None]
    y = jnp.einsum("bdn,bn->bd", h, cc) + uf * p["d_skip"]
    y = y.astype(x.dtype)[:, None, :]
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return y @ p["w_out"], {"h": h, "conv": conv}
