"""Attention variants: GQA (opt. qk-norm, sliding window), MLA, KV caches.

Two execution paths per variant:
  * ``*_forward``  — train / prefill over a full sequence (causal).
  * ``*_decode``   — one new token against a KV cache (full or ring-buffer).

Masking is position-based everywhere: a kv slot participates iff
``kv_pos >= 0  and  kv_pos <= q_pos  and (window == 0 or q_pos - kv_pos < window)``
which uniformly covers causal masks, cache validity and sliding windows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import hooks, layers
from .base import ModelConfig

NEG_INF = -1e30

# full-sequence attention switches to the q-chunked path when the score
# tensor Sq*Skv would exceed this (elements, per batch*head) — the pure-jnp
# analogue of the Pallas flash kernel's blocking (kernels/flash_attention)
CHUNK_THRESHOLD = 4096 * 4096
CHUNK_Q = 4096


# ==========================================================================
# scaled dot-product attention with position masking
def sdpa(q, k, v, q_pos, kv_pos, window: int = 0, scale: float | None = None):
    """q [B,Sq,Hq,Dq]  k [B,Skv,Hkv,Dq]  v [B,Skv,Hkv,Dv]
    q_pos [B,Sq] int, kv_pos [B,Skv] int (-1 = invalid slot).
    Returns [B,Sq,Hq,Dv]. Softmax in fp32.

    GQA is handled by broadcasting k/v up to Hq heads (a cheap view next to
    the O(S^2) score tensor): the score tensor then carries the FULL q-head
    axis, which — unlike the kv-head axis (often < mesh model size) — the
    sharding hooks can pin to the model axis. This matches the Pallas flash
    kernel's grid (one q head per cell, kv head = h // group)."""
    b, sq, hq, dq = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else (1.0 / jnp.sqrt(dq))
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)

    # scores [B, Hq, Sq, Skv]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores.astype(jnp.float32) * scale
    scores = hooks.shard_heads(scores, batch_dim=0, head_dim=1, seq_dim=2)

    valid = (kv_pos[:, None, :] >= 0) & (kv_pos[:, None, :] <= q_pos[:, :, None])
    if window > 0:
        valid &= (q_pos[:, :, None] - kv_pos[:, None, :]) < window
    scores = jnp.where(valid[:, None, :, :], scores, NEG_INF)

    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)
    return out


def chunked_sdpa(q, k, v, q_pos, kv_pos, window: int = 0,
                 scale: float | None = None, block_q: int = CHUNK_Q,
                 unroll: int = 1):
    """sdpa computed in q-blocks (sequential scan): the score tensor is
    [B, bq, H, Skv] per step instead of [B, Sq, H, Skv] — how the TPU flash
    kernel bounds VMEM, expressed in pure jnp so it lowers everywhere.
    ``unroll`` mirrors cfg.scan_unroll for exact dry-run cost accounting."""
    b, sq, hq, d = q.shape
    bq = min(block_q, sq)
    if sq % bq:
        return sdpa(q, k, v, q_pos, kv_pos, window=window, scale=scale)
    nq = sq // bq

    qb = q.reshape(b, nq, bq, hq, d).transpose(1, 0, 2, 3, 4)
    pb = q_pos.reshape(b, nq, bq).transpose(1, 0, 2)

    def blk(_, inp):
        qi, qpi = inp
        return None, sdpa(qi, k, v, qpi, kv_pos, window=window, scale=scale)

    _, ob = jax.lax.scan(blk, None, (qb, pb),
                         unroll=min(unroll, nq) if unroll > 1 else 1)
    return ob.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, -1)


def sdpa_auto(q, k, v, q_pos, kv_pos, window: int = 0,
              scale: float | None = None, unroll: int = 1):
    """Pick direct vs q-chunked attention by score-tensor size."""
    if q.shape[1] * k.shape[1] > CHUNK_THRESHOLD:
        return chunked_sdpa(q, k, v, q_pos, kv_pos, window=window,
                            scale=scale, unroll=unroll)
    return sdpa(q, k, v, q_pos, kv_pos, window=window, scale=scale)


# ==========================================================================
# GQA
def init_gqa(key, cfg: ModelConfig):
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, cfg.dt),
        "wk": layers.dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, cfg.dt),
        "wv": layers.dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, cfg.dt),
        "wo": layers.dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, cfg.dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.dt)
        p["k_norm"] = jnp.ones((hd,), cfg.dt)
    return p


def _gqa_qkv(cfg: ModelConfig, p, x, positions):
    b, s, _ = x.shape
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = layers.rope_freqs(positions, hd, cfg.rope_theta)
    q = layers.apply_rope(q, cos, sin)
    k = layers.apply_rope(k, cos, sin)
    # q may fall back to sequence sharding; k/v must not (their seq axis is
    # the softmax contraction) — they stay replicated if heads don't divide
    return (hooks.shard_heads(q, seq_dim=1), hooks.shard_heads(k),
            hooks.shard_heads(v))


def gqa_forward(cfg: ModelConfig, p, x, positions, window: int = 0,
                attn_fn=None):
    """Causal self-attention over a full sequence. positions [B,S]."""
    q, k, v = _gqa_qkv(cfg, p, x, positions)
    if attn_fn is not None:
        out = attn_fn(q, k, v, positions, window)
    else:
        out = sdpa_auto(q, k, v, positions, positions, window=window,
                        unroll=cfg.scan_unroll)
    b, s = x.shape[:2]
    out = hooks.shard_batch(out)
    return out.reshape(b, s, -1).astype(x.dtype) @ p["wo"]


def gqa_init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    hd = cfg.hd
    return {
        "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), cfg.dt),
        "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), cfg.dt),
        "slot_pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def gqa_decode(cfg: ModelConfig, p, x, pos, cache, window: int = 0):
    """One-token decode. x [B,1,D]; pos [B] int32 absolute position.

    Works for both a full-length cache (cache_len >= pos) and a ring buffer
    (cache_len == window): the write slot is ``pos % cache_len``.
    """
    b = x.shape[0]
    q, k, v = _gqa_qkv(cfg, p, x, pos[:, None])
    cache_len = cache["k"].shape[1]
    slot = (pos % cache_len).astype(jnp.int32)

    onehot = jax.nn.one_hot(slot, cache_len, dtype=cfg.dt)  # [B, L]
    ck = cache["k"] * (1 - onehot)[:, :, None, None] + onehot[:, :, None, None] * k
    cv = cache["v"] * (1 - onehot)[:, :, None, None] + onehot[:, :, None, None] * v
    sp = jnp.where(onehot.astype(bool), pos[:, None], cache["slot_pos"])

    out = sdpa(q, ck, cv, pos[:, None], sp, window=window)
    y = out.reshape(b, 1, -1).astype(x.dtype) @ p["wo"]
    return y, {"k": ck, "v": cv, "slot_pos": sp}


# ==========================================================================
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2 family)
def init_mla(key, cfg: ModelConfig):
    ks = jax.random.split(key, 7)
    h = cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    p = {
        "w_dq": layers.dense_init(ks[0], cfg.d_model, cfg.q_lora_rank, cfg.dt),
        "q_norm": jnp.ones((cfg.q_lora_rank,), cfg.dt),
        "w_uq": layers.dense_init(ks[1], cfg.q_lora_rank, h * qd, cfg.dt),
        # joint compression: [kv_rank | rope_dim]
        "w_dkv": layers.dense_init(ks[2], cfg.d_model,
                                   cfg.kv_lora_rank + cfg.qk_rope_dim, cfg.dt),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), cfg.dt),
        "w_uk": layers.dense_init(ks[3], cfg.kv_lora_rank,
                                  h * cfg.qk_nope_dim, cfg.dt),
        "w_uv": layers.dense_init(ks[4], cfg.kv_lora_rank,
                                  h * cfg.v_head_dim, cfg.dt),
        "wo": layers.dense_init(ks[5], h * cfg.v_head_dim, cfg.d_model, cfg.dt),
    }
    return p


def _mla_q(cfg: ModelConfig, p, x, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = layers.rms_norm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(b, s, h, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    cos, sin = layers.rope_freqs(positions, cfg.qk_rope_dim, cfg.rope_theta)
    q_rope = layers.apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _mla_ckv(cfg: ModelConfig, p, x, positions):
    ckv = x @ p["w_dkv"]
    c_kv, k_rope = jnp.split(ckv, [cfg.kv_lora_rank], axis=-1)
    c_kv = layers.rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    cos, sin = layers.rope_freqs(positions, cfg.qk_rope_dim, cfg.rope_theta)
    k_rope = layers.apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return c_kv, k_rope


def mla_forward(cfg: ModelConfig, p, x, positions, window: int = 0):
    """Train/prefill MLA: decompress k/v, run standard attention."""
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    c_kv, k_rope = _mla_ckv(cfg, p, x, positions)

    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, cfg.qk_nope_dim)
    v = (c_kv @ p["w_uv"]).reshape(b, s, h, cfg.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, h, cfg.qk_rope_dim))], axis=-1)
    q = hooks.shard_heads(q, seq_dim=1)
    k, v = hooks.shard_heads(k), hooks.shard_heads(v)
    out = sdpa_auto(q, k, v, positions, positions, window=window,
                    unroll=cfg.scan_unroll)
    out = hooks.shard_batch(out)
    return out.reshape(b, s, -1).astype(x.dtype) @ p["wo"]


def mla_init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    return {
        "c_kv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), cfg.dt),
        "k_rope": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), cfg.dt),
        "slot_pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def mla_decode(cfg: ModelConfig, p, x, pos, cache, window: int = 0):
    """Absorbed one-token MLA decode: attention runs in the compressed space.

    score_h = q_nope_h Wuk_h^T c_kv^T + q_rope · k_rope
    out_h   = (alpha_h @ c_kv) Wuv_h
    The cache never stores per-head k/v — that is MLA's memory saving.
    """
    b = x.shape[0]
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(cfg, p, x, pos[:, None])      # [B,1,H,*]
    c_new, r_new = _mla_ckv(cfg, p, x, pos[:, None])      # [B,1,rank],[B,1,rd]

    cache_len = cache["c_kv"].shape[1]
    slot = (pos % cache_len).astype(jnp.int32)
    onehot = jax.nn.one_hot(slot, cache_len, dtype=cfg.dt)
    c_kv = cache["c_kv"] * (1 - onehot)[..., None] + onehot[..., None] * c_new
    k_rope = cache["k_rope"] * (1 - onehot)[..., None] + onehot[..., None] * r_new
    sp = jnp.where(onehot.astype(bool), pos[:, None], cache["slot_pos"])

    wuk = p["w_uk"].reshape(cfg.kv_lora_rank, h, cfg.qk_nope_dim)
    # absorb: q_abs [B,H,rank]
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wuk)
    scores = jnp.einsum("bhr,bsr->bhs", q_abs, c_kv,
                        preferred_element_type=jnp.float32)
    scores += jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], k_rope,
                         preferred_element_type=jnp.float32)
    scores = scores.astype(jnp.float32) / jnp.sqrt(
        cfg.qk_nope_dim + cfg.qk_rope_dim)

    valid = (sp >= 0) & (sp <= pos[:, None])
    if window > 0:
        valid &= (pos[:, None] - sp) < window
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    alpha = jax.nn.softmax(scores, axis=-1).astype(cfg.dt)

    out_c = jnp.einsum("bhs,bsr->bhr", alpha, c_kv)
    wuv = p["w_uv"].reshape(cfg.kv_lora_rank, h, cfg.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", out_c, wuv).reshape(b, 1, -1)
    y = out.astype(x.dtype) @ p["wo"]
    return y, {"c_kv": c_kv, "k_rope": k_rope, "slot_pos": sp}
