"""Activation-sharding hooks.

Model code is mesh-agnostic; launchers opt in by installing axis names here
(before tracing). Each hook is a no-op unless axes are installed AND the
dimension divides — so tests/smoke runs on 1 CPU device are untouched.

GSPMD propagates input shardings, but without anchors it may re-shard
intermediates badly (we measured fully-replicated batch dims on the residual
stream — see EXPERIMENTS.md §Perf iteration 1). These constraints pin:
  * the residual stream batch dim to the data axes,
  * attention head dims to the model axis.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

_BATCH_AXES: tuple | None = None
_MODEL_AXIS: str | None = None
_SEQ_MODEL: bool = False


def set_activation_sharding(batch_axes, model_axis=None,
                            seq_model: bool = False) -> None:
    """``seq_model=True`` additionally shards dim 1 (sequence) of the
    residual stream on the model axis — Megatron-style sequence
    parallelism for the SAVED activations. The per-layer matmuls gather
    what they need; the layer-boundary carry (what scan/remat stores for
    the backward pass) stays 1/model-size per device."""
    global _BATCH_AXES, _MODEL_AXIS, _SEQ_MODEL
    _BATCH_AXES = tuple(batch_axes) if batch_axes else None
    _MODEL_AXIS = model_axis
    _SEQ_MODEL = seq_model


def clear() -> None:
    set_activation_sharding(None, None)


def data_axis_size() -> int:
    """Trace-time size of the data axes (1 when hooks are inactive) —
    used by the MoE grouped dispatch to pick its group count."""
    if _BATCH_AXES is None:
        return 1
    m = _mesh()
    return 1 if m is None else _axis_size(m, _BATCH_AXES)


def _axis_size(mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= dict(zip(mesh.axis_names, mesh.axis_sizes)).get(a, 1)
    return n


def _mesh():
    m = jax.sharding.get_abstract_mesh()
    return m if m is not None and m.axis_names else None


def shard_batch(x, batch_dim: int = 0):
    """Constrain x's batch dim onto the data axes (replicated elsewhere;
    with seq_model also dim batch_dim+1 onto the model axis)."""
    if _BATCH_AXES is None:
        return x
    m = _mesh()
    if m is None or x.shape[batch_dim] % _axis_size(m, _BATCH_AXES):
        return x
    spec = [None] * x.ndim
    spec[batch_dim] = _BATCH_AXES if len(_BATCH_AXES) > 1 else _BATCH_AXES[0]
    if (_SEQ_MODEL and _MODEL_AXIS and x.ndim > batch_dim + 1
            and x.shape[batch_dim + 1] % _axis_size(m, _MODEL_AXIS) == 0):
        spec[batch_dim + 1] = _MODEL_AXIS
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_heads(x, batch_dim: int = 0, head_dim: int = 2,
                seq_dim: int | None = None):
    """Constrain [B, S, H, D]-shaped activations: batch->data, heads->model.

    When the head count does not divide the model axis (llava's 56 heads,
    hymba's 25 on a 16-way axis), fall back to sharding a sequence dim on
    'model' instead — sequence parallelism for the attention interior. Pass
    ``seq_dim`` to name it (e.g. the q dim of a [B, H, Sq, Skv] score
    block); softmax axes must stay unsharded."""
    if _BATCH_AXES is None and _MODEL_AXIS is None:
        return x
    m = _mesh()
    if m is None:
        return x
    spec = [None] * x.ndim
    if _BATCH_AXES and x.shape[batch_dim] % _axis_size(m, _BATCH_AXES) == 0:
        spec[batch_dim] = (_BATCH_AXES if len(_BATCH_AXES) > 1
                           else _BATCH_AXES[0])
    if _MODEL_AXIS:
        msize = _axis_size(m, _MODEL_AXIS)
        if x.shape[head_dim] % msize == 0:
            spec[head_dim] = _MODEL_AXIS
        elif seq_dim is not None and x.shape[seq_dim] % msize == 0:
            spec[seq_dim] = _MODEL_AXIS
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
