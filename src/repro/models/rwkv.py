"""RWKV-6 "Finch" blocks [arXiv:2404.05892] — attention-free, data-dependent
decay linear recurrence.

Per head (head_dim = d/H) the time-mixing state is the matrix
``S in R^{hd x hd}``:

    wkv_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)
    S_t   = diag(w_t) S_{t-1} + k_t^T v_t

with the *data-dependent* per-channel decay ``w_t = exp(-exp(wb + lora(x_t)))``
— the Finch signature. Training uses ``lax.scan`` over time (a chunked Pallas
kernel lives in ``repro.kernels.rwkv6``); decode is an O(1) state update,
which is why rwkv6 runs the 500k-token decode shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .base import ModelConfig

HEAD_DIM = 64


def n_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // HEAD_DIM


def init_time_mix(key, cfg: ModelConfig):
    d = cfg.d_model
    h = n_heads(cfg)
    ks = jax.random.split(key, 9)
    lora = 32
    return {
        # token-shift interpolation coefficients per stream
        "mu_r": jnp.full((d,), 0.5, cfg.dt),
        "mu_k": jnp.full((d,), 0.5, cfg.dt),
        "mu_v": jnp.full((d,), 0.5, cfg.dt),
        "mu_w": jnp.full((d,), 0.5, cfg.dt),
        "mu_g": jnp.full((d,), 0.5, cfg.dt),
        "w_r": layers.dense_init(ks[0], d, d, cfg.dt),
        "w_k": layers.dense_init(ks[1], d, d, cfg.dt),
        "w_v": layers.dense_init(ks[2], d, d, cfg.dt),
        "w_g": layers.dense_init(ks[3], d, d, cfg.dt),
        # data-dependent decay: w = exp(-exp(base + lora))
        "decay_base": jnp.full((d,), -1.0, jnp.float32),
        "w_dec1": layers.dense_init(ks[4], d, lora, cfg.dt),
        "w_dec2": layers.dense_init(ks[5], lora, d, cfg.dt),
        "bonus_u": (jax.random.normal(ks[6], (h, HEAD_DIM)) * 0.1
                    ).astype(jnp.float32),
        "ln_g": jnp.ones((d,), cfg.dt),  # per-head group norm gamma
        "w_o": layers.dense_init(ks[7], d, d, cfg.dt),
    }


def init_channel_mix(key, cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, cfg.dt),
        "mu_r": jnp.full((d,), 0.5, cfg.dt),
        "w_k": layers.dense_init(ks[0], d, ff, cfg.dt),
        "w_v": layers.dense_init(ks[1], ff, d, cfg.dt),
        "w_r": layers.dense_init(ks[2], d, d, cfg.dt),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} stream. x [B,S,D]; last [B,D] for decode."""
    if last is not None:
        return last[:, None, :]
    pad = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _mix(x, xp, mu):
    return x * mu + xp * (1.0 - mu)


def _decay(p, xw):
    dd = (xw @ p["w_dec1"])
    dd = jnp.tanh(dd.astype(jnp.float32)).astype(xw.dtype) @ p["w_dec2"]
    return jnp.exp(-jnp.exp(p["decay_base"] + dd.astype(jnp.float32)))


WKV_CHUNK = 256  # remat granularity of the wkv recurrence


def _wkv_chunk(s0, rkvw, u):
    r, k, v, w = rkvw  # each [B,c,H,hd]

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp  # [B,H,hd]
        kv = k_t[..., :, None] * v_t[..., None, :]         # [B,H,hd,hd]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[:, :, None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, y

    sf, ys = jax.lax.scan(
        step, s0,
        (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
         v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3)))
    return sf, ys.transpose(1, 0, 2, 3)


def wkv_scan(r, k, v, w, u, s0=None, chunk: int = WKV_CHUNK):
    """Reference linear recurrence. r,k,v,w [B,S,H,hd] fp32; u [H,hd].
    Returns (y [B,S,H,hd], S_final [B,H,hd,hd]).

    Processed in rematerialized chunks: the backward pass stores only the
    per-chunk boundary states [B,H,hd,hd] (the same blocking as the Pallas
    wkv kernel in ``kernels/rwkv6``), not every step's [B,H,hd,hd] state —
    measured on rwkv6-1.6b train_4k in EXPERIMENTS.md §Perf fleet notes."""
    b, s, h, hd = r.shape
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32) if s0 is None else s0
    if s % chunk or s <= chunk:
        sf, ys = _wkv_chunk(s0, (r, k, v, w), u)
        return ys, sf

    nc = s // chunk

    def split(x):  # [B,S,H,hd] -> [nc,B,c,H,hd]
        return x.reshape(b, nc, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def body(state, rkvw_c):
        sf, ys = _wkv_chunk(state, rkvw_c, u)
        return sf, ys

    sf, ys = jax.lax.scan(body, s0, (split(r), split(k), split(v), split(w)))
    ys = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    return ys, sf


def _heads(x, h):
    b, s, d = x.shape
    return x.reshape(b, s, h, d // h)


def time_mix(cfg: ModelConfig, p, x, state=None, last_x=None):
    """state: [B,H,hd,hd] or None; last_x [B,D] (decode) or None."""
    h = n_heads(cfg)
    xp = _shift(x, last_x)
    r = _heads(_mix(x, xp, p["mu_r"]) @ p["w_r"], h).astype(jnp.float32)
    k = _heads(_mix(x, xp, p["mu_k"]) @ p["w_k"], h).astype(jnp.float32)
    v = _heads(_mix(x, xp, p["mu_v"]) @ p["w_v"], h).astype(jnp.float32)
    g = _mix(x, xp, p["mu_g"]) @ p["w_g"]
    w = _heads(_decay(p, _mix(x, xp, p["mu_w"])), h)  # fp32 in (0,1)
    k = k / jnp.sqrt(HEAD_DIM)

    y, sf = wkv_scan(r, k, v, w, p["bonus_u"], s0=state)
    b, s = x.shape[:2]
    y = y.reshape(b, s, cfg.d_model)
    # per-head group norm
    yn = y.reshape(b, s, h, HEAD_DIM)
    mu = yn.mean(-1, keepdims=True)
    var = yn.var(-1, keepdims=True)
    yn = (yn - mu) * jax.lax.rsqrt(var + 64e-5)
    y = (yn.reshape(b, s, cfg.d_model)
         * p["ln_g"].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    return y @ p["w_o"], sf, x[:, -1, :]


def channel_mix(cfg: ModelConfig, p, x, last_x=None):
    xp = _shift(x, last_x)
    k = _mix(x, xp, p["mu_k"]) @ p["w_k"]
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid((_mix(x, xp, p["mu_r"]) @ p["w_r"]).astype(jnp.float32))
    return (k @ p["w_v"]) * r.astype(x.dtype), x[:, -1, :]


def rwkv_init_cache(cfg: ModelConfig, batch: int):
    h = n_heads(cfg)
    return {
        "s": jnp.zeros((batch, h, HEAD_DIM, HEAD_DIM), jnp.float32),
        "tm_x": jnp.zeros((batch, cfg.d_model), cfg.dt),
        "cm_x": jnp.zeros((batch, cfg.d_model), cfg.dt),
    }
