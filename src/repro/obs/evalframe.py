"""Per-eval fairness telemetry: the ``EvalFrame`` time series.

FACADE's headline claims are *fairness* claims — DP/EO gaps,
worst-cluster accuracy, cluster settlement — but until this module the
repo recorded ``dp``/``eo``/``node_acc`` only as final scalars computed
once at run end, so fairness *over training* was invisible. An
:class:`EvalFrame` promotes every eval to a full fairness observation:
DP, EO, fair accuracy, per-cluster and worst-cluster accuracy, per-tier
accuracy, and cluster-assignment churn since the previous eval.

Cost model (the eval twin of the ``MetricsFrame`` drain contract): the
frame is pure HOST-side bookkeeping over arrays the evaluator already
drains — ``preds_c``/``labels_c``/``node_acc`` out of
``_History.eval_finish`` — so eval telemetry adds **zero extra
dispatches and zero extra device syncs**. It therefore never touches
the ``EngineSpec`` cache key and is recorded whether or not a device
:class:`~repro.obs.frame.ObsConfig` is attached.

:func:`compute_eval_frame` is the ONE shared recording hook both
drivers call (inside ``_History.eval_finish``, the single eval
bottleneck the engine, legacy and pipelined loops all route through —
the ``compute_frame`` discipline from the PR 6 contract), which is what
keeps the series engine/legacy bit-identical AND keeps the series'
final entry bit-for-bit equal to ``RunResult.dp``/``RunResult.eo``:
the run's final scalars are read OFF the last frame, never recomputed.

The series surfaces four ways: ``RunResult.eval_frames`` (always, so
``repro.sweep.aggregate_cell`` can build per-cell mean/std DP/EO
trajectories), ``Obs.eval_table()`` + ``type:"eval"`` JSONL records
(when an ``Obs`` is attached), the checkpoint history snapshot (resume
preserves the trajectory bit-for-bit, extending the PR 7 guarantee),
and ``repro.obs.report`` (rendered fairness-trajectory tables).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.fairness import (demographic_parity, equalized_odds,
                            fair_accuracy)


class EvalFrame(NamedTuple):
    """One eval's fairness observation. Plain Python scalars/tuples —
    JSON- and checkpoint-friendly, never device arrays."""
    round: int                  # 1-based eval round
    mean_acc: float             # node-weighted mean accuracy (the
    #                             target_acc stop metric)
    fair_acc: float             # paper Eq. 5 (lambda = 2/3)
    dp: float                   # demographic parity gap at this eval
    eo: float                   # equalized odds gap at this eval
    worst_cluster_acc: float    # min over the clusters that exist
    acc: tuple                  # per-cluster accuracy, ``cluster_ids`` order
    cluster_ids: tuple          # which cluster each ``acc`` entry is
    acc_core: float             # mean per-node accuracy, core-tier nodes
    acc_edge: float             # mean per-node accuracy, edge-tier nodes
    #                             (0 when the run has no edge tier)
    tier_gap: float             # acc_core - acc_edge (0 without tiers)
    cluster_churn: float        # nodes whose cluster assignment changed
    #                             since the PREVIOUS eval (0 at the first
    #                             eval and off-FACADE)


EVAL_FIELDS = EvalFrame._fields

# the scalar subset (everything but the ragged per-cluster vectors) —
# what Obs.eval_table() stacks into aligned numpy columns
EVAL_SCALAR_FIELDS = tuple(f for f in EVAL_FIELDS
                           if f not in ("acc", "cluster_ids"))


def compute_eval_frame(rnd: int, accs, cluster_ids, preds_c, labels_c,
                       node_acc, n_classes: int, *, mean_acc: float,
                       tiers=None, prev_cid=None, cid=None) -> EvalFrame:
    """Build one eval's :class:`EvalFrame` — the shared hook both
    drivers call from ``_History.eval_finish``.

    ``accs``/``cluster_ids``/``preds_c``/``labels_c``/``node_acc`` are
    exactly what ``make_evaluator``'s ``finish`` drained (per non-empty
    cluster accuracies + first-node predictions + per-node accuracy);
    ``mean_acc`` is the node-weighted mean the driver already computed
    (passed through, never recomputed, so the stop condition and the
    frame can't drift apart); ``tiers`` is the static per-node tier
    vector (1.0 = edge, ``repro.obs.tiers_of``) or ``None``;
    ``prev_cid``/``cid`` are the cluster-id vectors at the previous and
    current eval (``None`` off-FACADE / at the first eval).

    DP/EO/fair-accuracy are computed HERE with the same
    ``repro.fairness`` functions the final scalars always used — the
    caller reads its ``RunResult.dp``/``eo``/``fair_acc`` entries back
    off the frame, so the series' last entry is bit-for-bit the final
    scalar by construction (pinned in ``tests/test_obs.py``).
    """
    accs = [float(a) for a in accs]
    frame_acc_core = frame_acc_edge = tier_gap = 0.0
    if node_acc is not None:
        node_acc = np.asarray(node_acc, np.float64)
        if tiers is not None:
            edge = np.asarray(tiers, np.float64) > 0.5
            core_acc = node_acc[~edge]
            edge_acc = node_acc[edge]
        else:
            core_acc, edge_acc = node_acc, node_acc[:0]
        frame_acc_core = float(core_acc.mean()) if core_acc.size else 0.0
        frame_acc_edge = float(edge_acc.mean()) if edge_acc.size else 0.0
        if core_acc.size and edge_acc.size:
            tier_gap = frame_acc_core - frame_acc_edge
    churn = 0.0
    if prev_cid is not None and cid is not None:
        churn = float(np.sum(np.asarray(prev_cid) != np.asarray(cid)))
    return EvalFrame(
        round=int(rnd),
        mean_acc=float(mean_acc),
        fair_acc=float(fair_accuracy(accs)),
        dp=float(demographic_parity(preds_c, n_classes)),
        eo=float(equalized_odds(preds_c, labels_c, n_classes)),
        worst_cluster_acc=float(min(accs)) if accs else 0.0,
        acc=tuple(accs),
        cluster_ids=tuple(int(c) for c in cluster_ids),
        acc_core=frame_acc_core, acc_edge=frame_acc_edge,
        tier_gap=tier_gap, cluster_churn=churn)


def eval_table(frames) -> dict:
    """Stack a list of :class:`EvalFrame` into aligned columns:
    numpy arrays for every scalar field (``round`` int64, the rest
    float64) plus ``acc``/``cluster_ids`` as lists-of-tuples (ragged
    across runs with different cluster counts)."""
    out = {}
    for name in EVAL_SCALAR_FIELDS:
        dtype = np.int64 if name == "round" else np.float64
        out[name] = np.asarray([getattr(f, name) for f in frames], dtype)
    out["acc"] = [f.acc for f in frames]
    out["cluster_ids"] = [f.cluster_ids for f in frames]
    return out


def frame_record(frame: EvalFrame) -> dict:
    """The ``type:"eval"`` JSONL record for one frame."""
    rec = {"type": "eval"}
    for name, v in zip(EVAL_FIELDS, frame):
        rec[name] = list(v) if isinstance(v, tuple) else v
    return rec
