"""repro.obs — in-scan telemetry, fairness trajectories, run health
and run manifests.

Four layers, composable but independent:

* **device**: :class:`ObsConfig` + :class:`MetricsFrame`
  (:mod:`.frame`) — a fixed pytree of per-round scalars (update/param
  norms, cluster switches, delivered edges, per-tier byte split,
  gossip-staleness histogram, inclusion) computed INSIDE the engine's
  ``lax.scan`` and drained in the segment's existing single bulk
  ``device_get`` — zero extra dispatches, zero extra host syncs;
* **eval**: :class:`EvalFrame` (:mod:`.evalframe`) — one fairness
  observation per real eval (DP, EO, fair/worst-cluster/per-tier
  accuracy, cluster churn), pure host bookkeeping over arrays the
  evaluator already drains — zero extra dispatches, recorded whether
  or not a device ``ObsConfig`` is attached;
* **host**: :class:`Tracer` (:mod:`.trace`) — nested spans around
  compile / segment dispatch / scalar drain / eval, ``EngineCache``
  hit/miss events, optional ``jax.profiler`` hook — plus the
  :mod:`.health` rule engine judging both telemetry streams into a
  per-run :class:`HealthReport` verdict, and :mod:`.report` rendering
  manifest + JSONL into markdown/JSON run reports
  (``python -m repro.obs.report``);
* **disk**: :class:`JsonlSink` + :class:`RunManifest` (:mod:`.sink`) —
  one JSONL record format for training AND serving telemetry, plus a
  manifest (config fingerprint, spec key, settings, timing rollup,
  health verdict) written next to results and stamped into every
  ``BENCH_*.json``.

Usage — any algorithm, either driver, any netsim/topo combination::

    from repro.core.runner import run_experiment
    from repro.obs import Obs, ObsConfig

    obs = Obs(ObsConfig(), jsonl="results/obs/run.jsonl",
              out_dir="results/obs")
    res = run_experiment("facade", cfg, ds, rounds=100, obs=obs)
    obs.frames_table()["cluster_switches"]   # per-round settlement curve
    obs.eval_table()["dp"]                   # DP gap over training
    obs.manifests[-1].health["verdict"]      # "ok" | "warn" | "fail"
    obs.tracer.rollup()                      # where the wall-clock went

``obs=None`` (the default) is bit-for-bit the pre-obs path, and an
ENABLED frame never perturbs a trajectory either — telemetry is pure
observation (both pinned in ``tests/test_obs.py`` for all 5 algorithms
on both drivers). Only :class:`ObsConfig` (the device-side frame spec)
is an ``EngineSpec`` cache-key component; host-side eval telemetry,
health rules and sink/profiler settings on :class:`Obs` never fork the
key or recompile anything.
"""
from __future__ import annotations

import pathlib
from typing import Any

import numpy as np

from .evalframe import (EVAL_FIELDS, EVAL_SCALAR_FIELDS,  # noqa: F401
                        EvalFrame, compute_eval_frame, frame_record)
from .evalframe import eval_table as _eval_table
from .frame import (FRAME_FIELDS, MetricsFrame, ObsConfig,  # noqa: F401
                    compute_frame, tiers_of)
from .health import (HealthConfig, HealthContext,  # noqa: F401
                     HealthIssue, HealthReport, worst_verdict)
from .health import evaluate as evaluate_health  # noqa: F401
from .sink import (JsonlSink, RunManifest, bench_stamp,  # noqa: F401
                   fingerprint, read_jsonl)
from .trace import Tracer, maybe_profile  # noqa: F401


class Obs:
    """Host-side observability context for one or more runs.

    ``config``: the device-side :class:`ObsConfig` (``None`` = spans and
    manifests only, no in-scan frame — and no cache-key fork);
    ``health``: the :class:`HealthConfig` thresholds the driver judges
    each run against at run end (``None`` = skip health evaluation);
    ``jsonl``/``sink``: where events go (``jsonl`` path builds a
    :class:`JsonlSink`); ``out_dir``: where per-run manifests are
    written; ``profile_dir``: optional ``jax.profiler`` trace directory.

    One ``Obs`` may span many runs (a sweep shares one): frames, eval
    frames and manifests accumulate, with ``run.begin``/``run.end``
    events marking the boundaries in the JSONL stream and
    :meth:`run_frames_table`/:meth:`run_eval_table` slicing out the
    current run.
    """

    def __init__(self, config: "ObsConfig | None" = ObsConfig(), *,
                 health: "HealthConfig | None" = HealthConfig(),
                 jsonl=None, sink=None, out_dir=None, profile_dir=None):
        self.config = config
        self.health_config = health
        self.sink = sink if sink is not None else (
            JsonlSink(jsonl) if jsonl is not None else None)
        self.tracer = Tracer(sink=self.sink)
        self.out_dir = pathlib.Path(out_dir) if out_dir is not None else None
        self.profile_dir = profile_dir
        self.frames: list[tuple] = []      # (rounds [m], MetricsFrame [m,...])
        self.eval_frames: list[EvalFrame] = []
        self.manifests: list[RunManifest] = []
        self._frames_mark = 0              # where the current run's frames
        self._evals_mark = 0               # ... and eval frames begin

    # -- run lifecycle ------------------------------------------------------
    def begin_run(self, **attrs: Any) -> None:
        self._frames_mark = len(self.frames)
        self._evals_mark = len(self.eval_frames)
        self.tracer.event("run.begin", **attrs)

    def end_run(self, manifest: RunManifest) -> RunManifest:
        self.manifests.append(manifest)
        if self.out_dir is not None:
            manifest.save(self.out_dir /
                          f"manifest_{manifest.name}.json")
        self.tracer.event("run.end", run=manifest.name,
                          fingerprint=manifest.fingerprint)
        return manifest

    def profile(self):
        """Context manager: ``jax.profiler`` trace when ``profile_dir``
        is set and the profiler works here, else a no-op."""
        return maybe_profile(self.profile_dir)

    # -- frames -------------------------------------------------------------
    def record_frames(self, rounds, frame: MetricsFrame) -> None:
        """Store one drained segment of frames (host numpy, leading axis
        ``len(rounds)``) and mirror a ``metrics`` record to the sink."""
        rounds = np.asarray(rounds, np.int64).reshape(-1)
        frame = MetricsFrame(*(np.asarray(l) for l in frame))
        self.frames.append((rounds, frame))
        if self.sink is not None:
            rec = {"type": "metrics", "rounds": rounds.tolist()}
            for name, leaf in zip(MetricsFrame._fields, frame):
                rec[name] = np.asarray(leaf, np.float64).tolist()
            self.sink.emit(rec)

    def frames_table(self) -> dict:
        """All recorded frames concatenated: ``{"round": [m], field:
        [m, ...]}`` across every run this ``Obs`` observed."""
        return self._frames_table(self.frames)

    def run_frames_table(self) -> dict:
        """Like :meth:`frames_table`, restricted to the run started by
        the most recent :meth:`begin_run` — what health judges."""
        return self._frames_table(self.frames[self._frames_mark:])

    @staticmethod
    def _frames_table(frames) -> dict:
        if not frames:
            return {"round": np.zeros((0,), np.int64),
                    **{f: np.zeros((0,)) for f in MetricsFrame._fields}}
        out = {"round": np.concatenate([r for r, _ in frames])}
        for i, name in enumerate(MetricsFrame._fields):
            out[name] = np.concatenate(
                [np.atleast_1d(f[i]) if f[i].ndim == 0 else f[i]
                 for _, f in frames])
        return out

    # -- eval frames --------------------------------------------------------
    def record_eval(self, frame: EvalFrame) -> None:
        """Store one eval's fairness observation and mirror a
        ``type:"eval"`` record to the sink."""
        self.eval_frames.append(frame)
        if self.sink is not None:
            self.sink.emit(frame_record(frame))

    def eval_table(self) -> dict:
        """All recorded eval frames as aligned columns (numpy for the
        scalar fields, lists for the ragged per-cluster vectors)."""
        return _eval_table(self.eval_frames)

    def run_eval_table(self) -> dict:
        """Like :meth:`eval_table`, restricted to the current run."""
        return _eval_table(self.eval_frames[self._evals_mark:])
