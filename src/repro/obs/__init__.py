"""repro.obs — in-scan telemetry, span tracing and run manifests.

Three layers, composable but independent:

* **device**: :class:`ObsConfig` + :class:`MetricsFrame`
  (:mod:`.frame`) — a fixed pytree of per-round scalars (update/param
  norms, cluster switches, delivered edges, per-tier byte split,
  gossip-staleness histogram, inclusion) computed INSIDE the engine's
  ``lax.scan`` and drained in the segment's existing single bulk
  ``device_get`` — zero extra dispatches, zero extra host syncs;
* **host**: :class:`Tracer` (:mod:`.trace`) — nested spans around
  compile / segment dispatch / scalar drain / eval, ``EngineCache``
  hit/miss events, optional ``jax.profiler`` hook;
* **disk**: :class:`JsonlSink` + :class:`RunManifest` (:mod:`.sink`) —
  one JSONL record format for training AND serving telemetry, plus a
  manifest (config fingerprint, spec key, settings, timing rollup)
  written next to results and stamped into every ``BENCH_*.json``.

Usage — any algorithm, either driver, any netsim/topo combination::

    from repro.core.runner import run_experiment
    from repro.obs import Obs, ObsConfig

    obs = Obs(ObsConfig(), jsonl="results/obs/run.jsonl",
              out_dir="results/obs")
    res = run_experiment("facade", cfg, ds, rounds=100, obs=obs)
    obs.frames_table()["cluster_switches"]   # per-round settlement curve
    obs.tracer.rollup()                      # where the wall-clock went
    obs.manifests[-1].fingerprint            # what exactly ran

``obs=None`` (the default) is bit-for-bit the pre-obs path, and an
ENABLED frame never perturbs a trajectory either — telemetry is pure
observation (both pinned in ``tests/test_obs.py`` for all 5 algorithms
on both drivers). Only :class:`ObsConfig` (the device-side frame spec)
is an ``EngineSpec`` cache-key component; host-side sink/profiler
settings on :class:`Obs` never fork the key or recompile anything.
"""
from __future__ import annotations

import pathlib
from typing import Any

import numpy as np

from .frame import (FRAME_FIELDS, MetricsFrame, ObsConfig,  # noqa: F401
                    compute_frame, tiers_of)
from .sink import (JsonlSink, RunManifest, bench_stamp,  # noqa: F401
                   fingerprint, read_jsonl)
from .trace import Tracer, maybe_profile  # noqa: F401


class Obs:
    """Host-side observability context for one or more runs.

    ``config``: the device-side :class:`ObsConfig` (``None`` = spans and
    manifests only, no in-scan frame — and no cache-key fork);
    ``jsonl``/``sink``: where events go (``jsonl`` path builds a
    :class:`JsonlSink`); ``out_dir``: where per-run manifests are
    written; ``profile_dir``: optional ``jax.profiler`` trace directory.

    One ``Obs`` may span many runs (a sweep shares one): frames and
    manifests accumulate, with ``run.begin``/``run.end`` events marking
    the boundaries in the JSONL stream.
    """

    def __init__(self, config: "ObsConfig | None" = ObsConfig(), *,
                 jsonl=None, sink=None, out_dir=None, profile_dir=None):
        self.config = config
        self.sink = sink if sink is not None else (
            JsonlSink(jsonl) if jsonl is not None else None)
        self.tracer = Tracer(sink=self.sink)
        self.out_dir = pathlib.Path(out_dir) if out_dir is not None else None
        self.profile_dir = profile_dir
        self.frames: list[tuple] = []      # (rounds [m], MetricsFrame [m,...])
        self.manifests: list[RunManifest] = []

    # -- run lifecycle ------------------------------------------------------
    def begin_run(self, **attrs: Any) -> None:
        self.tracer.event("run.begin", **attrs)

    def end_run(self, manifest: RunManifest) -> RunManifest:
        self.manifests.append(manifest)
        if self.out_dir is not None:
            manifest.save(self.out_dir /
                          f"manifest_{manifest.name}.json")
        self.tracer.event("run.end", run=manifest.name,
                          fingerprint=manifest.fingerprint)
        return manifest

    def profile(self):
        """Context manager: ``jax.profiler`` trace when ``profile_dir``
        is set and the profiler works here, else a no-op."""
        return maybe_profile(self.profile_dir)

    # -- frames -------------------------------------------------------------
    def record_frames(self, rounds, frame: MetricsFrame) -> None:
        """Store one drained segment of frames (host numpy, leading axis
        ``len(rounds)``) and mirror a ``metrics`` record to the sink."""
        rounds = np.asarray(rounds, np.int64).reshape(-1)
        frame = MetricsFrame(*(np.asarray(l) for l in frame))
        self.frames.append((rounds, frame))
        if self.sink is not None:
            rec = {"type": "metrics", "rounds": rounds.tolist()}
            for name, leaf in zip(MetricsFrame._fields, frame):
                rec[name] = np.asarray(leaf, np.float64).tolist()
            self.sink.emit(rec)

    def frames_table(self) -> dict:
        """All recorded frames concatenated: ``{"round": [m], field:
        [m, ...]}`` across every run this ``Obs`` observed."""
        if not self.frames:
            return {"round": np.zeros((0,), np.int64),
                    **{f: np.zeros((0,)) for f in MetricsFrame._fields}}
        out = {"round": np.concatenate([r for r, _ in self.frames])}
        for i, name in enumerate(MetricsFrame._fields):
            out[name] = np.concatenate(
                [np.atleast_1d(f[i]) if f[i].ndim == 0 else f[i]
                 for _, f in self.frames])
        return out
