"""On-device per-round telemetry: the ``MetricsFrame`` scan leaf.

Since the scan-fused segment engine landed (PR 2), everything between two
evals — gossip mixing, cluster re-assignment, netsim conditions, the
adaptive topology policy — compiles away inside one opaque
``lax.scan`` dispatch. The paper's claims live on exactly those
internals (cluster-assignment settlement, per-tier bytes, staleness,
fairness dynamics), so this module recovers them WITHOUT reopening the
scan: a :class:`MetricsFrame` is a fixed pytree of per-round scalars
computed inside the scan step and stacked ``[length, ...]`` like every
other per-round output, then drained to the host in the segment's
existing single ``device_get`` — telemetry costs zero extra dispatches
and zero extra host syncs.

Schema contract (ROADMAP "obs"):

* every field is a fixed-shape float32 array whose shape depends only on
  the static :class:`ObsConfig` (``stale_hist`` is ``[staleness_bins]``,
  everything else a scalar), so the frame can ride ``lax.scan`` outputs;
* fields that don't apply to a run are ZEROS, never absent — the pytree
  structure is identical for FACADE and every baseline, with and without
  netsim, so one compiled segment program per config serves all;
* :func:`compute_frame` is the single definition both drivers share
  (the engine scans over it, the legacy loop jits it), the same
  discipline that keeps ``netsim.advance_conditions`` / ``topo.advance``
  engine/legacy bit-identical;
* adding a metric = add a ``MetricsFrame`` field + compute it here.
  Device-side knobs that change the compiled frame (an :class:`ObsConfig`
  field) fork the ``EngineSpec`` cache key; host-side sink/tracer
  settings (:class:`repro.obs.Obs`) never do — so adding a sink or a
  profile dir recompiles nothing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro import netsim


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Static, device-side telemetry description — an ``EngineSpec``
    cache-key component (every field here changes the compiled segment
    program's outputs, so every field forks the key; the every-field-
    forks + coverage contract is pinned in ``tests/test_obs.py`` /
    ``tests/test_property.py``, same pattern as ``TopoConfig``).

    ``norms``/``comm``/``switches`` gate the corresponding frame fields
    (gated-off fields are computed as zeros, keeping the pytree fixed);
    ``staleness_bins`` is the staleness histogram width — ages are
    clipped into the last bin.
    """
    norms: bool = True           # update/param L2 norms
    comm: bool = True            # delivered edges, inclusion, tier bytes
    switches: bool = True        # FACADE cluster-assignment switches
    staleness_bins: int = 4      # gossip-age histogram width
    faults: bool = True          # crashed/corrupted/quarantined counters
    #                              (repro.resil; zeros when faults are off)

    def __post_init__(self):
        if self.staleness_bins < 1:
            raise ValueError(
                f"staleness_bins must be >= 1, got {self.staleness_bins}")


class MetricsFrame(NamedTuple):
    """One round's telemetry. All leaves float32; shapes fixed per
    :class:`ObsConfig` (scalars except ``stale_hist`` ``[bins]``)."""
    update_norm: Any       # global L2 of the round's mixable-state delta
    param_norm: Any        # global L2 of the new mixable state
    cluster_switches: Any  # nodes whose cluster_id changed (0 off-FACADE)
    delivered_edges: Any   # directed edges that carried a message
    inclusion: Any         # fraction of nodes with >= 1 incident edge
    bytes_core: Any        # fresh bytes sent by core-tier nodes
    bytes_edge: Any        # fresh bytes sent by edge-tier nodes
    stale_hist: Any        # [bins] node count per gossip-staleness age
    crashed: Any           # nodes down this round (repro.resil crash chain)
    corrupted: Any         # nodes shipping a corrupted payload this round
    quarantined: Any       # senders the robust guard quarantined


FRAME_FIELDS = MetricsFrame._fields


def tiers_of(net, n: int):
    """Static per-node tier vector (1.0 = edge) for the byte split —
    all-core when the run has no tiered link classes."""
    if net is not None and net.classes is not None:
        return jnp.asarray(netsim.node_tiers(net, n), jnp.float32)
    return jnp.zeros((n,), jnp.float32)


def _sq_norms(prev_tree, new_tree):
    """(sum (new-prev)^2, sum new^2) over float leaves only — int leaves
    (cluster ids, round counters, PRNG keys) carry no norm."""
    usq = psq = jnp.zeros((), jnp.float32)
    for a, b in zip(jax.tree.leaves(prev_tree), jax.tree.leaves(new_tree)):
        if not jnp.issubdtype(jnp.asarray(b).dtype, jnp.floating):
            continue
        a32 = jnp.asarray(a, jnp.float32)
        b32 = jnp.asarray(b, jnp.float32)
        usq = usq + jnp.sum(jnp.square(b32 - a32))
        psq = psq + jnp.sum(jnp.square(b32))
    return usq, psq


def compute_frame(cfg: ObsConfig, n: int, tiers, prev_mix, new_mix,
                  prev_cid, new_cid, info, conds, gossip) -> MetricsFrame:
    """Build one round's :class:`MetricsFrame`. Pure observation: reads
    the round's states/info, never feeds anything back — enabling
    telemetry cannot perturb a trajectory (pinned by ``test_obs.py``).

    ``prev_mix``/``new_mix``: the algorithm's mixable trees before/after
    the round; ``prev_cid``/``new_cid``: cluster ids (``None``
    off-FACADE); ``info``: the round function's info dict (``adj_eff`` /
    ``payload_bytes`` from :func:`repro.core.netwire.comm_info`);
    ``conds``: the round's ``RoundConditions`` (``None`` without
    netsim); ``gossip``: the post-round :class:`netsim.GossipState`
    (``None`` means every node is fresh -> all mass in age bin 0).
    """
    zero = jnp.zeros((), jnp.float32)
    update_norm = param_norm = zero
    if cfg.norms:
        usq, psq = _sq_norms(prev_mix, new_mix)
        update_norm, param_norm = jnp.sqrt(usq), jnp.sqrt(psq)

    switches = zero
    if cfg.switches and prev_cid is not None and new_cid is not None:
        switches = jnp.sum((prev_cid != new_cid).astype(jnp.float32))

    delivered = inclusion = bytes_core = bytes_edge = zero
    if cfg.comm and "adj_eff" in info:
        adj = jnp.asarray(info["adj_eff"], jnp.float32)
        payload = jnp.asarray(info["payload_bytes"], jnp.float32)
        delivered = adj.sum()
        inclusion = jnp.mean((adj.sum(1) > 0).astype(jnp.float32))
        sends = adj
        if conds is not None and conds.stale is not None:
            # match the byte-honesty contract: a stale sender's
            # neighbors reuse its cached snapshot — no fresh bytes
            sends = adj * (1.0 - conds.stale)[:, None]
        node_bytes = sends.sum(1) * payload
        bytes_edge = (node_bytes * tiers).sum()
        bytes_core = node_bytes.sum() - bytes_edge

    bins = cfg.staleness_bins
    if gossip is not None:
        age = jnp.clip(gossip.age, 0, bins - 1)
        stale_hist = jnp.sum(jax.nn.one_hot(age, bins, dtype=jnp.float32),
                             axis=0)
    else:
        stale_hist = jnp.zeros((bins,), jnp.float32).at[0].set(float(n))

    crashed = corrupted = quarantined = zero
    if cfg.faults and conds is not None:
        if conds.crashed is not None:
            crashed = jnp.sum(jnp.asarray(conds.crashed, jnp.float32))
        if conds.corrupt is not None:
            corrupted = jnp.sum(jnp.asarray(conds.corrupt, jnp.float32))
        if "quarantined" in info:
            quarantined = jnp.asarray(info["quarantined"], jnp.float32)

    return MetricsFrame(update_norm=update_norm, param_norm=param_norm,
                        cluster_switches=switches,
                        delivered_edges=delivered, inclusion=inclusion,
                        bytes_core=bytes_core, bytes_edge=bytes_edge,
                        stale_hist=stale_hist, crashed=crashed,
                        corrupted=corrupted, quarantined=quarantined)
