"""Run reports: render a manifest + its JSONL trace into markdown/JSON.

The manifest says *what* ran (fingerprint, settings, timing rollup,
health verdict); the JSONL says *how it went* (per-eval ``EvalFrame``
records, per-round metrics, health events). This module joins the two
into one human-readable artifact — the fairness trajectory, the
cluster-settlement round, the health verdict with per-issue round
ranges, and the timing/cache rollup — so "did this run reproduce the
paper's fairness story" is one file, not a JSONL spelunk.

CLI (works on a single-run manifest OR a ``run_sweep`` JSON)::

    python -m repro.obs.report results/obs/manifest_facade-seed0.json
    python -m repro.obs.report results/sweep.json --out report.md
    python -m repro.obs.report manifest.json --jsonl trace.jsonl --json

The run path resolves its JSONL from ``manifest.settings["jsonl"]``
(recorded by ``run_experiment``) unless ``--jsonl`` overrides it; a
missing trace degrades to a manifest-only report (no trajectory table)
rather than failing — a report must render from whatever survived.
"""
from __future__ import annotations

import argparse
import json
import pathlib

from .sink import RunManifest, read_jsonl

# the trajectory columns a report tabulates, in display order
_TRAJ_FIELDS = ("round", "mean_acc", "fair_acc", "dp", "eo",
                "worst_cluster_acc", "cluster_churn")


def _slice_run_events(events, name):
    """The event window belonging to run ``name`` when one JSONL holds
    several runs: everything between the ``run.begin`` preceding the
    matching ``run.end`` and that ``run.end``. Falls back to the whole
    stream when the boundaries are absent (single-run logs, crashes)."""
    end = next((i for i, e in enumerate(events)
                if e.get("name") == "run.end" and e.get("run") == name),
               None)
    if end is None:
        return events
    begin = max((i for i in range(end)
                 if events[i].get("name") == "run.begin"), default=0)
    return events[begin:end + 1]


def settlement_round(evals) -> "int | None":
    """First eval round after which cluster assignment never changed
    again (paper Fig. 9's settlement) — ``None`` when churn was never
    observed or never stopped."""
    churned = [e["round"] for e in evals if e.get("cluster_churn", 0) > 0]
    if not churned:
        return None
    later = [e["round"] for e in evals if e["round"] > churned[-1]]
    return min(later) if later else None


def build_run_report(manifest: dict, events) -> dict:
    """Join one run's manifest dict with its event stream into the
    report payload (pure data — :func:`render_run_markdown` formats)."""
    events = _slice_run_events(events, manifest.get("name"))
    evals = [e for e in events if e.get("type") == "eval"]
    trajectory = {f: [e.get(f) for e in evals] for f in _TRAJ_FIELDS}
    health_events = [e for e in events
                     if str(e.get("name", "")).startswith("health.")]
    return {
        "name": manifest.get("name"),
        "kind": manifest.get("kind"),
        "fingerprint": manifest.get("fingerprint"),
        "settings": manifest.get("settings", {}),
        "n_evals": len(evals),
        "trajectory": trajectory,
        "settlement_round": settlement_round(evals),
        "health": manifest.get("health"),
        "health_events": health_events,
        "timing": manifest.get("timing", {}),
        "cache": manifest.get("cache"),
    }


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.4f}"
    return str(v)


def _md_table(headers, rows) -> list:
    lines = ["| " + " | ".join(headers) + " |",
             "|" + "|".join("---" for _ in headers) + "|"]
    lines += ["| " + " | ".join(_fmt(c) for c in row) + " |"
              for row in rows]
    return lines


def render_run_markdown(report: dict) -> str:
    lines = [f"# Run report: {report['name']}",
             "",
             f"- kind: `{report['kind']}`",
             f"- fingerprint: `{report['fingerprint']}`"]
    for k, v in sorted(report.get("settings", {}).items()):
        lines.append(f"- {k}: `{v}`")
    health = report.get("health")
    lines += ["", "## Health",
              f"**verdict: {health['verdict'] if health else 'n/a'}**"]
    for issue in (health or {}).get("issues", ()):
        lines.append(
            f"- `{issue['rule']}` [{issue['severity']}] rounds "
            f"{issue['round_start']}-{issue['round_end']}: "
            f"{issue['detail']} (value={_fmt(issue['value'])})")
    if health and not health.get("issues"):
        lines.append("- no issues")
    lines += ["", "## Fairness trajectory"]
    traj = report["trajectory"]
    if report["n_evals"]:
        rows = list(zip(*(traj[f] for f in _TRAJ_FIELDS)))
        lines += _md_table(_TRAJ_FIELDS, rows)
        settle = report["settlement_round"]
        lines.append("")
        lines.append(
            f"settlement round: {settle}" if settle is not None
            else "settlement round: n/a (no churn observed, or still "
                 "churning at the last eval)")
    else:
        lines.append("no eval records (trace missing or run had no evals)")
    timing = report.get("timing", {})
    spans = timing.get("spans", {})
    if spans:
        lines += ["", "## Timing"]
        lines += _md_table(
            ("span", "count", "total_s"),
            [(name, s["count"], s["total_s"])
             for name, s in sorted(spans.items(),
                                   key=lambda kv: -kv[1]["total_s"])])
    cache = report.get("cache")
    if cache:
        lines += ["", "## Compile cache",
                  "- " + ", ".join(f"{k}={v}" for k, v in
                                   sorted(cache.items())
                                   if not isinstance(v, (dict, list)))]
    return "\n".join(lines) + "\n"


def build_sweep_report(sweep: dict) -> dict:
    """The report payload for a ``run_sweep`` JSON (``cells`` key)."""
    cells = []
    for name, cell in sweep.get("cells", {}).items():
        summary = cell.get("summary", {})
        fa = summary.get("best_fair_acc") or {}
        cells.append({
            "name": name,
            "algo": cell.get("algo"),
            "net": cell.get("net"),
            "error": cell.get("error"),
            "skipped": cell.get("skipped", False),
            "health": cell.get("health"),
            "best_fair_acc": fa.get("mean"),
            "dp": (summary.get("dp") or {}).get("mean"),
            "eo": (summary.get("eo") or {}).get("mean"),
            "fairness_trajectory": summary.get("fairness_trajectory"),
        })
    return {"kind": "sweep", "seeds": sweep.get("seeds"),
            "wall_s": sweep.get("wall_s"), "cache": sweep.get("cache"),
            "cells": cells}


def render_sweep_markdown(report: dict) -> str:
    lines = ["# Sweep report", "",
             f"- seeds: `{report.get('seeds')}`",
             f"- wall_s: {_fmt(report.get('wall_s'))}",
             "", "## Cells"]
    rows = []
    for c in report["cells"]:
        verdict = (c["health"] or {}).get("verdict") if c["health"] else None
        status = ("ERROR" if c["error"] else
                  "skipped" if c["skipped"] else verdict or "-")
        rows.append((c["name"], c["algo"], c["net"], status,
                     c["best_fair_acc"], c["dp"], c["eo"]))
    lines += _md_table(("cell", "algo", "net", "health",
                        "best_fair_acc", "dp", "eo"), rows)
    return "\n".join(lines) + "\n"


def build_report(path, jsonl=None) -> "tuple[dict, str]":
    """Load ``path`` (run manifest or sweep JSON), build the payload,
    and return ``(report_dict, markdown)``."""
    path = pathlib.Path(path)
    data = json.loads(path.read_text())
    if "cells" in data:
        report = build_sweep_report(data)
        return report, render_sweep_markdown(report)
    manifest = RunManifest.load(path).to_json()
    trace = jsonl if jsonl is not None else manifest.get(
        "settings", {}).get("jsonl")
    events = read_jsonl(trace) if trace else []
    report = build_run_report(manifest, events)
    return report, render_run_markdown(report)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a run manifest or sweep JSON into a report.")
    ap.add_argument("path", help="run manifest .json or run_sweep .json")
    ap.add_argument("--jsonl", default=None,
                    help="JSONL trace (default: manifest settings['jsonl'])")
    ap.add_argument("--out", default=None,
                    help="write the report here instead of stdout")
    ap.add_argument("--json", action="store_true",
                    help="emit the report payload as JSON, not markdown")
    args = ap.parse_args(argv)
    report, md = build_report(args.path, jsonl=args.jsonl)
    text = (json.dumps(report, indent=2, default=repr)
            if args.json else md)
    if args.out:
        out = pathlib.Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(text)
    else:
        print(text, end="")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
