"""Host-side span tracer for the training and serving drivers.

Phase-level wall-clock is the instrument every later perf PR (sharding,
pipelined segments — ROADMAP Open Items 1 and 5) needs: you cannot
overlap segment dispatch with scalar drain until you can SEE how long
each takes. :class:`Tracer` provides nested spans (``compile`` /
``dispatch`` / ``drain`` / ``eval`` in the engine; ``prefill`` /
``decode`` in serving) with microsecond timestamps, point events
(``EngineCache`` hits/misses, SLO summaries), an aggregate
:meth:`Tracer.rollup`, and an optional mirror of every record into a
:class:`repro.obs.JsonlSink` — one JSONL format shared by training and
serving telemetry.

Everything here is host Python around the dispatch boundary: a span
never enters jitted code, so tracing cannot change a compiled program
(and therefore never touches the ``EngineSpec`` cache key).

Timing semantics at the dispatch boundary: JAX dispatch is
asynchronous, so a ``dispatch`` span measures trace+enqueue time while
the following ``drain`` span (which blocks on ``device_get``) absorbs
device compute + transfer. A ``compile`` span wraps the first call of a
segment program, where XLA compilation dominates. Under
``run_experiment(pipeline=True)`` segment ``t+1`` is dispatched before
``t`` is drained, so the device is already working while the host
blocks: ``drain`` shrinks to the RESIDUAL wait (often ~0 once the
pipeline is full) and the sum of ``drain`` spans no longer approximates
device time — compare wall-clock across the ``run`` span instead. A
``compile`` span can also be near-instant when the executable was
deserialized from a persistent cache dir
(``EngineCache(persist_dir=...)``): the span still marks the first
trace, but XLA loads instead of compiling.
"""
from __future__ import annotations

import contextlib
import time
from typing import Any


class Tracer:
    """Nested span tracer with an optional JSONL sink.

    ``span(name, **attrs)`` is a context manager; spans nest via an
    explicit stack, so every record carries its ``parent`` and
    ``depth``. ``event(name, **attrs)`` records a point event. All
    records are kept in memory (``spans`` / ``events``) and mirrored to
    ``sink`` when one is attached.
    """

    def __init__(self, sink=None, clock=time.perf_counter):
        self.sink = sink
        self.clock = clock
        self.spans: list[dict] = []
        self.events: list[dict] = []
        self._stack: list[str] = []
        self._t0 = clock()

    @contextlib.contextmanager
    def span(self, name: str, **attrs: Any):
        t0 = self.clock()
        parent = self._stack[-1] if self._stack else None
        self._stack.append(name)
        try:
            yield self
        finally:
            self._stack.pop()
            rec = {"type": "span", "name": name, "parent": parent,
                   "depth": len(self._stack), "t0_s": t0 - self._t0,
                   "dur_s": self.clock() - t0, **attrs}
            self.spans.append(rec)
            if self.sink is not None:
                self.sink.emit(rec)

    def event(self, name: str, **attrs: Any) -> dict:
        rec = {"type": "event", "name": name,
               "t_s": self.clock() - self._t0, **attrs}
        self.events.append(rec)
        if self.sink is not None:
            self.sink.emit(rec)
        return rec

    def rollup(self) -> dict:
        """Aggregate timing per span name: ``{name: {count, total_s}}``
        plus event counts — the ``RunManifest`` timing payload."""
        out: dict[str, dict] = {}
        for rec in self.spans:
            slot = out.setdefault(rec["name"],
                                  {"count": 0, "total_s": 0.0})
            slot["count"] += 1
            slot["total_s"] += rec["dur_s"]
        ev: dict[str, int] = {}
        for rec in self.events:
            ev[rec["name"]] = ev.get(rec["name"], 0) + 1
        return {"spans": out, "events": ev}


def maybe_profile(profile_dir):
    """Optional ``jax.profiler`` trace hook: a context manager writing a
    device trace under ``profile_dir`` when the profiler is available,
    and a no-op otherwise (never fails a run over a missing backend)."""
    if not profile_dir:
        return contextlib.nullcontext()
    try:
        import jax.profiler
        return jax.profiler.trace(str(profile_dir))
    except Exception:
        return contextlib.nullcontext()
