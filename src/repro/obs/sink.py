"""Sinks: JSONL event log + the run manifest written next to results.

One line = one JSON record is the single on-disk telemetry format for
the whole repo: engine spans, cache events, per-segment metric frames
and serving SLO spans all flow through :class:`JsonlSink`, so any
driver's trace can be replayed with :func:`read_jsonl` and joined on
the shared ``type``/``name`` fields.

:class:`RunManifest` is the "what exactly ran" record every result file
should sit next to: the static config fingerprint (sha1 over the
``EngineSpec`` repr — the same statics that key the compile cache), the
run settings (preset / topo / obs), the tracer's timing rollup and the
compile-cache stats. ``run_experiment`` writes one per run (when an
``Obs`` with an ``out_dir`` is attached), ``run_sweep`` writes one next
to its JSON output, and :func:`bench_stamp` embeds the same fingerprint
into every ``BENCH_*.json`` via ``benchmarks/common.write_bench``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
import time
import warnings
from typing import Any


def fingerprint(obj: Any) -> str:
    """Stable content hash of any JSON-ish object (non-serializable
    leaves fall back to ``repr`` via ``default=repr``)."""
    text = json.dumps(obj, sort_keys=True, default=repr)
    return hashlib.sha1(text.encode()).hexdigest()


class JsonlSink:
    """Append-structured JSONL writer. Opens lazily, flushes per record
    (a crashed run keeps every event up to the crash), and works as a
    context manager. ``mode="w"`` (default) starts a fresh log per sink;
    pass ``mode="a"`` to extend an existing one."""

    def __init__(self, path, mode: str = "w"):
        self.path = pathlib.Path(path)
        self._mode = mode
        self._fh = None
        self.n_emitted = 0

    def emit(self, record: dict) -> None:
        if self._fh is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = self.path.open(self._mode)
        self._fh.write(json.dumps(record, default=repr) + "\n")
        self._fh.flush()
        self.n_emitted += 1

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_jsonl(path) -> list[dict]:
    """Load a JSONL event log back into a list of dicts (empty when the
    file was never written — a sink with zero events opens no file).

    A hard kill mid-``write`` leaves a truncated FINAL line; that line
    is skipped with a warning so a crashed run's trace still replays.
    A malformed line anywhere else means real corruption and raises.
    """
    p = pathlib.Path(path)
    if not p.exists():
        return []
    lines = [(i, ln) for i, ln in enumerate(p.read_text().splitlines(), 1)
             if ln.strip()]
    records = []
    for pos, (lineno, ln) in enumerate(lines):
        try:
            records.append(json.loads(ln))
        except json.JSONDecodeError:
            if pos == len(lines) - 1:
                warnings.warn(
                    f"{p}: skipping truncated final line {lineno} "
                    "(interrupted write)", RuntimeWarning, stacklevel=2)
                break
            raise
    return records


@dataclasses.dataclass
class RunManifest:
    """What ran, keyed how, and where the time went.

    Every field carries a default and :meth:`load` drops unknown keys,
    so old manifests read under a grown schema (missing keys default)
    and new manifests read under an old one (extra keys ignored) —
    schema growth never ``TypeError``s a replay.
    """
    kind: str = "run"           # run | sweep | bench | serve
    name: str = ""              # e.g. "facade-seed0"
    fingerprint: str = ""       # sha1 over the static spec/config repr
    spec: str = ""              # repr of the EngineSpec / config object
    settings: dict = dataclasses.field(default_factory=dict)
    timing: dict = dataclasses.field(default_factory=dict)
    cache: "dict | None" = None   # EngineCache.stats() snapshot
    health: "dict | None" = None  # HealthReport.to_json() verdict
    created_unix: float = 0.0
    jax_version: str = ""

    @classmethod
    def build(cls, kind: str, name: str, spec: Any, settings: dict,
              timing: "dict | None" = None,
              cache: "dict | None" = None,
              health: "dict | None" = None) -> "RunManifest":
        import jax
        return cls(kind=kind, name=name,
                   fingerprint=fingerprint(repr(spec)), spec=repr(spec),
                   settings=settings, timing=timing or {}, cache=cache,
                   health=health,
                   created_unix=time.time(), jax_version=jax.__version__)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def save(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=2, default=repr))
        return path

    @classmethod
    def load(cls, path) -> "RunManifest":
        data = json.loads(pathlib.Path(path).read_text())
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in data.items() if k in known})


def bench_stamp(name: str, payload: dict) -> dict:
    """The manifest block ``benchmarks/common.write_bench`` stamps into
    every ``BENCH_*.json``: a content fingerprint of the payload plus
    enough environment to tell two benchmark runs apart."""
    import jax
    return {"name": name, "fingerprint": fingerprint(payload),
            "jax_version": jax.__version__, "created_unix": time.time()}
