"""Run-health monitoring: a declarative rule engine over the telemetry
streams.

Until this module, nothing watched a run: a NaN-corrupted mixture, a
quarantine storm, a cluster assignment that never settles or an
accuracy collapse all ran to completion and produced a silently-wrong
results table. The monitor closes that gap by judging the two streams
observability already records — the per-round :class:`MetricsFrame`
table and the per-eval :class:`EvalFrame` table — against a small set
of declarative rules, entirely on the host AFTER the run (it never
enters compiled code, never forks a cache key, never perturbs a
trajectory).

Each rule is a pure function ``(HealthConfig, HealthContext, frames,
evals) -> [HealthIssue]`` registered under a name; every issue carries
a severity and the ROUND RANGE it covers, fires a ``health.<rule>``
tracer event, and rolls up into a :class:`HealthReport` whose verdict
(``ok`` < ``warn`` < ``fail``) is embedded in the run's
:class:`~repro.obs.sink.RunManifest` (``manifest.health``) and, per
cell, in ``run_sweep``'s JSON.

Adding a rule (the ROADMAP "Observability contract v2" recipe)::

    @rule("my_rule")
    def _my_rule(cfg, ctx, frames, evals):
        rounds = frames["round"]
        if rounds.size == 0:          # stream not recorded: stay silent
            return []
        bad = frames["delivered_edges"] < 1
        return [_range_issue("my_rule", "warn", rounds, bad,
                             detail="no edges delivered")]

Rules must tolerate EMPTY tables (a run without a device ``ObsConfig``
has no metrics frames; a ``target_acc`` run may stop after one eval)
and must key thresholds off :class:`HealthConfig` so a deployment can
tune or ``disable`` them without code changes. Context that only the
driver knows (node count, warmup length, the topo fairness floor,
whether faults were injected) arrives via :class:`HealthContext`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

SEVERITY_ORDER = {"ok": 0, "warn": 1, "fail": 2}


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Thresholds for the built-in rules. Host-side only: never part of
    any cache key, changing it recompiles nothing."""
    norm_max: float = 1e6        # |update|/|param| beyond this = divergence
    quarantine_frac: float = 0.5  # (crashed+quarantined)/n spike threshold
    inclusion_slack: float = 0.05  # tolerated mean-inclusion shortfall
    #                                below the topo min_inclusion floor
    flap_frac: float = 0.25      # mean switches/n past warmup+grace = flap
    flap_grace: int = 8          # settling rounds granted after warmup
    stall_evals: int = 5         # window (in evals) for the stall test
    stall_tol: float = 1e-3      # improvement below this = stalled
    stall_acc: float = 0.5       # ...but only while accuracy is this low
    collapse_drop: float = 0.25  # absolute drop from the running peak
    collapse_min_peak: float = 0.4  # peaks below this never "collapse"
    disable: tuple = ()          # rule names to skip

    def __post_init__(self):
        unknown = set(self.disable) - set(RULES)
        if unknown:
            raise ValueError(
                f"disable names unknown health rules {sorted(unknown)}; "
                f"know {sorted(RULES)}")


@dataclasses.dataclass(frozen=True)
class HealthContext:
    """What the driver knows about the run that the tables don't say."""
    n: int                                # node count
    warmup_rounds: int = 0                # FACADE warmup length
    inclusion_floor: "float | None" = None  # topo min_inclusion when an
    #                                         adaptive policy guaranteed one
    faults: bool = False                  # fault injection was configured


@dataclasses.dataclass
class HealthIssue:
    """One rule firing over one round range."""
    rule: str
    severity: str        # "warn" | "fail"
    round_start: int
    round_end: int
    value: float         # the offending measurement (rule-specific)
    detail: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class HealthReport:
    """Per-run rollup: the worst severity across every issue."""
    verdict: str         # "ok" | "warn" | "fail"
    issues: list         # [HealthIssue], sorted by round_start
    rounds_seen: int     # metrics frames examined
    evals_seen: int      # eval frames examined

    def to_json(self) -> dict:
        return {"verdict": self.verdict,
                "issues": [i.to_json() for i in self.issues],
                "rounds_seen": self.rounds_seen,
                "evals_seen": self.evals_seen}

    @classmethod
    def from_json(cls, data: dict) -> "HealthReport":
        return cls(verdict=data.get("verdict", "ok"),
                   issues=[HealthIssue(**i)
                           for i in data.get("issues", ())],
                   rounds_seen=int(data.get("rounds_seen", 0)),
                   evals_seen=int(data.get("evals_seen", 0)))


def worst_verdict(verdicts) -> str:
    """The most severe of a collection of verdict strings (unknown
    strings rank as ``fail`` — a garbled verdict is not a clean one)."""
    worst = "ok"
    for v in verdicts:
        rank = SEVERITY_ORDER.get(v, SEVERITY_ORDER["fail"])
        if rank > SEVERITY_ORDER[worst]:
            worst = v if v in SEVERITY_ORDER else "fail"
    return worst


# ---------------------------------------------------------------- rules --
RULES: "dict[str, Callable]" = {}


def rule(name: str):
    """Register a health rule under ``name`` (fires ``health.<name>``)."""
    def deco(fn):
        RULES[name] = fn
        return fn
    return deco


def _mask_issues(name, severity, rounds, mask, value_of, detail):
    """One :class:`HealthIssue` per CONTIGUOUS run of ``mask`` — rules
    report round ranges, not per-round spam."""
    issues = []
    idx = np.flatnonzero(np.asarray(mask))
    if idx.size == 0:
        return issues
    splits = np.split(idx, np.flatnonzero(np.diff(idx) > 1) + 1)
    for grp in splits:
        issues.append(HealthIssue(
            rule=name, severity=severity,
            round_start=int(rounds[grp[0]]), round_end=int(rounds[grp[-1]]),
            value=float(value_of(grp)), detail=detail))
    return issues


@rule("nonfinite")
def _nonfinite(cfg, ctx, frames, evals):
    """NaN/inf update or param norms: the model state itself is poisoned
    (e.g. unguarded NaN corruption, ``repro.resil``)."""
    rounds = frames["round"]
    if rounds.size == 0:
        return []
    un, pn = frames["update_norm"], frames["param_norm"]
    bad = ~(np.isfinite(un) & np.isfinite(pn))
    return _mask_issues(
        "nonfinite", "fail", rounds, bad,
        lambda grp: np.sum(bad[grp]),
        "non-finite update/param norm: model state is poisoned")


@rule("divergence")
def _divergence(cfg, ctx, frames, evals):
    """Finite but runaway norms — the optimizer is blowing up."""
    rounds = frames["round"]
    if rounds.size == 0:
        return []
    un, pn = frames["update_norm"], frames["param_norm"]
    bad = (np.isfinite(un) & np.isfinite(pn)
           & ((un > cfg.norm_max) | (pn > cfg.norm_max)))
    return _mask_issues(
        "divergence", "fail", rounds, bad,
        lambda grp: max(np.max(un[grp]), np.max(pn[grp])),
        f"update/param norm exceeded norm_max={cfg.norm_max:g}")


@rule("quarantine_spike")
def _quarantine_spike(cfg, ctx, frames, evals):
    """Crash/quarantine mass above ``quarantine_frac`` of the nodes —
    the resilient path is carrying more faults than it was sized for."""
    rounds = frames["round"]
    if rounds.size == 0 or ctx.n <= 0:
        return []
    frac = (frames["crashed"] + frames["quarantined"]) / float(ctx.n)
    bad = frac > cfg.quarantine_frac
    return _mask_issues(
        "quarantine_spike", "warn", rounds, bad,
        lambda grp: np.max(frac[grp]),
        f"crashed+quarantined above {cfg.quarantine_frac:.0%} of nodes")


@rule("inclusion_floor")
def _inclusion_floor(cfg, ctx, frames, evals):
    """Mean inclusion below the topo ``min_inclusion`` guarantee (with
    ``inclusion_slack`` for per-round sampling noise) — the fairness
    floor the adaptive policy promised is not being delivered."""
    rounds = frames["round"]
    if rounds.size == 0 or ctx.inclusion_floor is None:
        return []
    tail = rounds > ctx.warmup_rounds
    if not np.any(tail):
        return []
    mean_inc = float(np.mean(frames["inclusion"][tail]))
    if mean_inc >= ctx.inclusion_floor - cfg.inclusion_slack:
        return []
    return [HealthIssue(
        rule="inclusion_floor", severity="warn",
        round_start=int(rounds[tail][0]), round_end=int(rounds[-1]),
        value=mean_inc,
        detail=(f"mean inclusion {mean_inc:.3f} below the topo floor "
                f"{ctx.inclusion_floor:.3f} (slack "
                f"{cfg.inclusion_slack:.3f})"))]


@rule("cluster_flapping")
def _cluster_flapping(cfg, ctx, frames, evals):
    """Cluster assignment still churning past warmup + grace — FACADE's
    settlement (paper Fig. 9) never happened."""
    rounds = frames["round"]
    if rounds.size == 0 or ctx.n <= 0:
        return []
    tail = rounds > ctx.warmup_rounds + cfg.flap_grace
    if not np.any(tail):
        return []
    mean_flap = float(np.mean(frames["cluster_switches"][tail])) / ctx.n
    if mean_flap <= cfg.flap_frac:
        return []
    return [HealthIssue(
        rule="cluster_flapping", severity="warn",
        round_start=int(rounds[tail][0]), round_end=int(rounds[-1]),
        value=mean_flap,
        detail=(f"mean cluster switches {mean_flap:.2f}/node/round past "
                f"warmup+{cfg.flap_grace} rounds (threshold "
                f"{cfg.flap_frac:.2f})"))]


@rule("accuracy_stall")
def _accuracy_stall(cfg, ctx, frames, evals):
    """No improvement over the last ``stall_evals`` evals while accuracy
    is still low — the run is burning rounds without learning."""
    rounds = evals["round"]
    if rounds.size < cfg.stall_evals:
        return []
    window = evals["mean_acc"][-cfg.stall_evals:]
    if not np.all(np.isfinite(window)):
        return []           # nonfinite rule owns poisoned runs
    improvement = float(window[-1] - window[0])
    if improvement >= cfg.stall_tol or window[-1] >= cfg.stall_acc:
        return []
    return [HealthIssue(
        rule="accuracy_stall", severity="warn",
        round_start=int(rounds[-cfg.stall_evals]), round_end=int(rounds[-1]),
        value=float(window[-1]),
        detail=(f"mean accuracy {window[-1]:.3f} improved "
                f"{improvement:+.4f} over the last {cfg.stall_evals} "
                f"evals (tol {cfg.stall_tol:g})"))]


@rule("accuracy_collapse")
def _accuracy_collapse(cfg, ctx, frames, evals):
    """Accuracy fell ``collapse_drop`` below its running peak — the run
    learned something and then lost it (divergence, poisoning, a bad
    restart)."""
    rounds = evals["round"]
    if rounds.size == 0:
        return []
    acc = np.where(np.isfinite(evals["mean_acc"]), evals["mean_acc"], 0.0)
    peak = np.maximum.accumulate(acc)
    bad = (peak >= cfg.collapse_min_peak) & (peak - acc >= cfg.collapse_drop)
    return _mask_issues(
        "accuracy_collapse", "fail", rounds, bad,
        lambda grp: np.max((peak - acc)[grp]),
        f"mean accuracy dropped >= {cfg.collapse_drop:g} below its peak")


# ------------------------------------------------------------- evaluate --
def evaluate(cfg: HealthConfig, ctx: HealthContext, frames: dict,
             evals: dict, tracer=None) -> HealthReport:
    """Run every (non-disabled) rule over the two tables, fire one
    ``health.<rule>`` tracer event per issue, and roll up the verdict.

    ``frames``: an ``Obs.frames_table()``-shaped dict (``round`` may be
    empty when no device ``ObsConfig`` was attached); ``evals``: an
    ``Obs.eval_table()``-shaped dict.
    """
    issues = []
    for name, fn in RULES.items():
        if name in cfg.disable:
            continue
        issues.extend(fn(cfg, ctx, frames, evals))
    issues.sort(key=lambda i: (i.round_start, i.rule))
    if tracer is not None:
        for i in issues:
            tracer.event(f"health.{i.rule}", severity=i.severity,
                         round_start=i.round_start, round_end=i.round_end,
                         value=i.value, detail=i.detail)
    return HealthReport(
        verdict=worst_verdict(i.severity for i in issues),
        issues=issues,
        rounds_seen=int(np.asarray(frames["round"]).size),
        evals_seen=int(np.asarray(evals["round"]).size))
