"""Latency/bandwidth cost model: bytes + topology -> simulated seconds.

A synchronous gossip round finishes when the slowest active node has both
(a) run its H local steps and (b) completed its slowest link exchange.
Stragglers multiply their compute AND any link touching them (a slow
uploader delays the receiver too). The result feeds ``CommLog``'s time
axis so benchmarks can report "simulated hours to target accuracy", the
companion to the paper's Fig. 7 "GB to target accuracy".

With heterogeneous link classes (``cfg.classes``), the per-link base time
comes from ``[n, n]`` latency/bandwidth matrices (:func:`link_matrices`)
instead of the uniform scalars: a link runs at its worse endpoint — max
latency, min bandwidth. Under asynchronous gossip, stale nodes do not
gate the round (their compute overlaps the next rounds); the caller
expresses that by zeroing their entry in ``active`` (see
``netwire.round_seconds``).
"""
from __future__ import annotations

import jax.numpy as jnp

from . import conditions as conditions_mod


def link_seconds(cfg, payload_bytes):
    """One-message transfer time on a clean link (latency + serialization).
    ``payload_bytes`` may be a python number or a traced jax scalar."""
    return cfg.latency_s + 8.0 * payload_bytes / cfg.bandwidth_bps


def link_matrices(cfg, n: int):
    """Per-link ``(latency [n, n], bandwidth [n, n])`` from the node tier
    assignment — symmetric, each link at its worse endpoint's class.
    Requires ``cfg.classes``; the scalar path never builds matrices."""
    cl = cfg.classes
    tiers = conditions_mod.node_tiers(cfg, n)
    lat = jnp.where(tiers > 0, cl.edge_latency_s, cl.core_latency_s)
    bw = jnp.where(tiers > 0, cl.edge_bandwidth_bps, cl.core_bandwidth_bps)
    return (jnp.maximum(lat[:, None], lat[None, :]),
            jnp.minimum(bw[:, None], bw[None, :]))


def round_time(cfg, adj_eff, payload_bytes, active, straggler,
               local_steps: int):
    """Simulated wall-clock seconds for one synchronous round.

    adj_eff  [n, n]: effective (post-churn/post-drop) adjacency;
    active    [n]:   {0,1} gate mask (offline — and, under async gossip,
                     stale — nodes don't gate the round);
    straggler [n]:   {0,1} mask from this round's conditions.
    An empty round (everyone churned out) costs 0 seconds.
    """
    adj_eff = jnp.asarray(adj_eff, jnp.float32)
    active = jnp.asarray(active, jnp.float32)
    straggler = jnp.asarray(straggler, jnp.float32)
    slow = 1.0 + (cfg.straggler_slowdown - 1.0) * straggler        # [n]
    if cfg.classes is None:
        base_link = link_seconds(cfg, payload_bytes)               # scalar
    else:
        lat, bw = link_matrices(cfg, adj_eff.shape[0])
        base_link = lat + 8.0 * payload_bytes / bw                 # [n, n]
    # link (i, j) runs at the slower endpoint's pace; links run in parallel
    pair_slow = jnp.maximum(slow[:, None], slow[None, :])          # [n, n]
    comm = (adj_eff * pair_slow * base_link).max(axis=1)           # [n]
    compute = local_steps * cfg.compute_s_per_step * slow          # [n]
    return jnp.max((compute + comm) * active, initial=0.0)
