"""Seeded, round-indexed network event schedules.

Stochastic conditions (conditions.py) model steady-state weather; events
model *scenarios*: a rack loses power at round 40, the network partitions
into two halves for 30 rounds and heals. Each event's victim set / group
assignment is drawn once from ``fold_in(seed, event index)`` — NOT from the
round — so the same nodes stay down for the whole window and the schedule
replays identically under a fixed seed.

All masks are computed with ``jnp.where`` on a traced round index, so the
schedule is jit-compatible (events are static config; the round is data).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_EVENT_TAG = 1000  # keeps event streams disjoint from conditions.py streams


@dataclasses.dataclass(frozen=True)
class BurstFailure:
    """A random ``fraction`` of nodes goes dark for rounds
    [start, start + duration)."""
    start: int
    duration: int
    fraction: float


@dataclasses.dataclass(frozen=True)
class Partition:
    """The network splits into ``groups`` random camps for rounds
    [start, start + duration): links across camps drop every message, links
    inside a camp are untouched. Then it heals."""
    start: int
    duration: int
    groups: int = 2


def _event_key(seed: int, idx: int):
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), _EVENT_TAG), idx)


def event_masks(seed: int, events: tuple, n: int, rnd):
    """(avail [n], edge_ok [n, n]) float32 {0,1} masks for round ``rnd``;
    all-ones when no event window covers the round."""
    avail = jnp.ones((n,), jnp.float32)
    edge_ok = jnp.ones((n, n), jnp.float32)
    for idx, ev in enumerate(events):
        if not isinstance(ev, (BurstFailure, Partition)):
            raise TypeError(f"unknown netsim event {type(ev).__name__}")
        key = _event_key(seed, idx)
        in_window = jnp.logical_and(rnd >= ev.start,
                                    rnd < ev.start + ev.duration)
        if isinstance(ev, BurstFailure):
            up = (jax.random.uniform(key, (n,)) >= ev.fraction)
            up = up.astype(jnp.float32)
            avail = avail * jnp.where(in_window, up, 1.0)
        elif isinstance(ev, Partition):
            camp = jax.random.randint(key, (n,), 0, ev.groups)
            same = (camp[:, None] == camp[None, :]).astype(jnp.float32)
            edge_ok = edge_ok * jnp.where(in_window, same, 1.0)
    return avail, edge_ok
