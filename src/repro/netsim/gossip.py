"""Asynchronous stale gossip: stragglers serve snapshots, not stalls.

The synchronous model makes every straggler stretch the round: the whole
fleet waits for the slowest node. Real asynchronous gossip does the
opposite — a slow node keeps computing in the background while its
neighbors reuse the last model it *published*. This module is the state
machine behind that mode (``NetworkConfig(async_gossip=True)``):

* :class:`GossipState` — the staleness buffer carried through the
  engine's ``lax.scan`` (or the legacy Python loop): ``published`` holds
  every node's last finished mixable state (params for the baselines;
  cores/heads/cluster-id for FACADE) and ``age[n]`` counts rounds since
  each node last published. Both live on device; no host syncs.
* Per round, a straggling node *stays stale* while ``age + 1 <=
  cfg.max_staleness``: its neighbors mix against ``published`` (see
  ``bindings.gossip_mix``), it sends no fresh bytes, and it does not gate
  the simulated round time. Once the cap is hit it must catch up — it
  publishes fresh state and gates the round like a synchronous straggler.
* ``max_staleness=0`` therefore forces every node fresh every round:
  the async path is bit-for-bit the synchronous path (mixing, bytes AND
  simulated seconds) — the parity contract ``tests/test_netsim.py`` and
  ``tests/test_engine.py`` pin for all five algorithms.

The node's own training is never stale: a straggler keeps advancing its
local state (background compute); only what its neighbors observe lags.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class GossipState(NamedTuple):
    """Staleness buffer, one entry per node (leading ``n`` axis)."""
    published: Any       # pytree: each node's last published mixable state
    age: Any             # [n] int32: rounds since the node last published


def tree_select(mask, when_on, when_off):
    """Per-node select along the leading axis: ``mask[i] > 0`` picks
    ``when_on``'s node-i leaves, else ``when_off``'s. Shared by the
    staleness machinery and ``netwire.stale_view``."""
    def pick(a, b):
        m = mask.reshape((mask.shape[0],) + (1,) * (a.ndim - 1))
        return jnp.where(m > 0, a, b).astype(a.dtype)
    return jax.tree.map(pick, when_on, when_off)


def init_gossip(cfg, n: int, mixable):
    """Fresh buffer from the run's initial state (``None`` when async
    gossip is off). ``mixable`` is copied leaf-for-leaf so the buffer
    never aliases the (donated) training state."""
    if cfg is None or not cfg.async_gossip:
        return None
    published = jax.tree.map(jnp.copy, mixable)
    return GossipState(published=published,
                       age=jnp.zeros((n,), jnp.int32))


def stale_mask(cfg, conds, gossip):
    """{0,1} [n]: 1 where the node stays stale this round — it is a
    straggler AND its snapshot would still be within ``max_staleness``."""
    within = (gossip.age + 1 <= cfg.max_staleness)
    return (conds.straggler * within).astype(jnp.float32)


def apply_async(cfg, conds, gossip):
    """Pre-round hook for both drivers: returns ``(conds', published)``.

    With async gossip on, ``conds'`` carries the round's ``stale`` mask
    and ``published`` is the buffer tree to hand the round function
    (``gossip=`` kwarg). Otherwise the conditions pass through untouched
    and ``published`` is None — the synchronous code path.
    """
    if cfg is None or gossip is None or not cfg.async_gossip:
        return conds, None
    return (conds._replace(stale=stale_mask(cfg, conds, gossip)),
            gossip.published)


def fold_gossip(cfg, gossip, conds, new_mixable):
    """Post-round hook: nodes that stayed stale keep their old snapshot
    and age by one; everyone else publishes the round's fresh mixable
    state and resets to age 0."""
    if gossip is None:
        return None
    stay = conds.stale
    published = tree_select(stay, gossip.published, new_mixable)
    age = jnp.where(stay > 0, gossip.age + 1, 0).astype(jnp.int32)
    return GossipState(published=published, age=age)
