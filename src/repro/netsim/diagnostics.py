"""Empirical diagnostics over the simulated network models.

The Gilbert–Elliott channel and the tiered link matrices make claims
(stationary loss rate, mean burst length, worst-endpoint link classes)
that tests and benchmark smokes want to check against *measured*
behavior. This module rolls the actual engine code path — one
``lax.scan`` over :func:`repro.netsim.advance_conditions` — and reduces
it to host-side statistics. Used by ``tests/test_property.py``
(hypothesis sweeps), ``tests/test_netsim.py`` (fixed-seed spot checks)
and the dry-run netsim-v2 smoke.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import conditions as conditions_mod


def channel_stats(cfg, n: int, rounds: int) -> dict:
    """Roll the bursty channel for ``rounds`` rounds and measure it.

    Returns a dict with the empirical per-link ``bad_rate`` and
    ``loss_rate``, the ``mean_burst_len`` over completed bad bursts
    (NaN when no burst completed), ``n_bursts``, and the structural
    flags ``symmetric`` / ``binary`` over every round's edge mask.
    One device->host transfer; the scan is the engine's exact path.
    """
    chan0 = conditions_mod.init_channel(cfg, n)

    def step(chan, rnd):
        conds, chan = conditions_mod.advance_conditions(cfg, n, rnd, chan)
        bad = (chan.bad if chan is not None
               else jnp.zeros((n, n), jnp.float32))
        return chan, (bad, conds.edge_mask)

    _, (bads, masks) = jax.lax.scan(step, chan0,
                                    jnp.arange(rounds, dtype=jnp.int32))
    bads, masks = np.asarray(bads), np.asarray(masks)

    iu = np.triu_indices(n, 1)
    bad_seq = bads[:, iu[0], iu[1]]                    # [rounds, links]
    lost_seq = 1.0 - masks[:, iu[0], iu[1]]

    lengths = []
    for link in bad_seq.T:
        run = 0
        for b in link:
            if b > 0:
                run += 1
            elif run:
                lengths.append(run)
                run = 0
    return {
        "bad_rate": float(bad_seq.mean()),
        "loss_rate": float(lost_seq.mean()),
        "mean_burst_len": float(np.mean(lengths)) if lengths else float("nan"),
        "n_bursts": len(lengths),
        "symmetric": bool((masks == np.swapaxes(masks, 1, 2)).all()
                          and (bads == np.swapaxes(bads, 1, 2)).all()),
        "binary": bool(set(np.unique(masks)) <= {0.0, 1.0}
                       and set(np.unique(bads)) <= {0.0, 1.0}),
    }
