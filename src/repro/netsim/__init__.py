"""repro.netsim — network-condition simulation for decentralized learning.

The core algorithms model gossip over a free, instantaneous, perfectly
reliable medium. This subsystem makes the medium a first-class simulated
object so every algorithm (FACADE and all four baselines) can run under
realistic conditions without per-algorithm forks:

* :mod:`.conditions` — ``NetworkConfig`` (presets ``ideal`` / ``lan`` /
  ``wan`` / ``edge-churn`` / ``hostile``) and ``round_conditions``: per-round
  edge-drop, node-churn (join/leave schedules) and straggler masks;
* :mod:`.timing` — a latency/bandwidth cost model turning per-round bytes +
  effective topology into simulated wall-clock seconds (max over the
  slowest active node/link), recorded on ``CommLog``'s time axis;
* :mod:`.events` — seeded round-indexed scenarios (``BurstFailure``,
  ``Partition``) for reproducible adversarial runs.

Usage — every algorithm composes with every condition::

    from repro.core.runner import run_experiment
    from repro.netsim import NetworkConfig

    res = run_experiment("facade", cfg, ds, rounds=100,
                         net=NetworkConfig.preset("edge-churn"))
    res.comm.total_gb         # cumulative traffic, as before
    res.comm.total_hours      # NEW: simulated wall-clock to get there
    res.comm.seconds_to_target(0.8)

``net=None`` (the default) is the exact pre-netsim code path;
``net=NetworkConfig.preset("ideal")`` runs the netsim path with all-ones
masks and reproduces the same training trajectory bit-for-bit (byte
accounting under netsim counts *actual* surviving directed edges rather
than the nominal ``n * degree`` upper bound).

netsim v2 adds three axes, all carried on device through the engine's
scan (presets ``bursty-wan`` / ``core-edge`` / ``async-edge`` /
``edge-v2``):

* bursty Gilbert–Elliott link loss (``burst=BurstConfig(...)``) — a
  per-link two-state Markov chain (:class:`ChannelState` in the carry)
  instead of i.i.d. drop coins;
* heterogeneous core/edge link tiers (``classes=LinkClasses(...)``) —
  per-link ``[n, n]`` latency/bandwidth matrices in the timing model;
* asynchronous stale gossip (``async_gossip=True``) — stragglers serve
  their last published snapshot (:mod:`.gossip`) instead of stretching
  the round; ``max_staleness=0`` is bit-identical to the sync path.
"""
from .conditions import (BurstConfig, ChannelState, LinkClasses,  # noqa: F401
                         NetworkConfig, PRESETS, RoundConditions,
                         advance_conditions, availability, edge_mask,
                         init_channel, node_tiers, round_conditions,
                         step_channel, straggler_mask)
from .diagnostics import channel_stats  # noqa: F401
from .events import BurstFailure, Partition, event_masks  # noqa: F401
from .gossip import (GossipState, apply_async, fold_gossip,  # noqa: F401
                     init_gossip, stale_mask, tree_select)
from .timing import link_matrices, link_seconds, round_time  # noqa: F401
