"""Vectorized network-condition models (churn, message loss, stragglers,
bursty links, heterogeneous link tiers).

Everything here is jit-friendly: a :class:`NetworkConfig` is static
(hashable, closed over at trace time) and :func:`round_conditions` maps a
round index to a :class:`RoundConditions` pytree of dense masks that the
round functions in ``core/`` consume:

* ``edge_mask [n, n]``  — 1 where the link delivered this round's message
  (symmetric: gossip is push-pull, a lost exchange is lost both ways);
* ``active [n]``        — 1 where the node is online this round (churn);
* ``straggler [n]``     — 1 where the node is slow this round. Stragglers
  still train and gossip — in a synchronous round they only stretch the
  simulated wall-clock time (see :mod:`repro.netsim.timing`); under
  asynchronous gossip (``async_gossip=True``) they instead serve stale
  snapshots to their neighbors (see :mod:`repro.netsim.gossip`);
* ``stale [n]``         — 1 where the node's neighbors observe its stale
  published snapshot this round (async gossip only; ``None`` otherwise).

Churn is drawn per *outage block* (``round // outage_rounds``) rather than
per round, so an offline node stays offline for ``outage_rounds``
consecutive rounds — a join/leave schedule, not per-round coin flips.
All randomness derives from ``jax.random.fold_in`` on ``(seed, stream,
round)``, so a given config replays the exact same schedule forever.

Bursty loss (``burst=BurstConfig(...)``) replaces the i.i.d. ``drop_rate``
coin with a per-link two-state Gilbert–Elliott Markov chain: each
undirected link is either *good* (loss prob ``drop_good``) or *bad*
(loss prob ``drop_bad``); per round a good link turns bad with ``p_bad``
and a bad link recovers with ``p_recover``. The chain state is an
on-device :class:`ChannelState` carried through the engine's scan (or the
legacy Python loop) via :func:`init_channel` / :func:`advance_conditions`
— never synced to the host mid-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import events as events_mod

# per-stream fold_in tags (repro.topo takes 7, repro.resil 8-11,
# events.py 1000 — keep them disjoint)
_DROP, _CHURN, _STRAGGLE, _BURST, _BURST_INIT, _TIER = 1, 2, 3, 4, 5, 6


class RoundConditions(NamedTuple):
    """Dense per-round masks, all float32 in {0, 1}."""
    edge_mask: Any       # [n, n] symmetric; 1 = message delivered
    active: Any          # [n]    1 = node online
    straggler: Any       # [n]    1 = node slow this round
    stale: Any = None    # [n]    1 = neighbors see this node's stale
    #                      snapshot (async gossip); None when sync
    crashed: Any = None  # [n]    1 = node crashed (repro.resil fault
    #                      chain; already folded into ``active``); None
    #                      when the crash chain is off
    corrupt: Any = None  # [n]    1 = node ships a corrupted payload this
    #                      round (repro.resil); None when corruption off
    fault_key: Any = None  # PRNG key for this round's payload noise
    #                      (repro.resil.corrupt_view); None w/o corruption


class ChannelState(NamedTuple):
    """On-device Gilbert–Elliott state: ``bad [n, n]`` float32 {0, 1},
    symmetric, zero diagonal — 1 where the undirected link is in its bad
    (bursty-loss) state. Lives in the engine's scan carry."""
    bad: Any


@dataclasses.dataclass(frozen=True)
class BurstConfig:
    """Gilbert–Elliott two-state Markov link loss.

    Per round and per undirected link: a *good* link goes bad with
    ``p_bad``; a *bad* link recovers with ``p_recover``; messages drop
    with ``drop_good`` / ``drop_bad`` depending on the current state.
    Stationary bad fraction is ``p_bad / (p_bad + p_recover)`` and bad
    bursts last ``1 / p_recover`` rounds in expectation — the two
    invariants ``tests/test_property.py`` pins.
    """
    p_bad: float = 0.05
    p_recover: float = 0.5
    drop_good: float = 0.0
    drop_bad: float = 1.0

    def stationary_bad(self) -> float:
        return self.p_bad / max(self.p_bad + self.p_recover, 1e-12)

    def stationary_drop(self) -> float:
        pi = self.stationary_bad()
        return (1.0 - pi) * self.drop_good + pi * self.drop_bad


@dataclasses.dataclass(frozen=True)
class LinkClasses:
    """Heterogeneous node tiers: a fast ``core`` and a slow ``edge`` class.

    Node tier assignment is seeded and static per ``(cfg.seed, n)``
    (:func:`node_tiers`); a link runs at its worse endpoint — pairwise
    latency is the max, bandwidth the min, of the endpoint class values
    (:func:`repro.netsim.timing.link_matrices`).
    """
    edge_fraction: float = 0.5
    core_latency_s: float = 1e-3
    edge_latency_s: float = 8e-2
    core_bandwidth_bps: float = 1e9
    edge_bandwidth_bps: float = 2e7


@dataclasses.dataclass(frozen=True)
class NetworkConfig:
    """Static description of the simulated network.

    Presets (``NetworkConfig.preset(name)``): ``ideal`` (today's free
    perfect medium), ``lan``, ``wan``, ``edge-churn`` (flaky edge devices,
    the paper's motivating healthcare/edge deployment), ``hostile``
    (stress test: heavy loss + churn + stragglers).
    """
    name: str = "custom"
    drop_rate: float = 0.0           # P(undirected link loses this round's msg)
    churn_rate: float = 0.0          # P(node offline in an outage block)
    outage_rounds: int = 2           # length of one offline stretch (rounds)
    straggler_rate: float = 0.0      # P(node is slow this round)
    straggler_slowdown: float = 4.0  # compute/link time multiplier when slow
    latency_s: float = 1e-3          # per-link one-way latency (seconds)
    bandwidth_bps: float = 1e9       # per-link bandwidth (bytes/sec would be
                                     # bps/8; we keep bits/sec like specs do)
    compute_s_per_step: float = 0.05 # seconds per local SGD step (sim scale)
    seed: int = 0                    # netsim's own stream; independent of
                                     # the experiment seed by construction
    events: tuple = ()               # round-indexed scenario (events.py)
    burst: "BurstConfig | None" = None     # Gilbert–Elliott bursty loss;
                                     # None keeps the i.i.d. drop_rate coin
    classes: "LinkClasses | None" = None   # core/edge link tiers; None keeps
                                     # the uniform latency_s/bandwidth_bps
    async_gossip: bool = False       # stragglers serve stale snapshots
                                     # instead of stretching the round
    max_staleness: int = 3           # max rounds a straggler may lag before
                                     # it must publish fresh state; 0 makes
                                     # async_gossip bit-identical to sync
    faults: Any = None               # repro.resil.FaultConfig | None —
                                     # node crash/restart chain + payload
                                     # corruption; riding here makes every
                                     # FaultConfig field an EngineSpec
                                     # cache-key component for free

    @classmethod
    def preset(cls, name: str, **overrides) -> "NetworkConfig":
        if name not in PRESETS:
            raise ValueError(
                f"unknown netsim preset {name!r}; know {sorted(PRESETS)}")
        kw = dict(PRESETS[name])
        kw.update(overrides)
        return cls(name=name, **kw)


PRESETS: dict[str, dict] = {
    # today's implicit model: free, instantaneous, perfectly reliable
    "ideal": dict(drop_rate=0.0, churn_rate=0.0, straggler_rate=0.0,
                  latency_s=0.0, bandwidth_bps=1e15),
    # one rack: fast links, the odd busy machine
    "lan": dict(drop_rate=0.0, churn_rate=0.0, straggler_rate=0.05,
                straggler_slowdown=2.0, latency_s=5e-4, bandwidth_bps=10e9),
    # cross-datacenter gossip
    "wan": dict(drop_rate=0.01, churn_rate=0.02, straggler_rate=0.10,
                straggler_slowdown=4.0, latency_s=5e-2, bandwidth_bps=1e8),
    # flaky phones/hospital workstations joining and leaving
    "edge-churn": dict(drop_rate=0.05, churn_rate=0.20, outage_rounds=3,
                       straggler_rate=0.20, straggler_slowdown=6.0,
                       latency_s=8e-2, bandwidth_bps=2e7),
    # stress test for cluster-assignment stability
    "hostile": dict(drop_rate=0.25, churn_rate=0.35, outage_rounds=4,
                    straggler_rate=0.30, straggler_slowdown=10.0,
                    latency_s=2e-1, bandwidth_bps=5e6),
    # --- netsim v2 ---------------------------------------------------------
    # cross-datacenter gossip whose loss comes in bursts, not i.i.d. coins
    "bursty-wan": dict(churn_rate=0.02, straggler_rate=0.10,
                       straggler_slowdown=4.0, latency_s=5e-2,
                       bandwidth_bps=1e8,
                       burst=BurstConfig(p_bad=0.15, p_recover=0.5,
                                         drop_good=0.005, drop_bad=0.9)),
    # fast datacenter core + slow edge devices: per-link latency/bandwidth
    "core-edge": dict(drop_rate=0.02, straggler_rate=0.15,
                      straggler_slowdown=4.0,
                      classes=LinkClasses(edge_fraction=0.5,
                                          core_latency_s=1e-3,
                                          edge_latency_s=8e-2,
                                          core_bandwidth_bps=1e9,
                                          edge_bandwidth_bps=2e7)),
    # flaky edge fleet where stragglers gossip stale updates asynchronously
    # instead of stretching the synchronous round
    "async-edge": dict(drop_rate=0.05, churn_rate=0.10, outage_rounds=3,
                       straggler_rate=0.25, straggler_slowdown=6.0,
                       latency_s=8e-2, bandwidth_bps=2e7,
                       async_gossip=True, max_staleness=3),
    # everything at once: bursty links, core/edge tiers, async stale gossip
    "edge-v2": dict(churn_rate=0.10, outage_rounds=3, straggler_rate=0.25,
                    straggler_slowdown=6.0,
                    burst=BurstConfig(p_bad=0.10, p_recover=0.4,
                                      drop_good=0.01, drop_bad=0.8),
                    classes=LinkClasses(edge_fraction=0.5,
                                        core_latency_s=1e-3,
                                        edge_latency_s=8e-2,
                                        core_bandwidth_bps=1e9,
                                        edge_bandwidth_bps=2e7),
                    async_gossip=True, max_staleness=3),
}


# --------------------------------------------------------------------------
def _stream(cfg: NetworkConfig, tag: int, rnd):
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), tag), rnd)


def _sym_uniform(key, n: int):
    """One uniform coin per undirected edge, mirrored to [n, n] (diag 0)."""
    u = jax.random.uniform(key, (n, n))
    upper = jnp.triu(u, 1)
    return upper + upper.T


# ------------------------------------------------------- bursty channel ---
def init_channel(cfg: "NetworkConfig | None", n: int):
    """Initial Gilbert–Elliott state, drawn from the stationary
    distribution (seeded, so the schedule replays). ``None`` when bursty
    loss is off — the chain then costs nothing in the carry."""
    if cfg is None or cfg.burst is None:
        return None
    pi = cfg.burst.stationary_bad()
    u = _sym_uniform(_stream(cfg, _BURST_INIT, 0), n)
    bad = (u < pi).astype(jnp.float32) * (1.0 - jnp.eye(n))
    return ChannelState(bad=bad)


def step_channel(cfg: "NetworkConfig | None", n: int, rnd, chan):
    """Advance every link's two-state chain by one round (symmetric: one
    transition coin per undirected edge)."""
    if cfg is None or cfg.burst is None:
        return None
    if chan is None:
        chan = init_channel(cfg, n)
    u = _sym_uniform(_stream(cfg, _BURST, rnd), n)
    stay_bad = u < (1.0 - cfg.burst.p_recover)
    go_bad = u < cfg.burst.p_bad
    bad = jnp.where(chan.bad > 0, stay_bad, go_bad).astype(jnp.float32)
    return ChannelState(bad=bad * (1.0 - jnp.eye(n)))


# ------------------------------------------------------------ link tiers --
def node_tiers(cfg: NetworkConfig, n: int):
    """{0=core, 1=edge} int32 [n]; seeded, static per ``(cfg.seed, n)``.
    All-core when ``cfg.classes`` is None."""
    if cfg.classes is None:
        return jnp.zeros((n,), jnp.int32)
    u = jax.random.uniform(_stream(cfg, _TIER, 0), (n,))
    return (u < cfg.classes.edge_fraction).astype(jnp.int32)


def edge_mask(cfg: NetworkConfig, n: int, rnd, chan=None):
    """Symmetric {0,1} [n, n]: 1 where the link delivers this round.

    Without ``cfg.burst`` this is the historical i.i.d. ``drop_rate`` coin
    (bit-for-bit). With burst, the per-link drop probability follows the
    Gilbert–Elliott state in ``chan``.
    """
    u_sym = _sym_uniform(_stream(cfg, _DROP, rnd), n)
    if cfg.burst is None:
        return (u_sym >= cfg.drop_rate).astype(jnp.float32)
    if chan is None:
        raise ValueError(
            "bursty loss needs the carried channel state: use "
            "init_channel(cfg, n) + advance_conditions(cfg, n, rnd, chan) "
            "instead of calling round_conditions/edge_mask statelessly")
    drop = jnp.where(chan.bad > 0, cfg.burst.drop_bad, cfg.burst.drop_good)
    return (u_sym >= drop).astype(jnp.float32)


def availability(cfg: NetworkConfig, n: int, rnd):
    """{0,1} [n]: node online this round. Constant over an outage block so
    departures last ``outage_rounds`` rounds (join/leave schedule)."""
    block = rnd // max(1, cfg.outage_rounds)
    u = jax.random.uniform(_stream(cfg, _CHURN, block), (n,))
    return (u >= cfg.churn_rate).astype(jnp.float32)


def straggler_mask(cfg: NetworkConfig, n: int, rnd):
    u = jax.random.uniform(_stream(cfg, _STRAGGLE, rnd), (n,))
    return (u < cfg.straggler_rate).astype(jnp.float32)


def round_conditions(cfg: NetworkConfig, n: int, rnd,
                     chan=None) -> RoundConditions:
    """All masks for round ``rnd`` (deterministic in (cfg.seed, rnd));
    composes the stochastic models with the scheduled events. ``chan`` is
    the carried :class:`ChannelState`, required iff ``cfg.burst`` is set."""
    edges = edge_mask(cfg, n, rnd, chan)
    active = availability(cfg, n, rnd)
    strag = straggler_mask(cfg, n, rnd)
    ev_active, ev_edges = events_mod.event_masks(cfg.seed, cfg.events, n, rnd)
    return RoundConditions(edge_mask=edges * ev_edges,
                           active=active * ev_active,
                           straggler=strag)


def advance_conditions(cfg: NetworkConfig, n: int, rnd, chan=None):
    """Step the bursty channel into round ``rnd`` and draw its masks:
    ``(RoundConditions, new ChannelState-or-None)``. This is THE per-round
    entry point for both drivers — the scan engine calls it inside
    ``lax.scan`` with the channel state in the donated carry; the legacy
    loop threads the same state through Python. Bit-identical to
    :func:`round_conditions` when ``cfg.burst`` is None."""
    chan = step_channel(cfg, n, rnd, chan)
    return round_conditions(cfg, n, rnd, chan), chan
