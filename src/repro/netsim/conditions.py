"""Vectorized network-condition models (churn, message loss, stragglers).

Everything here is jit-friendly: a :class:`NetworkConfig` is static
(hashable, closed over at trace time) and :func:`round_conditions` maps a
round index to a :class:`RoundConditions` pytree of dense masks that the
round functions in ``core/`` consume:

* ``edge_mask [n, n]``  — 1 where the link delivered this round's message
  (symmetric: gossip is push-pull, a lost exchange is lost both ways);
* ``active [n]``        — 1 where the node is online this round (churn);
* ``straggler [n]``     — 1 where the node is slow this round. Stragglers
  still train and gossip — in a synchronous round they only stretch the
  simulated wall-clock time (see :mod:`repro.netsim.timing`).

Churn is drawn per *outage block* (``round // outage_rounds``) rather than
per round, so an offline node stays offline for ``outage_rounds``
consecutive rounds — a join/leave schedule, not per-round coin flips.
All randomness derives from ``jax.random.fold_in`` on ``(seed, stream,
round)``, so a given config replays the exact same schedule forever.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import events as events_mod

_DROP, _CHURN, _STRAGGLE = 1, 2, 3   # per-stream fold_in tags


class RoundConditions(NamedTuple):
    """Dense per-round masks, all float32 in {0, 1}."""
    edge_mask: Any       # [n, n] symmetric; 1 = message delivered
    active: Any          # [n]    1 = node online
    straggler: Any       # [n]    1 = node slow this round


@dataclasses.dataclass(frozen=True)
class NetworkConfig:
    """Static description of the simulated network.

    Presets (``NetworkConfig.preset(name)``): ``ideal`` (today's free
    perfect medium), ``lan``, ``wan``, ``edge-churn`` (flaky edge devices,
    the paper's motivating healthcare/edge deployment), ``hostile``
    (stress test: heavy loss + churn + stragglers).
    """
    name: str = "custom"
    drop_rate: float = 0.0           # P(undirected link loses this round's msg)
    churn_rate: float = 0.0          # P(node offline in an outage block)
    outage_rounds: int = 2           # length of one offline stretch (rounds)
    straggler_rate: float = 0.0      # P(node is slow this round)
    straggler_slowdown: float = 4.0  # compute/link time multiplier when slow
    latency_s: float = 1e-3          # per-link one-way latency (seconds)
    bandwidth_bps: float = 1e9       # per-link bandwidth (bytes/sec would be
                                     # bps/8; we keep bits/sec like specs do)
    compute_s_per_step: float = 0.05 # seconds per local SGD step (sim scale)
    seed: int = 0                    # netsim's own stream; independent of
                                     # the experiment seed by construction
    events: tuple = ()               # round-indexed scenario (events.py)

    @classmethod
    def preset(cls, name: str, **overrides) -> "NetworkConfig":
        if name not in PRESETS:
            raise ValueError(
                f"unknown netsim preset {name!r}; know {sorted(PRESETS)}")
        kw = dict(PRESETS[name])
        kw.update(overrides)
        return cls(name=name, **kw)


PRESETS: dict[str, dict] = {
    # today's implicit model: free, instantaneous, perfectly reliable
    "ideal": dict(drop_rate=0.0, churn_rate=0.0, straggler_rate=0.0,
                  latency_s=0.0, bandwidth_bps=1e15),
    # one rack: fast links, the odd busy machine
    "lan": dict(drop_rate=0.0, churn_rate=0.0, straggler_rate=0.05,
                straggler_slowdown=2.0, latency_s=5e-4, bandwidth_bps=10e9),
    # cross-datacenter gossip
    "wan": dict(drop_rate=0.01, churn_rate=0.02, straggler_rate=0.10,
                straggler_slowdown=4.0, latency_s=5e-2, bandwidth_bps=1e8),
    # flaky phones/hospital workstations joining and leaving
    "edge-churn": dict(drop_rate=0.05, churn_rate=0.20, outage_rounds=3,
                       straggler_rate=0.20, straggler_slowdown=6.0,
                       latency_s=8e-2, bandwidth_bps=2e7),
    # stress test for cluster-assignment stability
    "hostile": dict(drop_rate=0.25, churn_rate=0.35, outage_rounds=4,
                    straggler_rate=0.30, straggler_slowdown=10.0,
                    latency_s=2e-1, bandwidth_bps=5e6),
}


# --------------------------------------------------------------------------
def _stream(cfg: NetworkConfig, tag: int, rnd):
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), tag), rnd)


def edge_mask(cfg: NetworkConfig, n: int, rnd):
    """Symmetric {0,1} [n, n]: 1 where the link delivers this round."""
    u = jax.random.uniform(_stream(cfg, _DROP, rnd), (n, n))
    upper = jnp.triu(u, 1)
    u_sym = upper + upper.T                      # one coin per undirected edge
    return (u_sym >= cfg.drop_rate).astype(jnp.float32)


def availability(cfg: NetworkConfig, n: int, rnd):
    """{0,1} [n]: node online this round. Constant over an outage block so
    departures last ``outage_rounds`` rounds (join/leave schedule)."""
    block = rnd // max(1, cfg.outage_rounds)
    u = jax.random.uniform(_stream(cfg, _CHURN, block), (n,))
    return (u >= cfg.churn_rate).astype(jnp.float32)


def straggler_mask(cfg: NetworkConfig, n: int, rnd):
    u = jax.random.uniform(_stream(cfg, _STRAGGLE, rnd), (n,))
    return (u < cfg.straggler_rate).astype(jnp.float32)


def round_conditions(cfg: NetworkConfig, n: int, rnd) -> RoundConditions:
    """All masks for round ``rnd`` (deterministic in (cfg.seed, rnd));
    composes the stochastic models with the scheduled events."""
    edges = edge_mask(cfg, n, rnd)
    active = availability(cfg, n, rnd)
    strag = straggler_mask(cfg, n, rnd)
    ev_active, ev_edges = events_mod.event_masks(cfg.seed, cfg.events, n, rnd)
    return RoundConditions(edge_mask=edges * ev_edges,
                           active=active * ev_active,
                           straggler=strag)
