"""Sweep driver: fan (algorithm x netsim preset x config) cells over seeds
on one shared compile cache.

Each :class:`SweepCell` is one grid cell — everything static; only the
experiment seed varies inside it. ``run_sweep`` routes every run through
:func:`repro.core.runner.run_experiment` with a shared
:class:`repro.core.cache.EngineCache`, so a cell pays its XLA compiles on
the first seed and every further seed runs warm; cells that coincide on
the static key (e.g. the same algorithm under two eval schedules) share
programs too, and all cells over one dataset+model share the evaluator.
Warm-cache runs are bit-identical to fresh ``run_experiment`` calls
(``tests/test_sweep.py`` pins this for all five algorithms, with and
without netsim).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import pathlib
import time
from typing import Any, Sequence

from repro.core.cache import EngineCache
from repro.core.runner import run_experiment
from repro.netsim import NetworkConfig
from repro.obs import RunManifest

from .aggregate import aggregate_cell


@dataclasses.dataclass
class SweepCell:
    """One grid cell. ``net`` may be a :class:`NetworkConfig`, a preset
    name (``"edge-churn"``), or ``None``; ``kwargs`` are passed through to
    ``run_experiment`` (``degree``, ``local_steps``, ``batch_size``,
    ``lr``, ``eval_every``, ``warmup_rounds``, ``target_acc``, ...) —
    everything except ``seed``, which ``run_sweep`` owns."""
    name: str
    algo: str
    cfg: Any
    dataset: Any
    rounds: int
    net: Any = None
    kwargs: dict = dataclasses.field(default_factory=dict)

    def resolved_net(self):
        return (NetworkConfig.preset(self.net) if isinstance(self.net, str)
                else self.net)


@dataclasses.dataclass
class CellResult:
    cell: SweepCell
    seeds: tuple
    results: list          # per-seed RunResult, in ``seeds`` order
    summary: dict          # aggregate_cell(results, targets)
    cache_stats: dict = dataclasses.field(default_factory=dict)
    #                      cumulative EngineCache.stats() right after this
    #                      cell — the warm-after-first-seed story per cell


@dataclasses.dataclass
class SweepResult:
    cells: list
    seeds: tuple
    cache: EngineCache
    wall_s: float

    def cell(self, name: str) -> CellResult:
        for c in self.cells:
            if c.cell.name == name:
                return c
        raise KeyError(f"no sweep cell named {name!r}; "
                       f"know {[c.cell.name for c in self.cells]}")

    def to_json(self) -> dict:
        cells = {}
        for c in self.cells:
            net = c.cell.net
            cells[c.cell.name] = {
                "algo": c.cell.algo,
                "net": (net if isinstance(net, str) or net is None
                        else net.name),
                "rounds": c.cell.rounds,
                "kwargs": {k: repr(v) if not isinstance(
                    v, (int, float, str, bool, type(None))) else v
                    for k, v in c.cell.kwargs.items()},
                "summary": c.summary,
                "cache": c.cache_stats,
            }
        return {"seeds": list(self.seeds), "wall_s": self.wall_s,
                "cache": self.cache.stats(), "cells": cells}

    def save(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=2, default=float))
        return path


def run_sweep(cells: Sequence[SweepCell], seeds: Sequence[int], *,
              cache: EngineCache | None = None, targets: Sequence[float] = (),
              json_path=None, obs=None,
              verbose: bool = False) -> SweepResult:
    """Run every cell over every seed, reusing compiled programs.

    ``cache``: share one :class:`EngineCache` across calls to keep programs
    warm between sweeps (``None`` builds a fresh one for this sweep).
    ``targets``: accuracies for the per-cell bytes/seconds-to-target table.
    ``json_path``: if set, the aggregated sweep is written there as JSON,
    with a :class:`repro.obs.RunManifest` next to it
    (``<json_path>.manifest.json``) recording what exactly ran.
    ``obs``: optional :class:`repro.obs.Obs` shared by every run of the
    sweep — per-cell ``sweep.cell`` spans wrap the usual per-run
    instrumentation, and the sweep manifest picks up its timing rollup.
    """
    cache = cache if cache is not None else EngineCache()
    tracer = obs.tracer if obs is not None else None
    seeds = tuple(int(s) for s in seeds)
    names = [c.name for c in cells]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate sweep cell names: {names}")
    for cell in cells:
        if "seed" in cell.kwargs:
            raise ValueError(
                f"cell {cell.name!r} sets 'seed' in kwargs; seeds are the "
                "sweep axis — pass them to run_sweep instead")

    t0 = time.perf_counter()
    out = []
    for cell in cells:
        net = cell.resolved_net()
        results = []
        span = (tracer.span("sweep.cell", cell=cell.name)
                if tracer is not None else contextlib.nullcontext())
        with span:
            for seed in seeds:
                results.append(run_experiment(
                    cell.algo, cell.cfg, cell.dataset, rounds=cell.rounds,
                    seed=seed, net=net, cache=cache, obs=obs,
                    **cell.kwargs))
        summary = aggregate_cell(results, targets=targets)
        out.append(CellResult(cell, seeds, results, summary,
                              cache_stats=cache.stats()))
        if verbose:
            fa = summary["best_fair_acc"]
            print(f"  [sweep] {cell.name}: best_fair_acc="
                  f"{fa['mean']:.3f}±{fa['std']:.3f} over "
                  f"{len(seeds)} seeds ({cache.stats()['compiles']} "
                  "compiles so far)")
    sweep = SweepResult(out, seeds, cache, time.perf_counter() - t0)
    if json_path is not None:
        path = sweep.save(json_path)
        manifest = RunManifest.build(
            kind="sweep", name=path.stem,
            spec=[repr(c.cell) for c in out],
            settings={"seeds": list(seeds), "cells": names,
                      "targets": list(targets)},
            timing=tracer.rollup() if tracer is not None else
            {"wall_s": sweep.wall_s},
            cache=cache.stats())
        manifest.save(path.with_suffix(path.suffix + ".manifest.json"))
    return sweep
