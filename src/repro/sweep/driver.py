"""Sweep driver: fan (algorithm x netsim preset x config) cells over seeds
on one shared compile cache.

Each :class:`SweepCell` is one grid cell — everything static; only the
experiment seed varies inside it. ``run_sweep`` routes every run through
:func:`repro.core.runner.run_experiment` with a shared
:class:`repro.core.cache.EngineCache`, so a cell pays its XLA compiles on
the first seed and every further seed runs warm; cells that coincide on
the static key (e.g. the same algorithm under two eval schedules) share
programs too, and all cells over one dataset+model share the evaluator.
Warm-cache runs are bit-identical to fresh ``run_experiment`` calls
(``tests/test_sweep.py`` pins this for all five algorithms, with and
without netsim).

Long grids survive preemption two ways (``ckpt_dir=``): every engine run
checkpoints per segment (``run_experiment(ckpt=...)``) so a killed cell
resumes mid-run, and every COMPLETED cell leaves a summary + manifest
behind so a rerun of the same sweep skips it outright (matched on a
content fingerprint of the cell's full static description — algorithm,
config, netsim preset incl. faults, dataset content, seeds, targets).
A cell that raises no longer kills the grid: the error is recorded on its
:class:`CellResult` (and as a ``sweep.cell_failed`` tracer event) and the
remaining cells run; only a sweep where EVERY cell failed raises.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import pathlib
import time
from typing import Any, Sequence

from repro.core.cache import EngineCache, data_fingerprint
from repro.core.runner import run_experiment
from repro.netsim import NetworkConfig
from repro.obs import RunManifest, fingerprint, worst_verdict

from .aggregate import aggregate_cell


@dataclasses.dataclass
class SweepCell:
    """One grid cell. ``net`` may be a :class:`NetworkConfig`, a preset
    name (``"edge-churn"``), or ``None``; ``kwargs`` are passed through to
    ``run_experiment`` (``degree``, ``local_steps``, ``batch_size``,
    ``lr``, ``eval_every``, ``warmup_rounds``, ``target_acc``, ...) —
    everything except ``seed``, which ``run_sweep`` owns."""
    name: str
    algo: str
    cfg: Any
    dataset: Any
    rounds: int
    net: Any = None
    kwargs: dict = dataclasses.field(default_factory=dict)

    def resolved_net(self):
        return (NetworkConfig.preset(self.net) if isinstance(self.net, str)
                else self.net)


@dataclasses.dataclass
class CellResult:
    cell: SweepCell
    seeds: tuple
    results: list          # per-seed RunResult, in ``seeds`` order
    summary: dict          # aggregate_cell(results, targets)
    cache_stats: dict = dataclasses.field(default_factory=dict)
    #                      cumulative EngineCache.stats() right after this
    #                      cell — the warm-after-first-seed story per cell
    error: "str | None" = None   # repr of the exception that killed the
    #                      cell (results/summary then hold no metrics)
    skipped: bool = False  # completed in an earlier sweep run and skipped
    #                      here (summary reloaded from ckpt_dir; no
    #                      per-seed RunResults)
    health: "dict | None" = None  # per-cell health rollup when the sweep
    #                      ran with an Obs: {"verdict": worst-over-seeds,
    #                      "runs": {manifest name: verdict}}


@dataclasses.dataclass
class SweepResult:
    cells: list
    seeds: tuple
    cache: EngineCache
    wall_s: float

    def cell(self, name: str) -> CellResult:
        for c in self.cells:
            if c.cell.name == name:
                return c
        raise KeyError(f"no sweep cell named {name!r}; "
                       f"know {[c.cell.name for c in self.cells]}")

    def to_json(self) -> dict:
        cells = {}
        for c in self.cells:
            net = c.cell.net
            cells[c.cell.name] = {
                "algo": c.cell.algo,
                "net": (net if isinstance(net, str) or net is None
                        else net.name),
                "rounds": c.cell.rounds,
                "kwargs": {k: repr(v) if not isinstance(
                    v, (int, float, str, bool, type(None))) else v
                    for k, v in c.cell.kwargs.items()},
                "summary": c.summary,
                "cache": c.cache_stats,
                "error": c.error,
                "skipped": c.skipped,
                "health": c.health,
            }
        return {"seeds": list(self.seeds), "wall_s": self.wall_s,
                "cache": self.cache.stats(), "cells": cells}

    def save(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=2, default=float))
        return path


def _cell_fingerprint(cell: SweepCell, net, seeds, targets) -> str:
    """Content hash of EVERYTHING that shapes a cell's summary. Built from
    reprs of frozen configs plus :func:`data_fingerprint` of the dataset —
    NEVER ``repr(cell)``, whose dataset repr can embed memory addresses
    and would break skip-on-rerun across processes."""
    return fingerprint({
        "name": cell.name, "algo": cell.algo, "cfg": repr(cell.cfg),
        "rounds": cell.rounds, "net": repr(net),
        "kwargs": {k: repr(v) for k, v in sorted(cell.kwargs.items())},
        "data": data_fingerprint(cell.dataset),
        "seeds": list(seeds), "targets": list(targets)})


def run_sweep(cells: Sequence[SweepCell], seeds: Sequence[int], *,
              cache: EngineCache | None = None, targets: Sequence[float] = (),
              json_path=None, obs=None, ckpt_dir=None,
              persist_dir=None, max_entries: int | None = None,
              verbose: bool = False) -> SweepResult:
    """Run every cell over every seed, reusing compiled programs.

    ``cache``: share one :class:`EngineCache` across calls to keep programs
    warm between sweeps (``None`` builds a fresh one for this sweep).
    ``persist_dir``/``max_entries``: forwarded to that fresh
    :class:`EngineCache` — ``persist_dir`` points JAX's persistent
    compilation cache at a directory so the sweep's compiled executables
    survive the process (a rerun, a CI shard or a resumed grid starts
    warm), ``max_entries`` LRU-bounds the in-process entry count for
    giant grids. Mutually exclusive with passing ``cache``, which carries
    its own settings.
    ``targets``: accuracies for the per-cell bytes/seconds-to-target table.
    ``json_path``: if set, the aggregated sweep is written there as JSON,
    with a :class:`repro.obs.RunManifest` next to it
    (``<json_path>.manifest.json``) recording what exactly ran.
    ``obs``: optional :class:`repro.obs.Obs` shared by every run of the
    sweep — per-cell ``sweep.cell`` spans wrap the usual per-run
    instrumentation, and the sweep manifest picks up its timing rollup.
    ``ckpt_dir``: if set, the sweep is preemption-safe — engine runs
    checkpoint per segment under ``<ckpt_dir>/<cell>-s<seed>.npz``, and a
    completed cell writes ``<cell>.summary.json`` + ``<cell>.manifest.json``
    there; rerunning the same sweep skips completed cells (fingerprint
    match) and resumes the one that was killed mid-run.

    A failing cell is recorded (``CellResult.error``, a
    ``sweep.cell_failed`` event) and the grid CONTINUES; ``RuntimeError``
    is raised only when every cell failed. A degenerate grid — no cells
    or no seeds — raises ``ValueError`` up front (historically an empty
    ``seeds`` made every cell "fail" on an empty aggregation and
    surfaced as the misleading every-cell-failed RuntimeError); a grid
    whose every cell is fingerprint-skipped returns cleanly.
    """
    if cache is not None and (persist_dir is not None
                              or max_entries is not None):
        raise ValueError(
            "pass persist_dir/max_entries OR a prebuilt cache, not both: "
            "an existing EngineCache already carries its own settings "
            "(build it with EngineCache(persist_dir=..., max_entries=...))")
    cache = cache if cache is not None else EngineCache(
        persist_dir=persist_dir, max_entries=max_entries)
    tracer = obs.tracer if obs is not None else None
    seeds = tuple(int(s) for s in seeds)
    cells = list(cells)
    if not cells:
        raise ValueError("run_sweep got an empty cell grid; build at "
                         "least one SweepCell (grid() with empty axes?)")
    if not seeds:
        raise ValueError("run_sweep got no seeds; pass at least one "
                         "(e.g. seeds=range(3))")
    names = [c.name for c in cells]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate sweep cell names: {names}")
    for cell in cells:
        for owned in ("seed", "ckpt"):
            if owned in cell.kwargs:
                raise ValueError(
                    f"cell {cell.name!r} sets {owned!r} in kwargs; "
                    f"run_sweep owns {owned!r} — pass seeds/ckpt_dir to "
                    "run_sweep instead")
    if ckpt_dir is not None:
        ckpt_dir = pathlib.Path(ckpt_dir)
        ckpt_dir.mkdir(parents=True, exist_ok=True)

    t0 = time.perf_counter()
    out = []
    for cell in cells:
        net = cell.resolved_net()
        cell_fp = (None if ckpt_dir is None
                   else _cell_fingerprint(cell, net, seeds, targets))
        if ckpt_dir is not None:
            man_path = ckpt_dir / f"{cell.name}.manifest.json"
            sum_path = ckpt_dir / f"{cell.name}.summary.json"
            if man_path.exists() and sum_path.exists():
                man = RunManifest.load(man_path)
                if man.settings.get("cell_fingerprint") == cell_fp:
                    summary = json.loads(sum_path.read_text())
                    out.append(CellResult(cell, seeds, [], summary,
                                          cache_stats=cache.stats(),
                                          skipped=True))
                    if tracer is not None:
                        tracer.event("sweep.cell_skipped", cell=cell.name)
                    if verbose:
                        print(f"  [sweep] {cell.name}: skipped "
                              "(completed in an earlier run)")
                    continue
        results = []
        m0 = len(obs.manifests) if obs is not None else 0
        span = (tracer.span("sweep.cell", cell=cell.name)
                if tracer is not None else contextlib.nullcontext())
        try:
            with span:
                for seed in seeds:
                    ckpt = None
                    if (ckpt_dir is not None
                            and cell.kwargs.get("engine", True)):
                        ckpt = str(ckpt_dir / f"{cell.name}-s{seed}.npz")
                    results.append(run_experiment(
                        cell.algo, cell.cfg, cell.dataset,
                        rounds=cell.rounds, seed=seed, net=net,
                        cache=cache, obs=obs, ckpt=ckpt, **cell.kwargs))
            summary = aggregate_cell(results, targets=targets)
        except Exception as e:  # noqa: BLE001 — one bad cell, whole grid
            out.append(CellResult(cell, seeds, results,
                                  {"error": repr(e)},
                                  cache_stats=cache.stats(),
                                  error=repr(e)))
            if tracer is not None:
                tracer.event("sweep.cell_failed", cell=cell.name,
                             error=repr(e))
            if verbose:
                print(f"  [sweep] {cell.name}: FAILED ({e!r}); "
                      "continuing with the remaining cells")
            continue
        health = None
        if obs is not None and obs.health_config is not None:
            # one manifest per seed run of this cell: roll the per-run
            # health verdicts into the cell's worst-over-seeds verdict
            runs = {m.name: (m.health or {}).get("verdict", "ok")
                    for m in obs.manifests[m0:]}
            health = {"verdict": worst_verdict(runs.values()),
                      "runs": runs}
        out.append(CellResult(cell, seeds, results, summary,
                              cache_stats=cache.stats(), health=health))
        if ckpt_dir is not None:
            sum_path.write_text(json.dumps(summary, indent=2,
                                           default=float))
            RunManifest.build(
                kind="sweep-cell", name=cell.name, spec=repr(cell.cfg),
                settings={"cell_fingerprint": cell_fp,
                          "seeds": list(seeds), "targets": list(targets),
                          "net": repr(net)},
                cache=cache.stats()).save(man_path)
        if verbose:
            fa = summary.get("best_fair_acc")
            if fa is not None:
                print(f"  [sweep] {cell.name}: best_fair_acc="
                      f"{fa['mean']:.3f}±{fa['std']:.3f} over "
                      f"{len(seeds)} seeds ({cache.stats()['compiles']} "
                      "compiles so far)")
    if out and all(c.error is not None for c in out):
        raise RuntimeError(
            f"every sweep cell failed ({len(out)}/{len(out)}): "
            + "; ".join(f"{c.cell.name}: {c.error}" for c in out))
    sweep = SweepResult(out, seeds, cache, time.perf_counter() - t0)
    if json_path is not None:
        path = sweep.save(json_path)
        cell_verdicts = {c.cell.name: c.health["verdict"]
                         for c in out if c.health is not None}
        manifest = RunManifest.build(
            kind="sweep", name=path.stem,
            spec=[repr(c.cell) for c in out],
            settings={"seeds": list(seeds), "cells": names,
                      "targets": list(targets)},
            timing=tracer.rollup() if tracer is not None else
            {"wall_s": sweep.wall_s},
            cache=cache.stats(),
            health=({"verdict": worst_verdict(cell_verdicts.values()),
                     "cells": cell_verdicts} if cell_verdicts else None))
        manifest.save(path.with_suffix(path.suffix + ".manifest.json"))
    return sweep
