"""repro.sweep — cross-run compile-cache sweeps.

The paper's headline numbers are all multi-seed grids: accuracy, fairness
and bytes-to-target per (algorithm, cluster-imbalance, dataset) cell,
averaged over seeds. A naive sweep calls ``run_experiment`` per run and
pays identical XLA compiles S times per cell; this subsystem reuses the
seed-independent machinery instead:

* :class:`repro.core.cache.EngineCache` / :class:`EngineSpec` — the
  config-keyed compile cache (algorithm programs, segment engines,
  evaluators);
* :func:`run_sweep` / :class:`SweepCell` — the grid driver: every cell
  compiles once, every further seed runs warm, bit-identical to fresh
  ``run_experiment`` calls;
* :func:`aggregate_cell` — per-cell mean/std trajectories, fairness
  metrics and bytes/seconds-to-target tables, JSON-ready.

Usage::

    from repro.sweep import SweepCell, run_sweep

    cells = [SweepCell(name=f"{a}/{p}", algo=a, cfg=cfg, dataset=ds,
                       rounds=400, net=p,
                       kwargs=dict(eval_every=40, local_steps=10))
             for a in ("facade", "el") for p in (None, "edge-churn")]
    sweep = run_sweep(cells, seeds=range(8), targets=(0.7,),
                      json_path="results/sweep.json")
    sweep.cell("facade/edge-churn").summary["best_fair_acc"]
"""
from repro.core.cache import (EngineCache, EngineSpec,  # noqa: F401
                              data_fingerprint)
from .aggregate import aggregate_cell  # noqa: F401
from .driver import (CellResult, SweepCell, SweepResult,  # noqa: F401
                     run_sweep)
