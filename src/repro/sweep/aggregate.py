"""Cross-seed aggregation: per-cell mean/std tables from ``RunResult``s.

The paper's figures report per-(algorithm, imbalance, dataset) cells
averaged over seeds — accuracy / fair-accuracy trajectories, final
fairness gaps (DP/EO), and bytes- / seconds-to-target. ``aggregate_cell``
turns one cell's list of per-seed :class:`repro.core.runner.RunResult`
into exactly those tables, JSON-ready (plain floats/lists only).

Trajectories are aligned on eval ROUND (not list index): ``target_acc``
early exit can truncate some seeds, so every trajectory row carries ``n``,
the number of seeds that actually reached that eval round.
"""
from __future__ import annotations

import numpy as np


def _ms(vals) -> dict:
    arr = np.asarray(list(vals), np.float64)
    return {"mean": float(arr.mean()), "std": float(arr.std())}


def aggregate_cell(results, targets=()) -> dict:
    """Aggregate one cell's per-seed results.

    ``targets``: accuracies for the bytes/seconds-to-target table. A seed
    that never crossed a target contributes to ``reached_frac`` only —
    averaging its ``None`` away would understate the true cost.
    """
    if not results:
        raise ValueError("aggregate_cell needs at least one RunResult")
    n_seeds = len(results)

    rounds = sorted({r for res in results for r, _ in res.fair_acc})
    fair = {r: [] for r in rounds}
    accs = {r: [] for r in rounds}
    for res in results:
        for r, fa in res.fair_acc:
            fair[r].append(fa)
        for r, a in res.acc_per_cluster:
            accs[r].append(a)
    trajectory = []
    for r in rounds:
        fa = np.asarray(fair[r], np.float64)
        pc = np.asarray(accs[r], np.float64)          # [seeds, k]
        trajectory.append({
            "round": r, "n": int(fa.size),
            "fair_acc_mean": float(fa.mean()),
            "fair_acc_std": float(fa.std()),
            "acc_mean": pc.mean(0).tolist(),
            "acc_std": pc.std(0).tolist()})

    # per-eval fairness trajectory: mean/std of each EvalFrame scalar
    # aligned on eval round (same target_acc-truncation semantics as the
    # accuracy trajectory above). getattr-defensive: results loaded from
    # older summaries/pickles may predate RunResult.eval_frames.
    fair_fields = ("dp", "eo", "worst_cluster_acc", "cluster_churn")
    by_round: dict = {}
    for res in results:
        for f in getattr(res, "eval_frames", None) or ():
            slot = by_round.setdefault(int(f.round),
                                       {k: [] for k in fair_fields})
            for k in fair_fields:
                slot[k].append(getattr(f, k))
    fairness_trajectory = []
    for r in sorted(by_round):
        row = {"round": r, "n": len(by_round[r][fair_fields[0]])}
        for k in fair_fields:
            row[f"{k}_mean"] = float(np.mean(by_round[r][k]))
            row[f"{k}_std"] = float(np.std(by_round[r][k]))
        fairness_trajectory.append(row)

    out = {
        "n_seeds": n_seeds,
        "eval_rounds": rounds,
        "trajectory": trajectory,
        "fairness_trajectory": fairness_trajectory,
        "best_fair_acc": _ms(res.best_fair_acc() for res in results),
        "final_fair_acc": _ms(
            (res.fair_acc[-1][1] if res.fair_acc else 0.0)
            for res in results),
        "dp": _ms(res.dp for res in results),
        "eo": _ms(res.eo for res in results),
        "stop_round": _ms(
            (res.comm.rounds[-1] if res.comm.rounds else 0)
            for res in results),
        "total_bytes": _ms(
            (res.comm.bytes[-1] if res.comm.bytes else 0.0)
            for res in results),
        "sim_seconds": _ms(
            (res.comm.seconds[-1] if res.comm.seconds else 0.0)
            for res in results),
        "to_target": {},
    }
    finals = np.asarray([res.final_acc for res in results], np.float64)
    out["final_acc_mean"] = finals.mean(0).tolist()
    out["final_acc_std"] = finals.std(0).tolist()

    for t in targets:
        bs = [res.comm.bytes_to_target(t) for res in results]
        ss = [res.comm.seconds_to_target(t) for res in results]
        reached_b = [b for b in bs if b is not None]
        entry = {"reached_frac": len(reached_b) / n_seeds}
        if reached_b:
            entry["bytes"] = _ms(reached_b)
            entry["seconds"] = _ms(s for s in ss if s is not None)
        else:
            # explicit CommLog sentinel: no seed ever crossed this target
            # — consumers key on `is None`, not on a missing key
            entry["bytes"] = None
            entry["seconds"] = None
        out["to_target"][f"{t:g}"] = entry
    return out
