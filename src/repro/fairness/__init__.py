from .metrics import demographic_parity, equalized_odds, fair_accuracy  # noqa: F401
