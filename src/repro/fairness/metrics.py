"""Fairness metrics from the paper (Sec. II-B, V-C).

  * demographic parity (Eq. 1):  sum_y |P[Yhat=y|S=0] - P[Yhat=y|S=1]|
  * equalized odds   (Eq. 2):    sum_y |P[Yhat=y|Y=y,S=1] - P[Yhat=y|Y=y,S=0]|
  * fair accuracy    (Eq. 5):    lam * mean_j Acc_j + (1-lam) * (1 - (max-min))

For k > 2 clusters, DP/EO report the MAXIMUM over cluster pairs (the
worst-case group gap; reduces to the paper's definition at k=2).
"""
from __future__ import annotations

import itertools

import numpy as np


def _pred_dist(preds: np.ndarray, n_classes: int) -> np.ndarray:
    return np.bincount(preds, minlength=n_classes) / max(len(preds), 1)


def demographic_parity(preds_per_cluster, n_classes: int) -> float:
    """preds_per_cluster: list (per cluster) of int prediction arrays."""
    dists = [_pred_dist(p, n_classes) for p in preds_per_cluster]
    if len(dists) < 2:
        return 0.0
    return float(max(np.abs(a - b).sum()
                     for a, b in itertools.combinations(dists, 2)))


def _tpr(preds: np.ndarray, labels: np.ndarray, n_classes: int) -> np.ndarray:
    tpr = np.zeros(n_classes)
    for y in range(n_classes):
        m = labels == y
        tpr[y] = (preds[m] == y).mean() if m.any() else 0.0
    return tpr


def equalized_odds(preds_per_cluster, labels_per_cluster,
                   n_classes: int) -> float:
    rates = [_tpr(p, l, n_classes)
             for p, l in zip(preds_per_cluster, labels_per_cluster)]
    if len(rates) < 2:
        return 0.0
    return float(max(np.abs(a - b).sum()
                     for a, b in itertools.combinations(rates, 2)))


def fair_accuracy(acc_per_cluster, lam: float = 2.0 / 3.0) -> float:
    """Eq. 5 with the paper's lambda = 2/3. Accuracies normalized in [0,1]."""
    accs = np.asarray(acc_per_cluster, np.float64)
    penalty = 1.0 - (accs.max() - accs.min())
    return float(lam * accs.mean() + (1.0 - lam) * penalty)
