"""Cross-run compile cache: the seed-independent machinery behind a sweep.

``run_experiment`` historically rebuilt everything per call — the model
binding, the algorithm round closures, the scan engine's jitted segment
programs and the jitted evaluator — so a sweep of S seeds over ONE config
paid S identical XLA compiles. At paper scale (5 algorithms x netsim
presets x cluster-imbalance grids x many seeds, tiny per-round compute)
those compiles dominate wall-clock.

:class:`EngineCache` memoizes on a static :class:`EngineSpec` key:

* the :class:`~repro.core.bindings.Binding` and the algorithm *program*
  (round/warmup closures, ``models_of``, ``finalize`` — everything
  ``runner.algo_setup`` builds except the seed-dependent initial state);
* one :class:`~repro.core.engine.SegmentEngine` per entry, whose compiled
  segment programs (keyed per ``(length, warmup)`` inside the engine) are
  therefore shared by every run of the cell;
* evaluators, cached cache-wide on ``(model cfg, eval batch, content
  fingerprint of the eval split)`` — independent of algorithm and netsim
  preset, so a grid of presets over one dataset compiles ONE evaluator.

Cache-key contract: every knob that changes a compiled program or the
round/eval arithmetic MUST be a field of :class:`EngineSpec`; only the
experiment seed (PRNG) and the data may vary within an entry. A changed
eval split changes the fingerprint, never silently reuses a stale
evaluator; train data is passed per call and never cached. ``rounds`` and
``eval_every`` are deliberately NOT key fields — segment programs are
keyed per ``(length, warmup)`` inside the engine, so different eval
schedules share an entry safely. The netsim-v2 knobs (``burst`` /
``classes`` / ``async_gossip`` / ``max_staleness``) need no extra key
field: they live on the frozen ``NetworkConfig``, which is already the
``net`` component of the key — ``tests/test_property.py`` pins that
perturbing ANY ``NetworkConfig`` field forks the key. The adaptive
topology policy is the ``topo`` component (a frozen
``repro.topo.TopoConfig`` or ``None``) with the same every-field-forks
contract, pinned the same way. In-scan telemetry is the ``obs``
component (a frozen ``repro.obs.ObsConfig`` or ``None``): its fields
change the compiled segment program's OUTPUTS (the MetricsFrame scan
leaf), so they fork the key too — while host-side sinks/tracers never
do (``tests/test_obs.py`` pins both directions).

Donation caveat: segment programs donate their input :class:`EngineCarry`
buffers. Reusing a cached engine across runs is safe precisely because
each run builds a FRESH carry from its own seed; never feed a consumed
carry back into ``run_segment``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import weakref
from typing import Any

import numpy as np

from .bindings import make_binding
from .engine import SegmentEngine


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Static cache key for one sweep cell.

    All fields are hashable statics: ``cfg`` is a frozen model config
    dataclass and ``net`` a frozen :class:`repro.netsim.NetworkConfig`
    (or ``None``). Two specs compare equal iff every compiled program and
    every round closure they imply is interchangeable.
    """
    algo: str                    # facade | el | dpsgd | deprl | dac
    cfg: Any                     # CNNConfig / ModelConfig (frozen)
    n: int                       # number of nodes
    k: int                       # number of clusters / FACADE heads
    degree: int
    local_steps: int
    batch_size: int
    lr: float
    warmup_rounds: int = 0
    head_jitter: float = 0.0
    net: Any = None              # NetworkConfig | None
    eval_batch: int = 256        # make_evaluator batch size
    topo: Any = None             # repro.topo.TopoConfig | None
    obs: Any = None              # repro.obs.ObsConfig | None — the
    #                              DEVICE-side telemetry spec: an enabled
    #                              MetricsFrame adds scan outputs, i.e. a
    #                              different compiled segment program, so
    #                              it must fork the key. Host-side sink /
    #                              tracer / profiler settings (repro.obs.
    #                              Obs) deliberately never appear here.


_FP_MEMO: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def data_fingerprint(dataset) -> str:
    """Content hash of everything an evaluator closes over: the node ->
    cluster map and the per-cluster eval split (shapes, dtypes, bytes).

    Memoized per dataset OBJECT (weakly, so the memo never pins data):
    sweeps look the same dataset up once per run, and re-hashing the eval
    split every time would be pure overhead. The flip side: mutating a
    dataset's eval arrays IN PLACE after first use is not detected —
    build a new dataset instead (the synthetic pipeline always does).
    """
    try:
        return _FP_MEMO[dataset]
    except (KeyError, TypeError):   # TypeError: non-weakrefable dataset
        pass
    h = hashlib.sha1()

    def feed(a):
        a = np.ascontiguousarray(np.asarray(a))
        h.update(f"{a.dtype}{a.shape}".encode())
        h.update(a.tobytes())

    feed(dataset.node_cluster)
    for x, y in zip(dataset.test_x, dataset.test_y):
        feed(x)
        feed(y)
    fp = h.hexdigest()
    try:
        _FP_MEMO[dataset] = fp
    except TypeError:
        pass
    return fp


class CacheEntry:
    """Seed-independent machinery for one :class:`EngineSpec`: binding,
    algorithm program and segment engine. ``setup(key)`` mints a fresh
    per-seed :class:`~repro.core.runner.AlgoSetup` over the shared
    closures — state is the ONLY per-seed piece."""

    def __init__(self, spec: EngineSpec):
        from . import runner     # runner imports this module; bind lazily
        self.spec = spec
        self.binding = make_binding(spec.cfg)
        self.program = runner.algo_program(
            spec.algo, self.binding, spec.n, spec.k, degree=spec.degree,
            local_steps=spec.local_steps, lr=spec.lr,
            warmup_rounds=spec.warmup_rounds, head_jitter=spec.head_jitter,
            topo=spec.topo,
            faults=spec.net.faults if spec.net is not None else None)
        self.engine = SegmentEngine(
            self.program.round_fn, warmup_fn=self.program.warmup_fn,
            net=spec.net, n=spec.n, local_steps=spec.local_steps,
            batch_size=spec.batch_size,
            track_cluster=self.program.track_cluster,
            mixable_of=self.program.mixable_of, topo=spec.topo,
            obs=spec.obs)

    def setup(self, key):
        return self.program.setup(key)

    @property
    def compile_count(self) -> int:
        return self.engine.compile_count


class EngineCache:
    """Config-keyed store of :class:`CacheEntry` + evaluators.

    ``entry(spec)`` returns the cell's entry, building it on first use;
    ``evaluator(binding, dataset, batch)`` returns the (cfg, batch,
    data-fingerprint)-keyed evaluator. ``compile_count`` totals every
    compiled program the cache owns — segment builds plus evaluator
    builds — which is what sweep smokes assert stays flat after each
    cell's first run.
    """

    def __init__(self):
        self._entries: dict[EngineSpec, CacheEntry] = {}
        self._evaluators: dict[tuple, Any] = {}
        self.hits = 0            # entry() served from cache
        self.misses = 0          # entry() had to build
        self.evaluator_builds = 0

    def entry(self, spec: EngineSpec) -> CacheEntry:
        e = self._entries.get(spec)
        if e is None:
            self.misses += 1
            e = self._entries[spec] = CacheEntry(spec)
        else:
            self.hits += 1
        return e

    def evaluator(self, binding, dataset, batch: int = 256):
        key = (binding.cfg, batch, data_fingerprint(dataset))
        ev = self._evaluators.get(key)
        if ev is None:
            from . import runner
            ev = self._evaluators[key] = runner.make_evaluator(
                binding, dataset.node_cluster, dataset.test_x,
                dataset.test_y, batch=batch)
            self.evaluator_builds += 1
        return ev

    @property
    def compile_count(self) -> int:
        return (sum(e.compile_count for e in self._entries.values())
                + self.evaluator_builds)

    def stats(self) -> dict:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "compiles": self.compile_count,
                "evaluator_builds": self.evaluator_builds}

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, spec) -> bool:
        return spec in self._entries
