"""Cross-run compile cache: the seed-independent machinery behind a sweep.

``run_experiment`` historically rebuilt everything per call — the model
binding, the algorithm round closures, the scan engine's jitted segment
programs and the jitted evaluator — so a sweep of S seeds over ONE config
paid S identical XLA compiles. At paper scale (5 algorithms x netsim
presets x cluster-imbalance grids x many seeds, tiny per-round compute)
those compiles dominate wall-clock.

:class:`EngineCache` memoizes on a static :class:`EngineSpec` key:

* the :class:`~repro.core.bindings.Binding` and the algorithm *program*
  (round/warmup closures, ``models_of``, ``finalize`` — everything
  ``runner.algo_setup`` builds except the seed-dependent initial state);
* one :class:`~repro.core.engine.SegmentEngine` per entry, whose compiled
  segment programs (keyed per ``(length, warmup)`` inside the engine) are
  therefore shared by every run of the cell;
* evaluators, cached cache-wide on ``(model cfg, eval batch, content
  fingerprint of the eval split)`` — independent of algorithm and netsim
  preset, so a grid of presets over one dataset compiles ONE evaluator.

Cache-key contract: every knob that changes a compiled program or the
round/eval arithmetic MUST be a field of :class:`EngineSpec`; only the
experiment seed (PRNG) and the data may vary within an entry. A changed
eval split changes the fingerprint, never silently reuses a stale
evaluator; train data is passed per call and never cached. ``rounds`` and
``eval_every`` are deliberately NOT key fields — segment programs are
keyed per ``(length, warmup)`` inside the engine, so different eval
schedules share an entry safely. The netsim-v2 knobs (``burst`` /
``classes`` / ``async_gossip`` / ``max_staleness``) need no extra key
field: they live on the frozen ``NetworkConfig``, which is already the
``net`` component of the key — ``tests/test_property.py`` pins that
perturbing ANY ``NetworkConfig`` field forks the key. The adaptive
topology policy is the ``topo`` component (a frozen
``repro.topo.TopoConfig`` or ``None``) with the same every-field-forks
contract, pinned the same way. In-scan telemetry is the ``obs``
component (a frozen ``repro.obs.ObsConfig`` or ``None``): its fields
change the compiled segment program's OUTPUTS (the MetricsFrame scan
leaf), so they fork the key too — while host-side sinks/tracers never
do (``tests/test_obs.py`` pins both directions).

Donation caveat: segment programs donate their input :class:`EngineCarry`
buffers. Reusing a cached engine across runs is safe precisely because
each run builds a FRESH carry from its own seed; never feed a consumed
carry back into ``run_segment``.

Always-warm extensions (ROADMAP Open Item 5a):

* ``EngineCache(persist_dir=...)`` points JAX's persistent compilation
  cache at a directory, so the serialized XLA executables behind every
  entry survive the PROCESS — a second sweep (or a CI shard, or a
  resumed grid) reaches its first dispatch without recompiling
  (``benchmarks/warm_start.py`` measures the cross-process win). The
  in-process :class:`EngineCache` keys stay the source of truth; the
  persistent layer only short-circuits XLA compilation underneath them.
* ``EngineCache(max_entries=...)`` bounds the in-process entry count with
  LRU eviction, so giant grids don't grow program memory without limit.
  Entries pinned via :meth:`EngineCache.pin` (``run_experiment`` pins its
  entry for the duration of the run) are never evicted — donation and
  segment-program reuse stay safe mid-run; when everything live is
  pinned the bound is allowed to overshoot rather than break a run.
  Evictions are counted in :meth:`stats` and emitted as ``cache.evict``
  tracer events next to the existing ``cache.hit``/``cache.miss``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import os
import weakref
from typing import Any

import numpy as np

from .bindings import make_binding
from .engine import SegmentEngine


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Static cache key for one sweep cell.

    All fields are hashable statics: ``cfg`` is a frozen model config
    dataclass and ``net`` a frozen :class:`repro.netsim.NetworkConfig`
    (or ``None``). Two specs compare equal iff every compiled program and
    every round closure they imply is interchangeable.
    """
    algo: str                    # facade | el | dpsgd | deprl | dac
    cfg: Any                     # CNNConfig / ModelConfig (frozen)
    n: int                       # number of nodes
    k: int                       # number of clusters / FACADE heads
    degree: int
    local_steps: int
    batch_size: int
    lr: float
    warmup_rounds: int = 0
    head_jitter: float = 0.0
    net: Any = None              # NetworkConfig | None
    eval_batch: int = 256        # make_evaluator batch size
    topo: Any = None             # repro.topo.TopoConfig | None
    obs: Any = None              # repro.obs.ObsConfig | None — the
    #                              DEVICE-side telemetry spec: an enabled
    #                              MetricsFrame adds scan outputs, i.e. a
    #                              different compiled segment program, so
    #                              it must fork the key. Host-side sink /
    #                              tracer / profiler settings (repro.obs.
    #                              Obs) deliberately never appear here.
    mesh: Any = None             # node-mesh SHAPE tuple (e.g. ``(8,)``)
    #                              or None — repro.core.meshctx.normalize's
    #                              canonical form. A sharded segment
    #                              program has different layouts and
    #                              collectives than the single-device one,
    #                              so sharded and unsharded runs must
    #                              never collide on an entry. Device
    #                              OBJECTS never enter the key (shape
    #                              only): specs stay repr-stable for
    #                              checkpoint fingerprints.


def attach_persist_dir(path) -> str:
    """Point JAX's persistent compilation cache at ``path`` (created if
    missing) and drop the persistence floors so the sweeps' many small
    segment programs — each well under the default 1s-compile-time /
    min-entry-size thresholds — are persisted too.

    The JAX compilation-cache directory is PROCESS-GLOBAL state: the last
    attach wins for every compile in the process, not just this cache's.
    That is the behavior we want (one warm disk cache per sweep process)
    but it means two live ``EngineCache(persist_dir=...)`` instances with
    different directories cannot both be honored — the newer one is.
    """
    import jax

    path = str(path)
    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    try:
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except Exception:   # knob absent on old jax: size floor stays default
        pass
    _reset_jax_cache()
    return path


def detach_persist_dir() -> None:
    """Undo :func:`attach_persist_dir`: stop persisting compiles to disk.
    Call this before a temporary persist dir is deleted — the attached
    cache object is process-global and would otherwise keep writing into
    the removed directory."""
    import jax

    jax.config.update("jax_compilation_cache_dir", None)
    _reset_jax_cache()


def _reset_jax_cache() -> None:
    """Drop JAX's lazily-initialized persistent-cache singleton so the
    next compile re-reads ``jax_compilation_cache_dir``. Without this,
    attaching after the process's first compile is silently a no-op (the
    singleton latched the old — usually absent — directory)."""
    try:
        from jax._src import compilation_cache
        compilation_cache.reset_cache()
    except Exception:   # private module moved: newer jax re-reads config
        pass


_FP_MEMO: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def data_fingerprint(dataset) -> str:
    """Content hash of everything an evaluator closes over: the node ->
    cluster map and the per-cluster eval split (shapes, dtypes, bytes).

    Memoized per dataset OBJECT (weakly, so the memo never pins data):
    sweeps look the same dataset up once per run, and re-hashing the eval
    split every time would be pure overhead. The flip side: mutating a
    dataset's eval arrays IN PLACE after first use is not detected —
    build a new dataset instead (the synthetic pipeline always does).
    """
    try:
        return _FP_MEMO[dataset]
    except (KeyError, TypeError):   # TypeError: non-weakrefable dataset
        pass
    h = hashlib.sha1()

    def feed(a):
        a = np.ascontiguousarray(np.asarray(a))
        h.update(f"{a.dtype}{a.shape}".encode())
        h.update(a.tobytes())

    feed(dataset.node_cluster)
    for x, y in zip(dataset.test_x, dataset.test_y):
        feed(x)
        feed(y)
    fp = h.hexdigest()
    try:
        _FP_MEMO[dataset] = fp
    except TypeError:
        pass
    return fp


class CacheEntry:
    """Seed-independent machinery for one :class:`EngineSpec`: binding,
    algorithm program and segment engine. ``setup(key)`` mints a fresh
    per-seed :class:`~repro.core.runner.AlgoSetup` over the shared
    closures — state is the ONLY per-seed piece."""

    def __init__(self, spec: EngineSpec):
        from . import runner     # runner imports this module; bind lazily
        self.spec = spec
        self.binding = make_binding(spec.cfg)
        self.program = runner.algo_program(
            spec.algo, self.binding, spec.n, spec.k, degree=spec.degree,
            local_steps=spec.local_steps, lr=spec.lr,
            warmup_rounds=spec.warmup_rounds, head_jitter=spec.head_jitter,
            topo=spec.topo,
            faults=spec.net.faults if spec.net is not None else None)
        self.engine = SegmentEngine(
            self.program.round_fn, warmup_fn=self.program.warmup_fn,
            net=spec.net, n=spec.n, local_steps=spec.local_steps,
            batch_size=spec.batch_size,
            track_cluster=self.program.track_cluster,
            mixable_of=self.program.mixable_of, topo=spec.topo,
            obs=spec.obs, mesh=spec.mesh)

    def setup(self, key):
        return self.program.setup(key)

    @property
    def compile_count(self) -> int:
        return self.engine.compile_count


class EngineCache:
    """Config-keyed store of :class:`CacheEntry` + evaluators.

    ``entry(spec)`` returns the cell's entry, building it on first use;
    ``evaluator(binding, dataset, batch)`` returns the (cfg, batch,
    data-fingerprint)-keyed evaluator. ``compile_count`` totals every
    compiled program the cache EVER built — segment builds plus evaluator
    builds, monotone across LRU evictions — which is what sweep smokes
    assert stays flat after each cell's first run.

    ``persist_dir``: attach JAX's persistent compilation cache (see
    :func:`attach_persist_dir`) so compiled executables survive the
    process. ``max_entries``: LRU bound on live entries; ``None`` (the
    default) keeps the historical unbounded behavior.

    The attached directory is PROCESS-GLOBAL jax state, so a cache built
    over a temporary directory must detach before that directory is
    deleted — otherwise every later compile in the process tries to
    persist into the void and fails. :meth:`close` (or using the cache as
    a context manager) does exactly that, and only if this cache's
    directory is still the attached one — it never stomps a newer attach
    by another cache. In-process entries stay usable after ``close``;
    only disk persistence stops.
    """

    def __init__(self, *, persist_dir=None, max_entries: int | None = None):
        if max_entries is not None and max_entries < 1:
            raise ValueError(
                f"max_entries={max_entries} must be >= 1 (or None for "
                "an unbounded cache): a run always needs its own entry")
        self._entries: dict[EngineSpec, CacheEntry] = {}  # insertion = LRU
        self._evaluators: dict[tuple, Any] = {}
        self._pins: dict[EngineSpec, int] = {}
        self.hits = 0            # entry() served from cache
        self.misses = 0          # entry() had to build
        self.evictions = 0       # entries dropped by the LRU bound
        self.evaluator_builds = 0
        self.max_entries = max_entries
        self._evicted_compiles = 0   # keeps compile_count monotone
        self.persist_dir = (attach_persist_dir(persist_dir)
                            if persist_dir is not None else None)

    def close(self) -> None:
        """Detach the persistent compile directory this cache attached
        (no-op without ``persist_dir``, idempotent). Call before deleting
        a temporary persist dir — the attach is process-global, so a
        deleted-but-still-attached directory would poison every later
        compile in the process. If ANOTHER cache attached a different
        directory since (last-attach-wins), that newer attach is left
        alone."""
        if self.persist_dir is None:
            return
        import jax

        if jax.config.jax_compilation_cache_dir == self.persist_dir:
            detach_persist_dir()
        self.persist_dir = None

    def __enter__(self) -> "EngineCache":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def entry(self, spec: EngineSpec, tracer=None) -> CacheEntry:
        e = self._entries.get(spec)
        if e is None:
            self.misses += 1
            e = self._entries[spec] = CacheEntry(spec)
        else:
            self.hits += 1
            self._entries[spec] = self._entries.pop(spec)  # -> MRU slot
        self._evict(keep=spec, tracer=tracer)
        return e

    def _evict(self, keep: EngineSpec, tracer=None):
        if self.max_entries is None:
            return
        while len(self._entries) > self.max_entries:
            victim = next(
                (s for s in self._entries       # oldest-first = LRU order
                 if s != keep and self._pins.get(s, 0) == 0), None)
            if victim is None:
                return   # every live entry is pinned by a running
                #          experiment: overshoot rather than break one
            dead = self._entries.pop(victim)
            self._evicted_compiles += dead.compile_count
            self.evictions += 1
            if tracer is not None:
                tracer.event("cache.evict", algo=victim.algo,
                             entries=len(self._entries))

    @contextlib.contextmanager
    def pin(self, spec: EngineSpec):
        """Hold ``spec``'s entry out of LRU eviction for the duration —
        ``run_experiment`` wraps each run in this so the entry (and its
        compiled segment programs) can't be dropped mid-run."""
        self._pins[spec] = self._pins.get(spec, 0) + 1
        try:
            yield
        finally:
            n = self._pins[spec] - 1
            if n:
                self._pins[spec] = n
            else:
                del self._pins[spec]

    def pinned(self, spec: EngineSpec) -> bool:
        return self._pins.get(spec, 0) > 0

    def evaluator(self, binding, dataset, batch: int = 256):
        key = (binding.cfg, batch, data_fingerprint(dataset))
        ev = self._evaluators.get(key)
        if ev is None:
            from . import runner
            ev = self._evaluators[key] = runner.make_evaluator(
                binding, dataset.node_cluster, dataset.test_x,
                dataset.test_y, batch=batch)
            self.evaluator_builds += 1
        return ev

    @property
    def compile_count(self) -> int:
        return (sum(e.compile_count for e in self._entries.values())
                + self._evicted_compiles + self.evaluator_builds)

    def stats(self) -> dict:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "compiles": self.compile_count,
                "evaluator_builds": self.evaluator_builds,
                "max_entries": self.max_entries,
                "persist_dir": self.persist_dir}

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, spec) -> bool:
        return spec in self._entries
