"""Core/head parameter split (paper Sec. III-A).

The model pytree is split by *top-level key*: the config names which groups
form the FACADE head (e.g. ``("final_norm", "lm_head")`` for LMs,
``("block2", "block3", "fc")`` for ResNet8). Everything else is the shared
core. Heads are replicated k times with independent values (one per
cluster); cores stay single.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def split_params(params: dict, head_keys: tuple):
    head = {k: params[k] for k in head_keys if k in params}
    core = {k: v for k, v in params.items() if k not in head}
    return core, head


def merge_params(core: dict, head: dict) -> dict:
    out = dict(core)
    out.update(head)
    return out


def stack_heads(head: dict, k: int, key=None, jitter: float = 0.0):
    """Replicate a head pytree k times -> leading axis k. Optional jitter
    decorrelates the initial heads (Appendix F notes identical-init heads
    help early settling; jitter=0 reproduces that 'shared init' strategy)."""
    def rep(leaf):
        return jnp.broadcast_to(leaf[None], (k,) + leaf.shape).copy()

    stacked = jax.tree.map(rep, head)
    if jitter > 0.0 and key is not None:
        leaves, treedef = jax.tree.flatten(stacked)
        keys = jax.random.split(key, len(leaves))
        leaves = [l + jitter * jax.random.normal(kk, l.shape, l.dtype)
                  for l, kk in zip(leaves, keys)]
        stacked = jax.tree.unflatten(treedef, leaves)
    return stacked


def select_head(stacked_head: dict, idx):
    """Pick head ``idx`` (traced int) from the k-stacked head pytree."""
    return jax.tree.map(lambda l: jax.lax.dynamic_index_in_dim(
        l, idx, axis=0, keepdims=False), stacked_head)


def set_head(stacked_head: dict, idx, head: dict):
    """Write ``head`` into slot ``idx`` of the k-stacked head pytree."""
    return jax.tree.map(
        lambda s, h: jax.lax.dynamic_update_index_in_dim(
            s, h.astype(s.dtype), idx, axis=0),
        stacked_head, head)


def tree_size_bytes(tree) -> int:
    return sum(int(l.size * l.dtype.itemsize) for l in jax.tree.leaves(tree))
