"""Model bindings: the uniform interface the DL algorithms train against.

A binding exposes:
    init(key)                  -> full param pytree (head keys included)
    head_keys                  -> which top-level groups form the FACADE head
    loss(params, batch)        -> scalar training loss (grads flow here)
    features(core, batch)      -> core activations shared by the k heads
    head_loss(head, feats, b)  -> candidate-head loss on cached core features

The features/head_loss pair implements the paper's III-E optimization
("store the output tokens of the model core and input these to each model
head") — the core runs ONCE per round per node, not k times.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models import api, cnn, layers, transformer, whisper
from repro.models.base import CNNConfig, ModelConfig

from . import meshctx


def node_matmul(a, x):
    """THE cross-node contraction: ``out[i, ...] = sum_j a[i, j] x[j, ...]``
    (``einsum("ij,j...->i...")``). Outside a node-mesh trace context this
    IS that einsum, bit for bit. Under :func:`repro.core.meshctx.activate`
    it lowers as a shard_map row block: each device holds a row shard of
    ``a`` and a node shard of ``x``, all-gathers the senders, and runs the
    einsum on its rows — per-row arithmetic (and therefore the result) is
    identical to the unsharded form; only cross-row REDUCTIONS downstream
    of this op can see a different summation order."""
    mesh = meshctx.current()
    if mesh is None:
        return jnp.einsum("ij,j...->i...", a, x)

    def blk(a_blk, x_blk):
        xg = jax.lax.all_gather(x_blk, meshctx.NODE_AXIS, tiled=True)
        return jnp.einsum("ij,j...->i...", a_blk, xg)

    return shard_map(blk, mesh=mesh,
                     in_specs=(P(meshctx.NODE_AXIS, None),
                               P(meshctx.NODE_AXIS)),
                     out_specs=P(meshctx.NODE_AXIS))(a, x)


def node_head_matmul(a, onehot, h):
    """FACADE's Eq. 4 receive contraction
    ``recv[i, c, ...] = sum_j a[i, j] onehot[j, c] h[j, ...]``
    (``einsum("ij,jc,j...->ic...")``) — same sharding story as
    :func:`node_matmul`: row-sharded ``a``, all-gathered senders."""
    mesh = meshctx.current()
    if mesh is None:
        return jnp.einsum("ij,jc,j...->ic...", a, onehot, h)

    def blk(a_blk, o_blk, h_blk):
        og = jax.lax.all_gather(o_blk, meshctx.NODE_AXIS, tiled=True)
        hg = jax.lax.all_gather(h_blk, meshctx.NODE_AXIS, tiled=True)
        return jnp.einsum("ij,jc,j...->ic...", a_blk, og, hg)

    return shard_map(blk, mesh=mesh,
                     in_specs=(P(meshctx.NODE_AXIS, None),
                               P(meshctx.NODE_AXIS),
                               P(meshctx.NODE_AXIS)),
                     out_specs=P(meshctx.NODE_AXIS))(a, onehot, h)


def node_vmap(fn):
    """``jax.vmap`` over the node axis, partitioned over the active node
    mesh. Outside a mesh trace context this IS ``jax.vmap(fn)`` — same
    jaxpr, bit for bit. Under :func:`repro.core.meshctx.activate` the
    vmapped body runs inside ``shard_map``, so each device maps only its
    own node block. Load-bearing for the sharded engine's scaling: XLA
    lowers a vmapped convolution to a grouped conv whose node axis lands
    in the FEATURE dimension, which GSPMD replicates (all-gathering every
    activation) rather than shards — so without this wrapper the whole
    local-training phase runs in full on every device. Per-node
    arithmetic is untouched either way; every argument and result must be
    node-stacked (leading dim n)."""
    mesh = meshctx.current()
    if mesh is None:
        return jax.vmap(fn)

    def call(*args):
        def row(l):
            return P(meshctx.NODE_AXIS, *([None] * (l.ndim - 1)))

        in_specs = jax.tree.map(row, args)
        out_sds = jax.eval_shape(jax.vmap(fn), *args)
        out_specs = jax.tree.map(
            lambda s: P(meshctx.NODE_AXIS,
                        *([None] * (len(s.shape) - 1))), out_sds)
        return shard_map(jax.vmap(fn), mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)(*args)

    return call


class Binding(NamedTuple):
    cfg: Any
    init: Callable
    head_keys: tuple
    loss: Callable          # (params, batch) -> scalar
    features: Callable      # (core, batch) -> feats
    head_loss: Callable     # (head, feats, batch) -> scalar


def local_sgd(binding: "Binding", params, batches_h, lr):
    """H plain-SGD steps (paper step 2d) on one node's params.

    ``batches_h``: pytree with leading [H, ...]. Shared by FACADE and every
    baseline round function — one arithmetic definition keeps the scan
    engine's parity guarantees algorithm-independent.
    """
    def step(p, batch):
        g = jax.grad(binding.loss)(p, batch)
        p = jax.tree.map(lambda w, gg: (w - lr * gg).astype(w.dtype), p, g)
        return p, None

    params, _ = jax.lax.scan(step, params, batches_h)
    return params


def gossip_mix(w, tree, visible=None, guard=None):
    """Row-stochastic gossip mixing (Eq. 3): ``out_i = sum_j W_ij x_j``
    over node-stacked pytrees — THE one mixing definition shared by FACADE
    and every baseline, so the engine's parity guarantees stay
    algorithm-independent (like :func:`local_sgd` for the local phase).

    ``visible`` (async stale gossip, ``netwire.stale_view`` /
    ``netwire.sent_view``): an optional same-structure tree of the
    per-node snapshots *neighbors observe* — stale nodes expose their
    last published state there. Neighbor terms then read ``visible``
    while each node's self-term always uses its own fresh leaf:
    ``out_i = sum_j W_ij v_j + W_ii (x_i - v_i)``. With no stale node
    (``visible == tree``) the correction is exactly zero.

    ``guard`` (robust aggregation, :func:`repro.resil.guard_of`): when a
    :class:`repro.resil.FaultConfig` is supplied, the mix degrades
    gracefully under poisoned payloads instead of NaN'ing every receiver:

    * **quarantine** — senders with ANY non-finite float leaf lose their
      off-diagonal weight entirely and each row of ``W`` is renormalized
      over its surviving neighbors (self weight always kept), so one
      NaN'd node costs its neighbors one contribution, not their state;
    * **norm clip** — every surviving neighbor's contribution is scaled
      by ``min(1, clip * ||self|| / ||sender||)``: a blown-up payload
      contributes at most ``clip`` times the receiver's own norm in the
      sender's direction. Honest payloads (comparable norms) are scaled
      by exactly 1.0's neighborhood, so degradation is smooth.

    ``guard=None`` (every zero-rate off-switch) is bit-for-bit the
    historical arithmetic — the guard's renormalization must never touch
    honest runs (``mixing_matrix`` rows are only float-tolerance
    stochastic, so renormalizing would perturb bits).
    """
    if guard is None:
        if visible is None:
            return jax.tree.map(
                lambda p: node_matmul(w.astype(p.dtype), p), tree)
        diag = jnp.diagonal(w)

        def mix(p, v):
            out = node_matmul(w.astype(p.dtype), v.astype(p.dtype))
            d = diag.reshape((diag.shape[0],) + (1,) * (p.ndim - 1))
            return (out + d.astype(p.dtype)
                    * (p - v.astype(p.dtype))).astype(p.dtype)

        return jax.tree.map(mix, tree, visible)

    from repro import resil   # local import: resil must stay core-free
    v_tree = tree if visible is None else visible
    n = w.shape[0]
    finite = resil.node_finite(v_tree)                         # [n]
    vnorm = jnp.where(finite > 0, resil.node_norm(v_tree), 1.0)
    pnorm = resil.node_norm(tree)                              # own, fresh
    eye = jnp.eye(n, dtype=w.dtype)
    off = 1.0 - eye
    # quarantine: drop poisoned senders' off-diagonal mass, renormalize
    # each row over the survivors (the self weight is always kept)
    wq = w * off * finite[None, :] + w * eye
    wr = wq / jnp.maximum(wq.sum(axis=1, keepdims=True), 1e-12)
    # norm clip: cap each neighbor's contribution at `clip` x own norm
    scale = jnp.minimum(1.0, guard.clip * jnp.maximum(pnorm, 1e-12)[:, None]
                        / jnp.maximum(vnorm, 1e-12)[None, :])
    scale = scale * off + eye          # never clip the self term
    ws = wr * scale
    diag = jnp.diagonal(wr)

    def mix(p, v):
        m = finite.reshape((n,) + (1,) * (p.ndim - 1))
        # zero quarantined leaves BEFORE the einsum: 0-weight x NaN = NaN
        vs = jnp.where(m > 0, v.astype(p.dtype), 0).astype(p.dtype)
        out = node_matmul(ws.astype(p.dtype), vs)
        d = diag.reshape((n,) + (1,) * (p.ndim - 1))
        return (out + d.astype(p.dtype) * (p - vs)).astype(p.dtype)

    return jax.tree.map(mix, tree, v_tree)


def _untie_lm_head(cfg, params, key):
    if "lm_head" not in params:
        params = dict(params)
        params["lm_head"] = layers.dense_init(
            key, cfg.d_model, cfg.vocab_size, cfg.dt, scale=0.02)
    return params


def make_binding(cfg) -> Binding:
    if isinstance(cfg, CNNConfig):
        return _cnn_binding(cfg)
    if cfg.encoder_layers > 0:
        return _whisper_binding(cfg)
    return _lm_binding(cfg)


# --------------------------------------------------------------------------
def _cnn_binding(cfg: CNNConfig) -> Binding:
    hk = cnn.head_keys(cfg)

    def loss(params, batch):
        return cnn.loss_fn(cfg, params, batch)[0]

    def features(core, batch):
        return cnn.features(cfg, core, batch["x"])

    def head_loss(head, feats, batch):
        logits = cnn.head_apply(cfg, head, feats)
        return layers.softmax_xent(logits, batch["y"])

    return Binding(cfg, lambda k: cnn.init_params(cfg, k), hk, loss,
                   features, head_loss)


# --------------------------------------------------------------------------
def _lm_binding(cfg: ModelConfig) -> Binding:
    hk = ("final_norm", "lm_head")

    def init(key):
        k1, k2 = jax.random.split(key)
        return _untie_lm_head(cfg, transformer.init_params(cfg, k1), k2)

    def loss(params, batch):
        return transformer.loss_fn(cfg, params, batch)[0]

    def features(core, batch):
        feats, _ = transformer.forward(cfg, core, batch["tokens"],
                                       img_embeds=batch.get("img_embeds"),
                                       apply_final_norm=False)
        n_img = (0 if batch.get("img_embeds") is None
                 else batch["img_embeds"].shape[1])
        return feats[:, n_img:]

    def head_loss(head, feats, batch):
        h = layers.rms_norm(feats, head["final_norm"], cfg.norm_eps)
        l, _ = transformer.chunked_ce(h, head["lm_head"], batch["labels"],
                                      batch["mask"].astype(jnp.float32))
        return l

    return Binding(cfg, init, hk, loss, features, head_loss)


# --------------------------------------------------------------------------
def _whisper_binding(cfg: ModelConfig) -> Binding:
    hk = ("final_norm", "lm_head")

    def init(key):
        k1, k2 = jax.random.split(key)
        return _untie_lm_head(cfg, whisper.init_params(cfg, k1), k2)

    def loss(params, batch):
        return whisper.loss_fn(cfg, params, batch)[0]

    def features(core, batch):
        feats, _ = whisper.forward(cfg, core, batch["tokens"],
                                   batch["frames"], apply_final_norm=False)
        return feats

    def head_loss(head, feats, batch):
        h = layers.layer_norm(feats, head["final_norm"]["g"],
                              head["final_norm"]["b"], cfg.norm_eps)
        l, _ = transformer.chunked_ce(h, head["lm_head"], batch["labels"],
                                      batch["mask"].astype(jnp.float32))
        return l

    return Binding(cfg, init, hk, loss, features, head_loss)
