"""The FACADE algorithm (paper Sec. III-D), fully jit-compiled.

One call to ``facade_round`` executes, for ALL nodes at once:

    1. randomized r-regular topology                      (step 1)
    2. core aggregation (Eq. 3) + cluster-wise head aggregation (Eq. 4)
    3. cluster identification: argmin_j loss(core ∘ head_j)  (step 2c)
    4. H local SGD steps on (core, selected head)            (step 2d)
    5. write trained head into the selected slot; report cluster ID

Node states are stacked (leading ``n`` axis); gossip is an einsum with the
round's mixing matrix. In simulation mode the node axis lives on one device;
in production mode it is sharded over the ``pod`` mesh axis and GSPMD turns
the einsums into cross-pod collectives (see launch/shardings.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import netsim
from repro import topo as topo_mod

from . import split, topology
from .bindings import Binding, gossip_mix, local_sgd
from .netwire import comm_info, masked_topology, stale_view
from .state import FacadeState, freeze_inactive


@dataclasses.dataclass(frozen=True)
class FacadeConfig:
    n_nodes: int
    k: int                    # number of cluster heads (paper hyperparam)
    degree: int = 4           # topology degree r (paper: 4)
    local_steps: int = 10     # H / tau (paper: 10; Flickr-Mammals 40)
    lr: float = 0.01
    warmup_rounds: int = 0    # App. F: initial EL-style shared-head rounds
    head_jitter: float = 0.0


# --------------------------------------------------------------------------
def _aggregate_heads(adj, cluster_id, heads, k, sent_heads=None):
    """Eq. 4: for each node i and cluster j, average the heads *sent* by
    neighbors claiming cluster j together with i's own stored head j.

    heads [n, k, ...]; sent head of node j' = sent_heads[j', cid_j'].
    ``cluster_id``/``sent_heads`` describe what each node PUBLISHES this
    round (under async gossip a stale node publishes its old snapshot);
    ``heads`` is always the receiver's own fresh stored bank.
    """
    n = adj.shape[0]
    if sent_heads is None:
        sent_heads = heads
    sent = jax.tree.map(
        lambda h: h[jnp.arange(n), cluster_id], sent_heads)  # [n, ...]
    onehot = jax.nn.one_hot(cluster_id, k, dtype=jnp.float32)  # [n, k]
    # cnt[i, c] = number of neighbors of i claiming cluster c
    cnt = jnp.einsum("ij,jc->ic", adj, onehot)              # [n, k]
    denom = 1.0 + cnt                                        # + own stored head

    def agg(h_all, h_sent):
        recv = jnp.einsum("ij,jc,j...->ic...", adj.astype(h_sent.dtype),
                          onehot.astype(h_sent.dtype), h_sent)
        d = denom.reshape(denom.shape + (1,) * (h_all.ndim - 2))
        return ((h_all + recv) / d.astype(h_all.dtype)).astype(h_all.dtype)

    return jax.tree.map(agg, heads, sent)


def _select_heads(binding: Binding, cores, heads, batches):
    """losses [n, k] via shared core features (paper III-E optimization)."""
    def per_node(core, heads_k, batch):
        feats = binding.features(core, batch)
        return jax.vmap(lambda h: binding.head_loss(h, feats, batch))(heads_k)

    return jax.vmap(per_node)(cores, heads, batches)        # [n, k]


# --------------------------------------------------------------------------
def facade_round(fcfg: FacadeConfig, binding: Binding, state: FacadeState,
                 batches, warmup: bool = False, net=None, gossip=None,
                 topo=None, topo_cfg=None):
    """One synchronous FACADE round for all nodes.

    batches: pytree with leading [n, H, B, ...] — per-node, per-local-step.
    net: optional ``netsim.RoundConditions`` (edge_mask/active/straggler
    masks). ``None`` is the exact ideal-medium code path; with masks, the
    drawn topology is filtered through :func:`topology.effective_adjacency`,
    churned-out nodes neither mix nor train (state frozen), and comm bytes
    count the directed edges that actually carried a message.
    gossip: optional async-gossip published-snapshot dict (``cores`` /
    ``heads`` / ``cluster_id``): stale nodes (``net.stale``) expose those
    to their neighbors instead of this round's fresh state.
    topo/topo_cfg: optional adaptive-topology state + static policy
    (:mod:`repro.topo`) — an adaptive policy replaces the uniform
    r-regular draw (same PRNG split, so the uniform policy stays
    bit-for-bit the legacy path).
    Returns (new_state, info dict with losses/selection/comm bytes).
    """
    n, k = fcfg.n_nodes, fcfg.k
    key, subkey = jax.random.split(state.rng)
    if topo_mod.adaptive(topo_cfg):
        adj = topo_mod.sample(topo_cfg, topo, subkey, n, fcfg.degree)
    else:
        adj = topology.random_regular(subkey, n, fcfg.degree)
    adj = masked_topology(net, adj)
    w = topology.mixing_matrix(adj)

    # --- what each node publishes this round (== its fresh state unless
    # --- it stays stale under async gossip) ---
    vis_cores = stale_view(net, None if gossip is None else gossip["cores"],
                           state.cores)
    sent_heads, sent_cid = None, state.cluster_id
    if gossip is not None and net is not None and net.stale is not None:
        sent_heads = netsim.tree_select(net.stale, gossip["heads"],
                                        state.heads)
        sent_cid = jnp.where(net.stale > 0, gossip["cluster_id"],
                             state.cluster_id).astype(jnp.int32)

    # --- aggregation (steps 2a/2b) ---
    cores = gossip_mix(w, state.cores, vis_cores)
    heads = _aggregate_heads(adj, sent_cid, state.heads, k,
                             sent_heads=sent_heads)

    # --- cluster identification (step 2c) on the first local batch ---
    first = jax.tree.map(lambda b: b[:, 0], batches)
    losses = _select_heads(binding, cores, heads, first)     # [n, k]
    new_cid = jnp.argmin(losses, axis=1).astype(jnp.int32)
    if warmup:  # App. F: shared-head warmup trains head 0 everywhere
        new_cid = jnp.zeros((n,), jnp.int32)

    # --- local training (step 2d) ---
    def train_node(core, heads_k, cid, node_batches):
        head = split.select_head(heads_k, cid)
        params = split.merge_params(core, head)
        params = local_sgd(binding, params, node_batches, fcfg.lr)
        new_core, new_head = split.split_params(params, binding.head_keys)
        if warmup:  # broadcast the trained head to every slot
            heads_k = split.stack_heads(new_head, k)
        else:
            heads_k = split.set_head(heads_k, cid, new_head)
        return new_core, heads_k

    new_cores, new_heads = jax.vmap(train_node)(cores, heads, new_cid,
                                                batches)

    # --- communication accounting: each node pushes (core, head, cid) ---
    core_bytes = split.tree_size_bytes(
        jax.tree.map(lambda l: l[0], state.cores))
    head_bytes = split.tree_size_bytes(
        jax.tree.map(lambda l: l[0, 0], state.heads))
    payload = core_bytes + head_bytes + 4
    if net is not None:
        new_cid = jnp.where(net.active > 0, new_cid, state.cluster_id)
        new_cores = freeze_inactive(net.active, new_cores, state.cores)
        new_heads = freeze_inactive(net.active, new_heads, state.heads)

    new_state = FacadeState(cores=new_cores, heads=new_heads,
                            cluster_id=new_cid, round=state.round + 1,
                            rng=key)
    info = {
        "selection_losses": losses,
        "cluster_id": new_cid,
        **comm_info(net, adj, payload, n * fcfg.degree,
                    actual=topo_mod.adaptive(topo_cfg)),
    }
    return new_state, info


# --------------------------------------------------------------------------
def final_allreduce(fcfg: FacadeConfig, state: FacadeState) -> FacadeState:
    """Paper Sec. V-A: a final all-reduce where every node shares its model
    with everyone and aggregates cluster-wise."""
    n, k = fcfg.n_nodes, fcfg.k
    adj = topology.fully_connected(n)
    w = topology.mixing_matrix(adj)
    cores = gossip_mix(w, state.cores)
    heads = _aggregate_heads(adj, state.cluster_id, state.heads, k)
    return state._replace(cores=cores, heads=heads)


def node_models(state: FacadeState, binding: Binding):
    """Merged per-node deployable models, stacked [n, ...]."""
    def pick(core, heads_k, cid):
        return split.merge_params(core, split.select_head(heads_k, cid))

    return jax.vmap(pick)(state.cores, state.heads, state.cluster_id)
