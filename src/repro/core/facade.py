"""The FACADE algorithm (paper Sec. III-D), fully jit-compiled.

One call to ``facade_round`` executes, for ALL nodes at once:

    1. randomized r-regular topology                      (step 1)
    2. core aggregation (Eq. 3) + cluster-wise head aggregation (Eq. 4)
    3. cluster identification: argmin_j loss(core ∘ head_j)  (step 2c)
    4. H local SGD steps on (core, selected head)            (step 2d)
    5. write trained head into the selected slot; report cluster ID

Node states are stacked (leading ``n`` axis); gossip is an einsum with the
round's mixing matrix. In simulation mode the node axis lives on one device;
in production mode it is sharded over the ``pod`` mesh axis and GSPMD turns
the einsums into cross-pod collectives (see launch/shardings.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import resil
from repro import topo as topo_mod

from . import split, topology
from .bindings import (Binding, gossip_mix, local_sgd, node_head_matmul,
                       node_matmul, node_vmap)
from .netwire import comm_info, masked_topology, sent_view
from .state import FacadeState, freeze_inactive


@dataclasses.dataclass(frozen=True)
class FacadeConfig:
    n_nodes: int
    k: int                    # number of cluster heads (paper hyperparam)
    degree: int = 4           # topology degree r (paper: 4)
    local_steps: int = 10     # H / tau (paper: 10; Flickr-Mammals 40)
    lr: float = 0.01
    warmup_rounds: int = 0    # App. F: initial EL-style shared-head rounds
    head_jitter: float = 0.0


# --------------------------------------------------------------------------
def _aggregate_heads(adj, cluster_id, heads, k, sent_heads=None,
                     guard=None):
    """Eq. 4: for each node i and cluster j, average the heads *sent* by
    neighbors claiming cluster j together with i's own stored head j.

    heads [n, k, ...]; sent head of node j' = sent_heads[j', cid_j'].
    ``cluster_id``/``sent_heads`` describe what each node PUBLISHES this
    round (under async gossip a stale node publishes its old snapshot;
    under payload corruption it may be mangled); ``heads`` is always the
    receiver's own fresh stored bank.

    ``guard`` (:func:`repro.resil.guard_of`): the head-bank analogue of
    ``gossip_mix``'s robust guard — a sender whose published head is
    non-finite is quarantined (dropped from both the sum AND the count),
    and finite senders are norm-clipped against the receiver's own
    per-slot RMS head norm. ``None`` is the bit-exact legacy arithmetic.
    """
    n = adj.shape[0]
    if sent_heads is None:
        sent_heads = heads
    sent = jax.tree.map(
        lambda h: h[jnp.arange(n), cluster_id], sent_heads)  # [n, ...]
    onehot = jax.nn.one_hot(cluster_id, k, dtype=jnp.float32)  # [n, k]
    adj_w = adj
    if guard is not None:
        finite = resil.node_finite(sent)                     # [n]
        snorm = jnp.where(finite > 0, resil.node_norm(sent), 1.0)
        own = resil.node_norm(heads) / jnp.sqrt(float(k))    # per-slot RMS
        clip = jnp.minimum(
            1.0, guard.clip * jnp.maximum(own, 1e-12)[:, None]
            / jnp.maximum(snorm, 1e-12)[None, :])            # [n, n]
        # quarantined senders leave both the weighted sum and the count;
        # their (possibly NaN) head leaves are zeroed before the einsum
        adj = adj * finite[None, :]
        adj_w = adj * clip
        sent = resil_tree_zero(sent, finite)
    # cnt[i, c] = number of neighbors of i claiming cluster c
    cnt = node_matmul(adj, onehot)                          # [n, k]
    denom = 1.0 + cnt                                        # + own stored head

    def agg(h_all, h_sent):
        recv = node_head_matmul(adj_w.astype(h_sent.dtype),
                                onehot.astype(h_sent.dtype), h_sent)
        d = denom.reshape(denom.shape + (1,) * (h_all.ndim - 2))
        return ((h_all + recv) / d.astype(h_all.dtype)).astype(h_all.dtype)

    return jax.tree.map(agg, heads, sent)


def resil_tree_zero(tree, keep):
    """Zero float leaves of nodes with ``keep == 0`` along the leading
    axis (quarantine hygiene: 0-weight x NaN is still NaN in an einsum)."""
    def z(l):
        if not jnp.issubdtype(l.dtype, jnp.floating):
            return l
        m = keep.reshape((keep.shape[0],) + (1,) * (l.ndim - 1))
        return jnp.where(m > 0, l, 0).astype(l.dtype)

    return jax.tree.map(z, tree)


def _select_heads(binding: Binding, cores, heads, batches):
    """losses [n, k] via shared core features (paper III-E optimization)."""
    def per_node(core, heads_k, batch):
        feats = binding.features(core, batch)
        return jax.vmap(lambda h: binding.head_loss(h, feats, batch))(heads_k)

    return node_vmap(per_node)(cores, heads, batches)       # [n, k]


# --------------------------------------------------------------------------
def facade_round(fcfg: FacadeConfig, binding: Binding, state: FacadeState,
                 batches, warmup: bool = False, net=None, gossip=None,
                 topo=None, topo_cfg=None, fault_cfg=None):
    """One synchronous FACADE round for all nodes.

    batches: pytree with leading [n, H, B, ...] — per-node, per-local-step.
    net: optional ``netsim.RoundConditions`` (edge_mask/active/straggler
    masks). ``None`` is the exact ideal-medium code path; with masks, the
    drawn topology is filtered through :func:`topology.effective_adjacency`,
    churned-out nodes neither mix nor train (state frozen), and comm bytes
    count the directed edges that actually carried a message.
    gossip: optional async-gossip published-snapshot dict (``cores`` /
    ``heads`` / ``cluster_id``): stale nodes (``net.stale``) expose those
    to their neighbors instead of this round's fresh state.
    topo/topo_cfg: optional adaptive-topology state + static policy
    (:mod:`repro.topo`) — an adaptive policy replaces the uniform
    r-regular draw (same PRNG split, so the uniform policy stays
    bit-for-bit the legacy path).
    fault_cfg: optional static :class:`repro.resil.FaultConfig` — payload
    corruption mangles what a flagged node delivers (``netwire.sent_view``)
    and, when robust, the aggregation guard quarantines/clips poisoned
    senders in BOTH the core mix and the head aggregation.
    Returns (new_state, info dict with losses/selection/comm bytes).
    """
    n, k = fcfg.n_nodes, fcfg.k
    key, subkey = jax.random.split(state.rng)
    if topo_mod.adaptive(topo_cfg):
        adj = topo_mod.sample(topo_cfg, topo, subkey, n, fcfg.degree)
    else:
        adj = topology.random_regular(subkey, n, fcfg.degree)
    adj = masked_topology(net, adj)
    w = topology.mixing_matrix(adj)

    # --- what each node's neighbors receive this round (== its fresh
    # --- state unless it stays stale under async gossip or ships a
    # --- corrupted payload under fault injection) ---
    fresh = {"cores": state.cores, "heads": state.heads,
             "cluster_id": state.cluster_id}
    sent = sent_view(net, gossip, fresh, fault_cfg)
    if sent is None:
        vis_cores, sent_heads, sent_cid = None, None, state.cluster_id
    else:
        vis_cores, sent_heads = sent["cores"], sent["heads"]
        sent_cid = sent["cluster_id"]

    # --- aggregation (steps 2a/2b) ---
    guard = resil.guard_of(fault_cfg)
    cores = gossip_mix(w, state.cores, vis_cores, guard=guard)
    heads = _aggregate_heads(adj, sent_cid, state.heads, k,
                             sent_heads=sent_heads, guard=guard)

    # --- cluster identification (step 2c) on the first local batch ---
    first = jax.tree.map(lambda b: b[:, 0], batches)
    losses = _select_heads(binding, cores, heads, first)     # [n, k]
    new_cid = jnp.argmin(losses, axis=1).astype(jnp.int32)
    if warmup:  # App. F: shared-head warmup trains head 0 everywhere
        new_cid = jnp.zeros((n,), jnp.int32)

    # --- local training (step 2d) ---
    def train_node(core, heads_k, cid, node_batches):
        head = split.select_head(heads_k, cid)
        params = split.merge_params(core, head)
        params = local_sgd(binding, params, node_batches, fcfg.lr)
        new_core, new_head = split.split_params(params, binding.head_keys)
        if warmup:  # broadcast the trained head to every slot
            heads_k = split.stack_heads(new_head, k)
        else:
            heads_k = split.set_head(heads_k, cid, new_head)
        return new_core, heads_k

    new_cores, new_heads = node_vmap(train_node)(cores, heads, new_cid,
                                                 batches)

    # --- communication accounting: each node pushes (core, head, cid) ---
    core_bytes = split.tree_size_bytes(
        jax.tree.map(lambda l: l[0], state.cores))
    head_bytes = split.tree_size_bytes(
        jax.tree.map(lambda l: l[0, 0], state.heads))
    payload = core_bytes + head_bytes + 4
    if net is not None:
        new_cid = jnp.where(net.active > 0, new_cid, state.cluster_id)
        new_cores = freeze_inactive(net.active, new_cores, state.cores)
        new_heads = freeze_inactive(net.active, new_heads, state.heads)

    new_state = FacadeState(cores=new_cores, heads=new_heads,
                            cluster_id=new_cid, round=state.round + 1,
                            rng=key)
    info = {
        "selection_losses": losses,
        "cluster_id": new_cid,
        "quarantined": resil.quarantined_count(guard, sent),
        **comm_info(net, adj, payload, n * fcfg.degree,
                    actual=topo_mod.adaptive(topo_cfg)),
    }
    return new_state, info


# --------------------------------------------------------------------------
def final_allreduce(fcfg: FacadeConfig, state: FacadeState) -> FacadeState:
    """Paper Sec. V-A: a final all-reduce where every node shares its model
    with everyone and aggregates cluster-wise."""
    n, k = fcfg.n_nodes, fcfg.k
    adj = topology.fully_connected(n)
    w = topology.mixing_matrix(adj)
    cores = gossip_mix(w, state.cores)
    heads = _aggregate_heads(adj, state.cluster_id, state.heads, k)
    return state._replace(cores=cores, heads=heads)


def node_models(state: FacadeState, binding: Binding):
    """Merged per-node deployable models, stacked [n, ...]."""
    def pick(core, heads_k, cid):
        return split.merge_params(core, split.select_head(heads_k, cid))

    return jax.vmap(pick)(state.cores, state.heads, state.cluster_id)
