"""Node-axis mesh plumbing for the sharded segment engine.

The engine's ``mesh=`` path lays the donated :class:`EngineCarry` out over
a 1-D ``node`` device mesh (leading-``n`` leaves row-sharded, everything
else replicated) and routes the cross-node contractions in
:mod:`repro.core.bindings` through ``shard_map`` row blocks. This module
owns the three pieces everything shares:

* the canonical mesh description — a SHAPE tuple like ``(8,)``, which is
  what :class:`repro.core.cache.EngineSpec` keys on (device objects never
  enter cache keys or checkpoint fingerprints) — plus :func:`build`, which
  turns it into a live ``jax.sharding.Mesh`` over host devices;
* the carry layout rule (:func:`node_spec` / :func:`carry_shardings`):
  a leaf whose leading dim equals ``n`` is ``P('node', None, ...)`` —
  so ``[n, n]`` mixing weights, ``ChannelState.bad``, link matrices and
  topo/fault masks all shard along ROWS — and every other leaf (scalars,
  PRNG keys) is replicated;
* the TRACE-TIME context (:func:`activate` / :func:`current`): the engine
  traces its segment program inside ``activate(mesh)``, and the bindings'
  contraction helpers consult :func:`current` to decide between the plain
  einsum and the shard_map row-block form. ``mesh=None`` never activates
  a context, so that path stays bit-for-bit the historical single-device
  arithmetic — same jaxpr, same program.

Forced host devices (``XLA_FLAGS=--xla_force_host_platform_device_count``)
must be set BEFORE the first jax import — the ``launch/dryrun.py`` /
``benchmarks/scale_curve.py`` subprocess pattern.
"""
from __future__ import annotations

import contextlib

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

NODE_AXIS = "node"

_ACTIVE: list = []   # trace-time stack; [-1] is the mesh being traced under


def normalize(mesh):
    """Canonicalize a user-facing ``mesh=`` argument to the shape tuple the
    cache keys on: ``None`` | int | 1-tuple | ``Mesh`` -> ``None`` or
    ``(n_devices,)``. Multi-axis meshes are rejected — the engine shards
    exactly one axis (the node axis)."""
    if mesh is None:
        return None
    if isinstance(mesh, Mesh):
        shape = tuple(int(s) for s in mesh.devices.shape)
    elif isinstance(mesh, int):
        shape = (int(mesh),)
    else:
        shape = tuple(int(s) for s in mesh)
    if len(shape) != 1:
        raise ValueError(
            f"mesh shape {shape} has {len(shape)} axes; the segment engine "
            "shards exactly one axis (the node axis) — pass an int, a "
            "1-tuple like (8,), or a 1-D Mesh")
    if shape[0] < 1:
        raise ValueError(f"mesh needs at least 1 device, got {shape[0]}")
    return shape


def build(shape) -> "Mesh | None":
    """Shape tuple -> live 1-D node mesh over the first ``shape[0]`` host
    devices (``None`` passes through)."""
    if shape is None:
        return None
    (size,) = normalize(shape)
    devices = jax.devices()
    if size > len(devices):
        raise RuntimeError(
            f"node mesh ({size},) needs {size} devices, have "
            f"{len(devices)}; set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={size} BEFORE importing jax (the "
            "launch/dryrun.py subprocess pattern)")
    return Mesh(np.asarray(devices[:size]), (NODE_AXIS,))


@contextlib.contextmanager
def activate(mesh: "Mesh | None"):
    """Trace-time marker: while active, the cross-node contractions in
    :mod:`repro.core.bindings` lower as shard_map row blocks over ``mesh``.
    ``None`` is a true no-op so un-meshed callers never pay anything."""
    if mesh is None:
        yield
        return
    _ACTIVE.append(mesh)
    try:
        yield
    finally:
        _ACTIVE.pop()


def current() -> "Mesh | None":
    """The mesh being traced under, or ``None`` outside any context."""
    return _ACTIVE[-1] if _ACTIVE else None


def node_spec(leaf, n: int) -> P:
    """The carry layout rule: leading dim == ``n`` -> rows on the node
    axis, anything else (scalars, PRNG keys, odd shapes) replicated."""
    shape = getattr(leaf, "shape", ())
    if len(shape) >= 1 and shape[0] == n:
        return P(NODE_AXIS, *([None] * (len(shape) - 1)))
    return P()


def carry_shardings(mesh: Mesh, tree, n: int):
    """Pytree of :class:`NamedSharding` mirroring ``tree`` under the
    :func:`node_spec` rule — the layout ``device_put`` commits the carry
    to and ``with_sharding_constraint`` pins at segment boundaries."""
    return jax.tree.map(
        lambda l: NamedSharding(mesh, node_spec(l, n)), tree)


def constrain_tree(tree, n: int):
    """Pin a node-stacked pytree to the active node-mesh layout under the
    :func:`node_spec` rule (identity when no mesh context is active).
    Load-bearing on the per-round batch tree: its gather indices come off
    a REPLICATED PRNG key, so without this pin GSPMD replicates the
    gathered batches — and the whole local-training phase downstream of
    them — onto every device instead of partitioning over nodes."""
    mesh = current()
    if mesh is None:
        return tree
    return jax.lax.with_sharding_constraint(
        tree, carry_shardings(mesh, tree, n))


def constrain_rows(a):
    """Pin a node-leading array's rows to the active node mesh (identity
    when no mesh context is active) — keeps GSPMD from replicating the
    per-round ``[n, n]`` adjacency/mask intermediates across devices."""
    mesh = current()
    if mesh is None:
        return a
    return jax.lax.with_sharding_constraint(
        a, NamedSharding(mesh, P(NODE_AXIS, *([None] * (a.ndim - 1)))))
