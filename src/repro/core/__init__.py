"""FACADE — the paper's primary contribution — plus the three baselines."""
from .bindings import Binding, make_binding  # noqa: F401
from .facade import (FacadeConfig, facade_round, final_allreduce,  # noqa: F401
                     node_models)
from .state import (BaselineState, FacadeState, init_baseline_state,  # noqa: F401
                    init_facade_state, node_model)
