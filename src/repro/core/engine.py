"""Scan-fused segment engine: whole eval-to-eval spans in one XLA dispatch.

The legacy driver pays, per round: an eager ``sample_round_batches``, a
jitted conditions call, a jitted round call, a jitted timing call, and a
forced device->host sync (``float(round_bytes)``). At paper scale (5
algorithms x seeds x hundreds of rounds x netsim presets) that per-round
overhead dominates the tiny per-round compute.

This module folds everything between two evals into one ``lax.scan``:

* per-round batch sampling runs on device, keyed off a split of the
  carried PRNG (bit-identical to the legacy eager sampling);
* ``netsim.round_conditions`` is computed inside the scan from the scanned
  round counter (``start + arange(length)``);
* the algorithm round function — FACADE or any baseline, all sharing the
  ``fn(state, batches, net=conds) -> (state, info)`` stepper signature —
  advances the node-stacked state, which ``donate_argnums`` updates in
  place instead of copying every round;
* per-round scalars (``round_bytes``, simulated ``round_s``, FACADE's
  cluster ids) come back stacked ``[length, ...]`` and are drained to the
  host in ONE transfer per segment (``CommLog.record_bulk``).

FACADE's warmup/main phase split is two compiled segment variants (the
``warmup`` flag is static), so a run with warmup compiles at most
``{lengths} x {warmup, main}`` segment programs; ``segment_plan`` cuts the
round range at eval boundaries AND at the warmup->main boundary, never
inside a phase. ``target_acc`` early exit therefore happens at segment
granularity — exactly the rounds where the legacy driver evaluated.
"""
from __future__ import annotations

import contextlib
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro import netsim
from repro import resil as resil_mod
from repro import topo as topo_mod
from repro.data import pipeline
from repro.obs import frame as obs_frame

from . import meshctx
from .netwire import round_seconds
from .state import EngineCarry


def _sp(tracer, name, **attrs):
    """Tracer span or no-op — the engine never requires an ``Obs``."""
    if tracer is None:
        return contextlib.nullcontext()
    return tracer.span(name, **attrs)


class Segment(NamedTuple):
    start: int           # first round of the span (0-based)
    length: int          # number of rounds fused into one dispatch
    warmup: bool         # FACADE warmup phase? (static at compile time)
    eval_at_end: bool    # the span's last round is an eval round


def segment_plan(rounds: int, eval_every: int,
                 warmup_rounds: int = 0) -> list[Segment]:
    """Cut ``range(rounds)`` into scan segments.

    Boundaries: every eval round (``(rnd+1) % eval_every == 0`` plus the
    final round — the legacy driver's eval schedule) and the warmup->main
    phase switch (a cut without an eval). Segments never straddle the
    warmup boundary, so the per-segment ``warmup`` flag can stay static.
    """
    evals = set(range(eval_every, rounds + 1, eval_every))
    if rounds > 0:
        evals.add(rounds)
    cuts = {0, rounds} | evals
    if 0 < warmup_rounds < rounds:
        cuts.add(warmup_rounds)
    cuts = sorted(cuts)
    return [Segment(a, b - a, a < warmup_rounds, b in evals)
            for a, b in zip(cuts[:-1], cuts[1:])]


class SegmentEngine:
    """Compiles and runs eval-to-eval spans for one (algorithm, net) pair.

    ``round_fn`` / ``warmup_fn``: the shared stepper signature
    ``fn(state, batches, net=conds, gossip=published, topo=tstate) ->
    (state, info)`` where ``info`` carries ``round_bytes``
    (+ ``adj_eff``/``payload_bytes`` under netsim, + ``cluster_id`` for
    FACADE). ``topo`` is the static :class:`repro.topo.TopoConfig` whose
    per-link EWMA state rides in the carry (``None`` => the legacy
    sampling path). Compiled segment programs are cached per
    ``(length, warmup)``; carries are donated, so the caller must treat the
    passed-in ``EngineCarry`` as consumed.

    ``mesh``: optional 1-D node mesh (``jax.sharding.Mesh`` or anything
    :func:`repro.core.meshctx.normalize` accepts). When set, the carry's
    node axis is laid out over the mesh devices (:meth:`place_carry`),
    the segment program is traced under the mesh context — so the
    cross-node contractions in :mod:`repro.core.bindings` lower as
    shard_map row blocks — and segment boundaries pin the carry layout
    with sharding constraints, keeping donation buffer-compatible across
    dispatches. ``mesh=None`` is bit-for-bit the historical single-device
    path: no context is activated and the traced program is unchanged.
    Per-row arithmetic is identical either way; only reductions ACROSS
    rows (``round_bytes``/``round_s``/obs-frame scalars) may sum in a
    different order on a multi-device mesh.
    """

    def __init__(self, round_fn: Callable, *, n: int, local_steps: int,
                 batch_size: int, net=None, warmup_fn: Callable | None = None,
                 track_cluster: bool = False, mixable_of: Callable | None = None,
                 topo=None, obs=None, mesh=None):
        self._round = round_fn
        self._warm = warmup_fn if warmup_fn is not None else round_fn
        self._net = net
        self._topo = topo           # repro.topo.TopoConfig | None (static)
        self._obs = obs             # repro.obs.ObsConfig | None (static):
        #                             when set, every scanned round also
        #                             emits a MetricsFrame — an extra out
        #                             leaf stacked [length, ...], drained
        #                             in the segment's one device_get
        self._tiers = obs_frame.tiers_of(net, n) if obs is not None else None
        self._n = n
        self._h = local_steps
        self._b = batch_size
        self._track = track_cluster
        self._mixable_of = mixable_of
        self._mesh = meshctx.build(mesh) if not hasattr(mesh, "devices") \
            else mesh
        if self._mesh is not None and n % self._mesh.size != 0:
            raise ValueError(
                f"mesh of {self._mesh.size} devices must divide n={n} "
                "nodes evenly: the carry's node axis is row-sharded in "
                "equal blocks (pad the node count or shrink the mesh)")
        self._compiled: dict[tuple[int, bool], Callable] = {}
        # compile_count tracks XLA compiles, not just fresh (length, warmup)
        # builds: a cached jitted segment RETRACES when the train arrays
        # change shape/dtype (the only traced args whose shapes aren't
        # pinned by the engine's config), so the counter is keyed on those
        # too — sweep drivers assert it plateaus once a cell is warm.
        self._traced: set[tuple] = set()
        self.compile_count = 0

    # -- run-level carry ----------------------------------------------------
    def init_carry(self, state, k_data) -> EngineCarry:
        """Mint the run's :class:`EngineCarry`: algorithm state, data PRNG,
        plus the netsim-v2 on-device state — the Gilbert–Elliott channel
        (``net.burst``) and the async staleness buffer (``net.async_gossip``;
        a leaf-for-leaf COPY of the initial mixable state so the buffer
        never aliases the donated training buffers) — plus the adaptive
        topology policy's link EWMAs (``None`` for uniform/off) and the
        node-crash chain (``net.faults``, :mod:`repro.resil`)."""
        net, n = self._net, self._n
        chan = netsim.init_channel(net, n) if net is not None else None
        gossip = None
        if net is not None and net.async_gossip:
            if self._mixable_of is None:
                raise ValueError(
                    "async_gossip needs mixable_of: construct the "
                    "SegmentEngine with mixable_of=<state -> gossip tree> "
                    "(runner.algo_program provides it)")
            gossip = netsim.init_gossip(net, n, self._mixable_of(state))
        topo = topo_mod.init_state(self._topo, net, n)
        fault = resil_mod.init_state(net, n, state)
        return self.place_carry(
            EngineCarry(state, k_data, chan, gossip, topo, fault))

    def place_carry(self, carry: EngineCarry) -> EngineCarry:
        """Commit the carry to the node-mesh layout (leading-``n`` leaves
        row-sharded, scalars/PRNG keys replicated) — identity when
        ``mesh=None``. Also the checkpoint-resume hook: a carry rebuilt
        from host arrays must be re-placed before dispatch so donation
        reuses correctly laid-out buffers."""
        if self._mesh is None:
            return carry
        return jax.device_put(
            carry, meshctx.carry_shardings(self._mesh, carry, self._n))

    def place_data(self, train_x, train_y):
        """Commit the node-stacked train arrays (leading ``[n, ...]``) to
        the node mesh — identity when ``mesh=None``. One placement per
        run; every segment dispatch then reads its node shard locally."""
        if self._mesh is None:
            return train_x, train_y
        sh = jax.sharding.NamedSharding(
            self._mesh, jax.sharding.PartitionSpec(meshctx.NODE_AXIS))
        return jax.device_put(train_x, sh), jax.device_put(train_y, sh)

    # -- one segment = one jitted scan --------------------------------------
    def _build(self, length: int, warmup: bool) -> Callable:
        round_fn = self._warm if warmup else self._round
        net, n, h, b, track = self._net, self._n, self._h, self._b, self._track
        mixable_of, tcfg = self._mixable_of, self._topo
        ocfg, tiers = self._obs, self._tiers
        mesh = self._mesh
        mix_of = mixable_of if mixable_of is not None else (lambda s: s)

        def segment(carry, start, train_x, train_y):
            # the mesh context is consulted at TRACE time (this body runs
            # under jit tracing): with a mesh, the carry layout is pinned
            # at entry/exit — donation then reuses identically-sharded
            # buffers — and the bindings' contractions see the context;
            # with mesh=None nothing here runs and the jaxpr is unchanged
            with meshctx.activate(mesh):
                if mesh is not None:
                    carry = jax.lax.with_sharding_constraint(
                        carry, meshctx.carry_shardings(mesh, carry, n))
                carry, outs = _scan(carry, start, train_x, train_y)
                if mesh is not None:
                    carry = jax.lax.with_sharding_constraint(
                        carry, meshctx.carry_shardings(mesh, carry, n))
                return carry, outs

        def _scan(carry, start, train_x, train_y):
            def step(carry, rnd):
                prev_state, k_data, chan, gossip, topo, fault = carry
                k_data, k_b = jax.random.split(k_data)
                batches = meshctx.constrain_tree(
                    pipeline.sample_round_batches(k_b, train_x, train_y,
                                                  h, b), n)
                conds = published = None
                if net is not None:
                    conds, chan = netsim.advance_conditions(net, n, rnd,
                                                            chan)
                    conds, fault, restarted = resil_mod.advance(
                        net, n, rnd, conds, fault)
                    if restarted is not None:
                        prev_state = resil_mod.reset_nodes(
                            n, restarted, fault.init, prev_state)
                    conds, published = netsim.apply_async(net, conds, gossip)
                state, info = round_fn(prev_state, batches, net=conds,
                                       gossip=published, topo=topo)
                if published is not None:
                    gossip = netsim.fold_gossip(net, gossip, conds,
                                                mixable_of(state))
                # fold this round's observed conditions into the policy
                # EWMAs AFTER the round: round t samples from what was
                # seen up to t-1 (no-op when topo is off / net is None)
                topo = topo_mod.advance(tcfg, net, topo, conds)
                out = {"round_bytes": info["round_bytes"],
                       "round_s": round_seconds(net, info, conds, h)}
                if track:
                    out["cluster_id"] = info["cluster_id"]
                if ocfg is not None:
                    out["frame"] = obs_frame.compute_frame(
                        ocfg, n, tiers, mix_of(prev_state), mix_of(state),
                        getattr(prev_state, "cluster_id", None),
                        getattr(state, "cluster_id", None), info, conds,
                        gossip)
                return EngineCarry(state, k_data, chan, gossip, topo,
                                   fault), out

            rnds = start + jnp.arange(length, dtype=jnp.int32)
            return jax.lax.scan(step, carry, rnds)

        return jax.jit(segment, donate_argnums=(0,))

    def dispatch_segment(self, carry: EngineCarry, start: int, length: int,
                         train_x, train_y, warmup: bool = False,
                         tracer=None):
        """Enqueue ``length`` rounds in one async dispatch — no host sync.

        Returns ``(new_carry, outs)`` where both are DEVICE values (the
        stacked per-round outs still live on device); pair with
        :meth:`drain` to pull ``outs`` to the host. This is the pipelined
        driver's half-step: it dispatches segment ``t+1`` off the fresh
        carry before draining segment ``t``'s scalars, so host-side
        bookkeeping overlaps device compute. The input ``carry`` is
        donated — consumed either way.

        ``tracer`` wraps the call in a ``compile`` span (first trace of
        this program in this process) or a ``dispatch`` span (async:
        trace + enqueue only).
        """
        key = (length, warmup)
        fn = self._compiled.get(key)
        if fn is None:
            fn = self._compiled[key] = self._build(length, warmup)
        trace_key = key + tuple((a.shape, str(a.dtype))
                                for a in (train_x, train_y))
        fresh = trace_key not in self._traced
        if fresh:
            self._traced.add(trace_key)
            self.compile_count += 1
        with _sp(tracer, "compile" if fresh else "dispatch",
                 length=length, warmup=warmup):
            return fn(carry, jnp.asarray(start, jnp.int32),
                      train_x, train_y)

    def drain(self, outs, tracer=None, length: int | None = None):
        """Pull a dispatched segment's stacked outs to the host (the
        segment's only device->host transfer). In the serialized driver
        the ``drain`` span absorbs device compute + transfer; in the
        pipelined driver the next segment is already running, so the span
        shrinks to the residual wait."""
        with _sp(tracer, "drain",
                 **({} if length is None else {"length": length})):
            return jax.device_get(outs)

    def run_segment(self, carry: EngineCarry, start: int, length: int,
                    train_x, train_y, warmup: bool = False, tracer=None):
        """Advance ``length`` rounds in one dispatch and drain the outs.

        Returns ``(new_carry, outs)`` where ``outs`` is a dict of host
        numpy arrays with leading axis ``length``. Dispatch is async, so
        the drain span absorbs device compute + transfer — the
        serialization the ``pipeline=True`` driver overlaps away via
        :meth:`dispatch_segment` + :meth:`drain`.
        """
        carry, outs = self.dispatch_segment(carry, start, length, train_x,
                                            train_y, warmup=warmup,
                                            tracer=tracer)
        return carry, self.drain(outs, tracer=tracer, length=length)
