"""Shared netsim plumbing for every round function (FACADE + baselines).

Each algorithm's round follows the same contract: draw its topology,
filter it through the round's network conditions, and — when a
``netsim.RoundConditions`` is supplied — report the *effective* adjacency
and per-message payload so the runner can feed the timing model. Keeping
the logic here (used by ``facade_round`` and all four baselines alike)
means adding another algorithm needs no netsim-specific code, and the
byte-accounting contract lives in exactly one place.

netsim v2 additions, all keyed off ``conds.stale`` (the async-gossip
stay-stale mask; ``None`` on every synchronous path):

* :func:`stale_view` — the per-node tree neighbors observe (stale nodes
  expose their published snapshot), fed to ``bindings.gossip_mix``;
* :func:`comm_info` counts no fresh bytes for messages a stale node
  "sends" — its neighbors reuse the cached copy they already hold;
* :func:`round_seconds` drops stale nodes from the round's gating set —
  their compute overlaps later rounds instead of stretching this one.

repro.resil rides the same contracts: :func:`sent_view` composes the
stale view with per-sender payload corruption, and crashed nodes need NO
new accounting — they are ``active == 0``, so ``effective_adjacency``
zeroes their directed edges (0 bytes) and ``round_time``'s ``active``
product keeps them out of the ``round_seconds`` gating set.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro import netsim
from repro import resil

from . import meshctx, topology


def masked_topology(net, adj):
    """Apply the round's drop/churn masks (identity when ``net is None``).

    Every round function routes its drawn topology through here, so this
    is also where the sharded engine pins the ``[n, n]`` adjacency to the
    node mesh's rows (:func:`repro.core.meshctx.constrain_rows` — a no-op
    outside a mesh trace context): downstream masks, mixing weights and
    byte accounting then all inherit the row layout instead of GSPMD
    replicating the per-round matrices on every device."""
    if net is None:
        return meshctx.constrain_rows(adj)
    return meshctx.constrain_rows(
        topology.effective_adjacency(adj, net.edge_mask, net.active))


def stale_view(net, published, fresh):
    """The node-stacked tree *neighbors observe* under async gossip: the
    published snapshot where ``conds.stale == 1``, the fresh leaves
    elsewhere. ``None`` (meaning: everyone fresh, take the plain mixing
    path) whenever async gossip is off or no buffer was supplied."""
    if net is None or published is None or net.stale is None:
        return None
    return netsim.tree_select(net.stale, published, fresh)


def sent_view(net, published, fresh, fault_cfg=None):
    """What each node's neighbors RECEIVE this round: the async stale view
    (:func:`stale_view`) composed with per-sender payload corruption
    (:func:`repro.resil.corrupt_view`). A corrupting node mangles
    whatever it delivers — its fresh state or its stale snapshot alike;
    its own stored state is untouched. Returns ``None`` (plain mixing
    path) when both mechanisms are off — exactly :func:`stale_view`'s
    contract, so every zero-rate off-switch stays bit-for-bit legacy."""
    vis = stale_view(net, published, fresh)
    if (fault_cfg is None or fault_cfg.corrupt_rate <= 0
            or net is None or net.corrupt is None):
        return vis
    return resil.corrupt_view(fault_cfg, net,
                              fresh if vis is None else vis)


def comm_info(net, adj_eff, payload_bytes, nominal_sends, actual=False):
    """round_bytes accounting + netsim extras.

    Without netsim, keep the historical nominal count (``n * degree``
    directed pushes) — unless ``actual`` is set (adaptive topology: the
    drawn graph varies per round, so bytes must count its real directed
    edges even on an ideal medium). Under netsim, count the directed
    edges that actually carried a message this round; under async
    gossip, edges out of a stale node carry no NEW bytes (neighbors
    reuse its cached snapshot), so its rows are excluded.
    """
    payload = jnp.asarray(payload_bytes, jnp.float32)
    if net is None:
        # adj_eff/payload ride along for telemetry (repro.obs frames)
        # even off-netsim; round_bytes keeps its historical definition
        # on every path, and unconsumed extras are dead code to XLA
        if actual:
            return {"round_bytes": adj_eff.sum() * payload_bytes,
                    "adj_eff": adj_eff, "payload_bytes": payload}
        return {"round_bytes": jnp.asarray(
            nominal_sends * payload_bytes, jnp.float32),
            "adj_eff": adj_eff, "payload_bytes": payload}
    sends = adj_eff
    if net.stale is not None:
        sends = adj_eff * (1.0 - net.stale)[:, None]
    return {"round_bytes": sends.sum() * payload_bytes,
            "adj_eff": adj_eff,
            "payload_bytes": payload}


def round_seconds(net, info, conds, local_steps: int):
    """Simulated wall-clock for one round from its ``comm_info`` dict.

    Always a float32 scalar (0 when netsim is off) so the segment engine
    can stack it as a scan output; the legacy per-round driver feeds the
    same ingredients to :func:`repro.netsim.round_time` directly. Stale
    nodes (async gossip) are removed from the gating set — only nodes
    that must finish this round can stretch it.
    """
    if net is None:
        return jnp.float32(0.0)
    active = conds.active
    adj_gate = info["adj_eff"]
    if conds.stale is not None:
        # stale nodes neither gate the round nor make anyone wait on a
        # transfer: receivers reuse the cached snapshot (column mask),
        # and the stale node's own compute overlaps later rounds (gate)
        active = active * (1.0 - conds.stale)
        adj_gate = adj_gate * (1.0 - conds.stale)[None, :]
    return netsim.round_time(net, adj_gate, info["payload_bytes"],
                             active, conds.straggler,
                             local_steps=local_steps)
