"""Shared netsim plumbing for every round function (FACADE + baselines).

Each algorithm's round follows the same contract: draw its topology,
filter it through the round's network conditions, and — when a
``netsim.RoundConditions`` is supplied — report the *effective* adjacency
and per-message payload so the runner can feed the timing model. Keeping
the logic here (used by ``facade_round`` and all four baselines alike)
means adding another algorithm needs no netsim-specific code, and the
byte-accounting contract lives in exactly one place.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro import netsim

from . import topology


def masked_topology(net, adj):
    """Apply the round's drop/churn masks (identity when ``net is None``)."""
    if net is None:
        return adj
    return topology.effective_adjacency(adj, net.edge_mask, net.active)


def comm_info(net, adj_eff, payload_bytes, nominal_sends):
    """round_bytes accounting + netsim extras.

    Without netsim, keep the historical nominal count (``n * degree``
    directed pushes). Under netsim, count the directed edges that actually
    carried a message this round.
    """
    if net is None:
        return {"round_bytes": jnp.asarray(
            nominal_sends * payload_bytes, jnp.float32)}
    return {"round_bytes": adj_eff.sum() * payload_bytes,
            "adj_eff": adj_eff,
            "payload_bytes": jnp.asarray(payload_bytes, jnp.float32)}


def round_seconds(net, info, conds, local_steps: int):
    """Simulated wall-clock for one round from its ``comm_info`` dict.

    Always a float32 scalar (0 when netsim is off) so the segment engine
    can stack it as a scan output; the legacy per-round driver feeds the
    same ingredients to :func:`repro.netsim.round_time` directly.
    """
    if net is None:
        return jnp.float32(0.0)
    return netsim.round_time(net, info["adj_eff"], info["payload_bytes"],
                             conds.active, conds.straggler,
                             local_steps=local_steps)
