"""Randomized communication topologies (paper Sec. III-D step 1).

Each round FACADE (and the EL baseline) draws a fresh random r-regular
undirected graph. We build it jit-compatibly as the union of ``r/2`` random
cyclic permutations (plus their inverses), which yields an r-regular
multigraph whose union over rounds mixes well — the property the paper's
convergence analysis (Remark 1) relies on. DAC uses similarity-weighted
sampling instead; D-PSGD uses a fixed ring/torus.

All functions return a dense adjacency matrix ``A [n, n]`` (float, 0/1,
zero diagonal). The mixing matrix helpers turn A into the row-stochastic
W used for aggregation (uniform weights over neighbors + self, Eq. 3/4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _check_degree(n: int, r: int):
    """Degrees at or above ``n`` used to silently collapse into
    multi-edges (a nominally r-regular draw quietly delivering degree
    <= n - 1); fail loudly instead. ``n`` and ``r`` are static Python
    ints, so this runs at trace time and costs nothing jitted."""
    if not 1 <= r < n:
        raise ValueError(
            f"degree={r} out of range for n={n} nodes: a simple graph "
            f"supports 1 <= degree <= n - 1 (multi-edges collapse)")


def random_regular(key, n: int, r: int):
    """Random r-regular-ish undirected graph via r/2 random cycles.

    For odd r the last 'half-edge' round adds one extra random matching.
    Guaranteed: symmetric, zero diagonal, every node degree >= r//2*2 and
    <= r (multi-edges collapse). Matches EL's 'sample s out-neighbors'
    spirit while staying jit-friendly (no rejection sampling).
    Raises ``ValueError`` when ``r`` is outside ``[1, n - 1]``.
    """
    _check_degree(n, r)
    a = jnp.zeros((n, n), jnp.float32)
    n_cycles = max(1, r // 2)
    keys = jax.random.split(key, n_cycles + 1)
    for i in range(n_cycles):
        perm = jax.random.permutation(keys[i], n)
        src = perm
        dst = jnp.roll(perm, 1)
        a = a.at[src, dst].set(1.0)
        a = a.at[dst, src].set(1.0)
    if r % 2 == 1:
        # one extra matching: pair consecutive nodes of a random permutation
        perm = jax.random.permutation(keys[-1], n)
        half = n // 2
        u, v = perm[:half], perm[half:2 * half]
        a = a.at[u, v].set(1.0)
        a = a.at[v, u].set(1.0)
    a = a * (1.0 - jnp.eye(n))
    return a


def ring(n: int, r: int = 2):
    """Static ring (D-PSGD default) with r//2 hops each side.
    Raises ``ValueError`` when ``r`` is outside ``[1, n - 1]``."""
    _check_degree(n, r)
    a = jnp.zeros((n, n), jnp.float32)
    idx = jnp.arange(n)
    for hop in range(1, max(1, r // 2) + 1):
        a = a.at[idx, (idx + hop) % n].set(1.0)
        a = a.at[(idx + hop) % n, idx].set(1.0)
    return a * (1.0 - jnp.eye(n))


def fully_connected(n: int):
    return jnp.ones((n, n), jnp.float32) - jnp.eye(n)


def effective_adjacency(adj, edge_mask, active):
    """The adjacency that actually carried messages this round: drawn edges
    masked by per-edge delivery (netsim drop model / partitions) and by both
    endpoints being online. Stays symmetric when ``edge_mask`` is symmetric;
    churned-out nodes end up with degree 0 (``mixing_matrix`` then gives
    them the self-weight-1 row, i.e. they keep their own model)."""
    return adj * edge_mask * active[:, None] * active[None, :]


def mixing_matrix(adj):
    """Row-stochastic W with uniform weights over {neighbors} ∪ {self}:
    W[i, j] = 1/(deg_i + 1) for j ∈ N(i) ∪ {i} (Eq. 3 aggregation).
    Row-stochastic for ANY 0/1 adjacency, including zero-degree nodes
    (the self edge keeps every denominator >= 1)."""
    n = adj.shape[0]
    a_hat = adj + jnp.eye(n)
    deg = a_hat.sum(axis=1, keepdims=True)
    return a_hat / deg


def weighted_mixing(adj, weights):
    """DAC-style: row-normalize arbitrary nonnegative weights masked by
    adjacency (+ self edge with weight = max of the row's weights)."""
    n = adj.shape[0]
    w = weights * adj
    self_w = jnp.maximum(w.max(axis=1), 1e-6)
    w = w + jnp.diag(self_w)
    return w / w.sum(axis=1, keepdims=True)


def degrees(adj):
    return adj.sum(axis=1)
