"""Stacked decentralized-learning state.

Every node's parameters live in one pytree with a leading ``node`` axis —
the representation that makes gossip an einsum (and, with the node axis
sharded on the ``pod`` mesh axis, makes cross-pod collectives appear from
GSPMD). Heads carry an extra leading ``k`` axis (one slot per cluster).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import split


class FacadeState(NamedTuple):
    cores: Any           # pytree, leading [n, ...]
    heads: Any           # pytree, leading [n, k, ...]
    cluster_id: Any      # [n] int32 — cluster ID reported last round
    round: Any           # scalar int32
    rng: Any             # PRNG key driving topology randomness


class BaselineState(NamedTuple):
    params: Any          # pytree, leading [n, ...] (full model)
    extra: Any           # algorithm-specific (e.g. DAC weights [n, n])
    round: Any
    rng: Any


class EngineCarry(NamedTuple):
    """Scan carry of the segment engine (core/engine.py): the algorithm
    state plus the data-sampling PRNG key, plus the netsim-v2 on-device
    state — the bursty-link channel and the async-gossip staleness buffer
    (both ``None`` unless the run's ``NetworkConfig`` enables them) —
    plus the adaptive-topology EWMA state (``None`` unless the run's
    ``TopoConfig`` is adaptive). The round counter rides in the scanned
    xs, so the whole carry is donated buffer-for-buffer between segments
    (``donate_argnums``) — node-stacked params update in place."""
    state: Any           # FacadeState | BaselineState
    k_data: Any          # PRNG key consumed by pipeline.sample_round_batches
    chan: Any = None     # netsim.ChannelState (Gilbert–Elliott) | None
    gossip: Any = None   # netsim.GossipState (async staleness) | None
    topo: Any = None     # repro.topo.TopoState (link EWMAs) | None
    fault: Any = None    # repro.resil.FaultState (crash chain) | None


def _stack_n(tree, n):
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (n,) + l.shape).copy(), tree)


def init_facade_state(binding, key, n: int, k: int,
                      head_jitter: float = 0.0) -> FacadeState:
    """All nodes start from the same init (paper: 'initializing its local
    model in the same way'); the k heads share weights at round 0 unless
    ``head_jitter`` decorrelates them."""
    k_init, k_jit, k_rng = jax.random.split(key, 3)
    params = binding.init(k_init)
    core, head = split.split_params(params, binding.head_keys)
    heads_k = split.stack_heads(head, k, key=k_jit, jitter=head_jitter)
    return FacadeState(
        cores=_stack_n(core, n),
        heads=_stack_n(heads_k, n),
        cluster_id=jnp.zeros((n,), jnp.int32),
        round=jnp.zeros((), jnp.int32),
        rng=k_rng,
    )


def init_baseline_state(binding, key, n: int, extra=None) -> BaselineState:
    k_init, k_rng = jax.random.split(key)
    params = binding.init(k_init)
    return BaselineState(params=_stack_n(params, n), extra=extra,
                         round=jnp.zeros((), jnp.int32), rng=k_rng)


def freeze_inactive(active, new_tree, old_tree):
    """netsim churn semantics: nodes with ``active == 0`` sat the round out,
    so every leaf keeps its old value along the leading node axis. (One
    select definition repo-wide: delegates to ``netsim.tree_select``, the
    same helper the async staleness buffers use.)"""
    from repro.netsim import tree_select   # netsim never imports core
    return tree_select(active, new_tree, old_tree)


def node_model(state: FacadeState, i: int):
    """Merged (core, selected head) of node i — its deployable model."""
    core = jax.tree.map(lambda l: l[i], state.cores)
    heads = jax.tree.map(lambda l: l[i], state.heads)
    head = split.select_head(heads, state.cluster_id[i])
    return split.merge_params(core, head)
