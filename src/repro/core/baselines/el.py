"""Epidemic Learning (EL) baseline [NeurIPS'23, de Vos et al.]:
D-PSGD over a fresh random r-regular topology each round. This is the
paper's primary baseline and the communication-cost reference point."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import resil
from repro import topo as topo_mod

from .. import split, topology
from ..bindings import Binding, gossip_mix, local_sgd, node_vmap
from ..state import BaselineState, freeze_inactive
from ..netwire import comm_info, masked_topology, sent_view


@dataclasses.dataclass(frozen=True)
class ELConfig:
    n_nodes: int
    degree: int = 4
    local_steps: int = 10
    lr: float = 0.05


def el_round(cfg: ELConfig, binding: Binding, state: BaselineState, batches,
             net=None, gossip=None, topo=None, topo_cfg=None,
             fault_cfg=None):
    """batches: pytree leading [n, H, B, ...]; net: optional
    ``netsim.RoundConditions`` masks (see ``facade_round``); gossip:
    optional published-snapshot tree (async stale gossip); topo/topo_cfg:
    optional adaptive topology policy (:mod:`repro.topo` — uniform stays
    the legacy draw bit-for-bit, same PRNG split); fault_cfg: optional
    :class:`repro.resil.FaultConfig` (payload corruption + robust mix
    guard, see ``facade_round``)."""
    key, sub = jax.random.split(state.rng)
    if topo_mod.adaptive(topo_cfg):
        adj = topo_mod.sample(topo_cfg, topo, sub, cfg.n_nodes, cfg.degree)
    else:
        adj = topology.random_regular(sub, cfg.n_nodes, cfg.degree)
    adj = masked_topology(net, adj)
    w = topology.mixing_matrix(adj)

    vis = sent_view(net, gossip, state.params, fault_cfg)
    guard = resil.guard_of(fault_cfg)
    params = gossip_mix(w, state.params, vis, guard=guard)
    params = node_vmap(lambda p, b: local_sgd(binding, p, b, cfg.lr))(
        params, batches)
    if net is not None:
        params = freeze_inactive(net.active, params, state.params)

    model_bytes = split.tree_size_bytes(
        jax.tree.map(lambda l: l[0], state.params))
    info = comm_info(net, adj, model_bytes, cfg.n_nodes * cfg.degree,
                     actual=topo_mod.adaptive(topo_cfg))
    info["quarantined"] = resil.quarantined_count(guard, vis)
    return BaselineState(params=params, extra=state.extra,
                         round=state.round + 1, rng=key), info
