"""DEPRL baseline [Xiong et al., AAAI'24]: personalized DL with shared
representations — the core is gossiped over a STATIC topology, the head is
trained locally and NEVER shared (the paper observes this overfits and
plateaus, Sec. V-B/V-D)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Any

import jax
import jax.numpy as jnp

from repro import resil
from repro import topo as topo_mod

from .. import split, topology
from ..bindings import Binding, gossip_mix, local_sgd, node_vmap
from ..state import BaselineState, freeze_inactive
from ..netwire import comm_info, masked_topology, sent_view


@dataclasses.dataclass(frozen=True)
class DeprlConfig:
    n_nodes: int
    degree: int = 4
    local_steps: int = 10
    lr: float = 0.01


def deprl_round(cfg: DeprlConfig, binding: Binding, state: BaselineState,
                batches, net=None, gossip=None, topo=None, topo_cfg=None,
                fault_cfg=None):
    """state.params [n, ...] full models; only cores are mixed."""
    # static-ring legacy topology: adaptive sampling uses repro.topo's own
    # seeded round stream (see dpsgd_round)
    if topo_mod.adaptive(topo_cfg):
        adj = topo_mod.sample(topo_cfg, topo,
                              topo_mod.static_key(topo_cfg, state.round),
                              cfg.n_nodes, cfg.degree)
    else:
        adj = topology.ring(cfg.n_nodes, cfg.degree)
    adj = masked_topology(net, adj)
    w = topology.mixing_matrix(adj)

    def split_n(params):
        return split.split_params(params, binding.head_keys)

    cores, heads = jax.vmap(split_n)(state.params)
    pub_cores = None
    if gossip is not None:
        pub_cores, _ = jax.vmap(split_n)(gossip)
    vis = sent_view(net, pub_cores, cores, fault_cfg)
    guard = resil.guard_of(fault_cfg)
    cores = gossip_mix(w, cores, vis, guard=guard)

    def local(core, head, bh):
        p = split.merge_params(core, head)
        return local_sgd(binding, p, bh, cfg.lr)

    params = node_vmap(local)(cores, heads, batches)
    if net is not None:
        params = freeze_inactive(net.active, params, state.params)

    core_bytes = split.tree_size_bytes(jax.tree.map(lambda l: l[0], cores))
    info = comm_info(net, adj, core_bytes, cfg.n_nodes * cfg.degree,
                     actual=topo_mod.adaptive(topo_cfg))
    info["quarantined"] = resil.quarantined_count(guard, vis)
    return BaselineState(params=params, extra=state.extra,
                         round=state.round + 1, rng=state.rng), info
