from .dac import DACConfig, dac_round, init_dac_extra  # noqa: F401
from .deprl import DeprlConfig, deprl_round  # noqa: F401
from .dpsgd import DpsgdConfig, dpsgd_round  # noqa: F401
from .el import ELConfig, el_round  # noqa: F401
