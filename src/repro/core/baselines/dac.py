"""DAC baseline [Zec et al., 2022]: decentralized adaptive clustering —
communication partners are sampled with probability derived from the
(inverse) loss of each peer's model on the local data; mixing weights adapt
to data similarity. Dynamic topology, full-model exchange."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import resil
from repro import topo as topo_mod

from .. import split, topology
from ..bindings import Binding, gossip_mix, local_sgd, node_vmap
from ..state import BaselineState, freeze_inactive
from ..netwire import comm_info, masked_topology, sent_view


@dataclasses.dataclass(frozen=True)
class DACConfig:
    n_nodes: int
    degree: int = 4
    local_steps: int = 10
    lr: float = 0.005
    tau: float = 30.0  # similarity temperature (DAC paper's tau)


def init_dac_extra(n: int):
    """Pairwise similarity scores, updated every round."""
    return {"sim": jnp.zeros((n, n), jnp.float32)}


def dac_round(cfg: DACConfig, binding: Binding, state: BaselineState,
              batches, net=None, gossip=None, topo=None, topo_cfg=None,
              fault_cfg=None):
    n = cfg.n_nodes
    key, k_top = jax.random.split(state.rng)
    sim = state.extra["sim"]

    # --- sample neighbors: Gumbel-top-k over similarity logits ---
    # DAC keeps its own data-similarity sampler; an adaptive topology
    # policy composes with it via the shared participation-gated pipeline
    # (topo.gumbel_graph) — link-quality logits add to the similarity
    # logits and the fairness floor gates the round — so partners are
    # chosen by similarity AND link quality, at the policy's degree budget
    logits = cfg.tau * sim - 1e9 * jnp.eye(n)
    part = None
    if topo_mod.adaptive(topo_cfg):
        adj, nbr, part = topo_mod.gumbel_graph(
            topo_cfg, topo, k_top, n,
            topo_mod.budget(topo_cfg, cfg.degree), extra_logits=logits)
    else:
        gumbel = jax.random.gumbel(k_top, (n, n))
        _, nbr = jax.lax.top_k(logits + gumbel, cfg.degree)  # [n, r]
        adj = jnp.zeros((n, n)).at[jnp.arange(n)[:, None], nbr].set(1.0)
        adj = jnp.maximum(adj, adj.T)  # symmetrize (push-pull exchange)
    adj = masked_topology(net, adj)

    # what each peer DELIVERS this round: its published snapshot when it
    # is stale (async gossip), its live params otherwise — possibly
    # corrupted in transit (fault injection)
    vis = sent_view(net, gossip, state.params, fault_cfg)
    guard = resil.guard_of(fault_cfg)
    delivered_params = state.params if vis is None else vis

    # --- similarity update: inverse loss of peer's model on local batch ---
    first = jax.tree.map(lambda b: b[:, 0], batches)

    def peer_losses(i):
        my_batch = jax.tree.map(lambda b: b[i], first)

        def loss_of(j):
            pj = jax.tree.map(lambda p: p[j], delivered_params)
            return binding.loss(pj, my_batch)

        return jax.vmap(loss_of)(nbr[i])                     # [r]

    l_peer = jax.vmap(peer_losses)(jnp.arange(n))            # [n, r]
    if guard is not None:
        # a NaN'd peer model scores NaN loss, which would poison the
        # similarity table forever — under the robust guard it scores as
        # maximally dissimilar instead
        l_peer = jnp.where(jnp.isfinite(l_peer), l_peer, 1e9)
    rows = jnp.arange(n)[:, None]
    inv_loss = 1.0 / jnp.maximum(l_peer, 1e-6)
    if net is not None or part is not None:
        # a lost/offline/non-participating exchange brings no model to
        # score — keep the old entry
        delivered = adj[rows, nbr] > 0                       # [n, r]
        inv_loss = jnp.where(delivered, inv_loss, sim[rows, nbr])
    new_sim = sim.at[rows, nbr].set(inv_loss)

    # --- aggregate with similarity weights, then local train ---
    w = topology.weighted_mixing(adj, jnp.maximum(new_sim, 1e-6))
    params = gossip_mix(w, state.params, vis, guard=guard)

    params = node_vmap(lambda p, b: local_sgd(binding, p, b, cfg.lr))(
        params, batches)
    if net is not None:
        params = freeze_inactive(net.active, params, state.params)
        new_sim = jnp.where(net.active[:, None] > 0, new_sim, sim)

    model_bytes = split.tree_size_bytes(
        jax.tree.map(lambda l: l[0], state.params))
    info = comm_info(net, adj, model_bytes, n * cfg.degree,
                     actual=part is not None)
    info["quarantined"] = resil.quarantined_count(guard, vis)
    return BaselineState(params=params, extra={"sim": new_sim},
                         round=state.round + 1, rng=key), info
