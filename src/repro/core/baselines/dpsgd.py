"""D-PSGD baseline [Lian et al., NeurIPS'17]: static-topology decentralized
SGD (paper Alg. 1 / Appendix B). Used for the Fig. 1 motivation experiment."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import resil
from repro import topo as topo_mod

from .. import split, topology
from ..bindings import Binding, gossip_mix, local_sgd, node_vmap
from ..state import BaselineState, freeze_inactive
from ..netwire import comm_info, masked_topology, sent_view


@dataclasses.dataclass(frozen=True)
class DpsgdConfig:
    n_nodes: int
    degree: int = 4
    local_steps: int = 10
    lr: float = 0.05


def dpsgd_round(cfg: DpsgdConfig, binding: Binding, state: BaselineState,
                batches, net=None, gossip=None, topo=None, topo_cfg=None,
                fault_cfg=None):
    # legacy topology is a static ring (no per-round PRNG to reuse), so an
    # adaptive policy samples from repro.topo's own seeded round stream
    if topo_mod.adaptive(topo_cfg):
        adj = topo_mod.sample(topo_cfg, topo,
                              topo_mod.static_key(topo_cfg, state.round),
                              cfg.n_nodes, cfg.degree)
    else:
        adj = topology.ring(cfg.n_nodes, cfg.degree)
    adj = masked_topology(net, adj)
    w = topology.mixing_matrix(adj)

    # D-PSGD order: local train, then exchange+aggregate (stale neighbors
    # contribute their last published model instead of today's)
    params = node_vmap(lambda p, b: local_sgd(binding, p, b, cfg.lr))(
        state.params, batches)
    vis = sent_view(net, gossip, params, fault_cfg)
    guard = resil.guard_of(fault_cfg)
    params = gossip_mix(w, params, vis, guard=guard)
    if net is not None:
        params = freeze_inactive(net.active, params, state.params)

    model_bytes = split.tree_size_bytes(
        jax.tree.map(lambda l: l[0], state.params))
    info = comm_info(net, adj, model_bytes, cfg.n_nodes * cfg.degree,
                     actual=topo_mod.adaptive(topo_cfg))
    info["quarantined"] = resil.quarantined_count(guard, vis)
    return BaselineState(params=params, extra=state.extra,
                         round=state.round + 1, rng=state.rng), info
