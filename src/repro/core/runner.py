"""Experiment runner: drives any DL algorithm (FACADE / EL / D-PSGD / DEPRL
/ DAC) over a clustered dataset, evaluating per-cluster accuracy, fairness
metrics and communication volume — the harness behind every paper table.

Two interchangeable drivers share all setup and evaluation code:

* ``engine=True`` (default): the scan-fused segment engine
  (:mod:`repro.core.engine`) — one XLA dispatch and one device->host
  transfer per eval-to-eval span, donated state buffers;
* ``engine=False``: the legacy per-round Python loop, kept as the parity
  reference and the baseline for ``benchmarks/round_throughput.py``.

Both produce bit-identical trajectories for the same seed.

All seed-independent machinery (bindings, round closures, compiled segment
programs, evaluators) is resolved through a
:class:`repro.core.cache.EngineCache`; pass ``cache=`` to share compiles
across calls — that is how ``repro.sweep.run_sweep`` makes many-seed grids
pay XLA compilation once per cell. The default (``cache=None``) builds a
private fresh cache, i.e. exactly the historical per-call behavior.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import os
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.comm import CommLog
from repro.data import pipeline
from repro.models import cnn as cnn_mod
from repro import netsim
from repro import obs as obs_mod
from repro import resil as resil_mod
from repro import topo as topo_mod

from . import facade as facade_mod
from . import meshctx
from . import netwire
from .baselines import (DACConfig, DeprlConfig, DpsgdConfig, ELConfig,
                        dac_round, deprl_round, dpsgd_round, el_round,
                        init_dac_extra)
from .bindings import Binding
from .cache import EngineCache, EngineSpec
from .engine import _sp, segment_plan
from .state import EngineCarry, init_baseline_state, init_facade_state


@dataclasses.dataclass
class RunResult:
    algo: str
    acc_per_cluster: list      # history: [(round, [acc_c0, acc_c1, ...])]
    fair_acc: list             # [(round, fair_acc)]
    dp: float                  # final demographic parity
    eo: float                  # final equalized odds
    comm: CommLog
    cluster_history: list      # FACADE: [(round, cluster_id array)]
    final_acc: list            # per-cluster accuracy at the end
    node_acc: Any = None       # final per-NODE accuracy [n] (per-tier /
    #                            fairness-floor tables; repro.topo)
    eval_frames: list = dataclasses.field(default_factory=list)
    #                            per-eval EvalFrame fairness trajectory
    #                            (repro.obs.evalframe) — recorded for every
    #                            run, obs attached or not: pure host
    #                            bookkeeping over the arrays the evaluator
    #                            already drains

    def best_fair_acc(self) -> float:
        return max(v for _, v in self.fair_acc) if self.fair_acc else 0.0


# --------------------------------------------------------------------------
class AlgoSetup(NamedTuple):
    """Everything the drivers need, behind one stepper signature:
    ``round_fn(state, batches, net=conds, gossip=published, topo=tstate)
    -> (state, info)``."""
    state: Any                 # initial stacked state
    round_fn: Callable         # main-phase round
    warmup_fn: Callable        # warmup-phase round (== round_fn off-FACADE)
    models_of: Callable        # state -> deployable models, stacked [n, ...]
    finalize: Callable         # applied to the state after the last round
    track_cluster: bool        # info carries a per-round cluster_id [n]
    mixable_of: Callable       # state -> what gossip exchanges (async
    #                            staleness buffers snapshot this tree)


class AlgoProgram(NamedTuple):
    """The seed-INDEPENDENT part of an algorithm: round closures and state
    constructor. ``EngineCache`` memoizes programs per static config, so a
    sweep builds one and mints per-seed setups via :meth:`setup`."""
    init_state: Callable       # PRNG key -> initial stacked state
    round_fn: Callable
    warmup_fn: Callable
    models_of: Callable
    finalize: Callable
    track_cluster: bool
    mixable_of: Callable

    def setup(self, key) -> AlgoSetup:
        return AlgoSetup(self.init_state(key), self.round_fn, self.warmup_fn,
                         self.models_of, self.finalize, self.track_cluster,
                         self.mixable_of)


def algo_program(algo: str, binding: Binding, n: int, k: int, *,
                 degree: int, local_steps: int, lr: float,
                 warmup_rounds: int = 0, head_jitter: float = 0.0,
                 topo=None, faults=None) -> AlgoProgram:
    """``topo``: optional frozen :class:`repro.topo.TopoConfig`, closed
    over the round closures like the algorithm config (static at trace
    time); its per-link EWMA state is passed per round via the stepper's
    ``topo=`` kwarg. ``faults``: optional frozen
    :class:`repro.resil.FaultConfig` (== ``net.faults``), closed over the
    same way — payload corruption + the robust aggregation guard."""
    if algo == "facade":
        fcfg = facade_mod.FacadeConfig(
            n_nodes=n, k=k, degree=degree, local_steps=local_steps, lr=lr,
            warmup_rounds=warmup_rounds, head_jitter=head_jitter)
        return AlgoProgram(
            init_state=lambda key: init_facade_state(
                binding, key, n, k, head_jitter=head_jitter),
            round_fn=functools.partial(facade_mod.facade_round, fcfg,
                                       binding, warmup=False,
                                       topo_cfg=topo, fault_cfg=faults),
            warmup_fn=functools.partial(facade_mod.facade_round, fcfg,
                                        binding, warmup=True,
                                        topo_cfg=topo, fault_cfg=faults),
            models_of=lambda s: facade_mod.node_models(s, binding),
            finalize=functools.partial(facade_mod.final_allreduce, fcfg),
            track_cluster=True,
            mixable_of=lambda s: {"cores": s.cores, "heads": s.heads,
                                  "cluster_id": s.cluster_id})
    if algo in ("el", "dpsgd", "deprl", "dac"):
        cfg_cls = {"el": ELConfig, "dpsgd": DpsgdConfig,
                   "deprl": DeprlConfig, "dac": DACConfig}[algo]
        acfg = cfg_cls(n_nodes=n, degree=degree, local_steps=local_steps,
                       lr=lr)
        round_fn = {"el": el_round, "dpsgd": dpsgd_round,
                    "deprl": deprl_round, "dac": dac_round}[algo]
        fn = functools.partial(round_fn, acfg, binding, topo_cfg=topo,
                               fault_cfg=faults)
        return AlgoProgram(
            init_state=lambda key: init_baseline_state(
                binding, key, n,
                extra=init_dac_extra(n) if algo == "dac" else None),
            round_fn=fn, warmup_fn=fn,
            models_of=lambda s: s.params,
            finalize=lambda s: s, track_cluster=False,
            mixable_of=lambda s: s.params)
    raise ValueError(f"unknown algorithm {algo!r}")


def algo_setup(algo: str, binding: Binding, key, n: int, k: int, *,
               degree: int, local_steps: int, lr: float,
               warmup_rounds: int = 0, head_jitter: float = 0.0,
               topo=None, faults=None) -> AlgoSetup:
    return algo_program(algo, binding, n, k, degree=degree,
                        local_steps=local_steps, lr=lr,
                        warmup_rounds=warmup_rounds,
                        head_jitter=head_jitter, topo=topo,
                        faults=faults).setup(key)


# --------------------------------------------------------------------------
def make_evaluator(binding: Binding, node_cluster, test_x, test_y,
                   batch: int = 256) -> Callable:
    """Vmapped, padded per-cluster evaluator.

    Replaces the legacy Python node-loop: every node of a cluster runs the
    whole (zero-padded, masked) test set in ONE jit dispatch per cluster —
    a ``lax.map`` over fixed-shape eval batches with the node axis vmapped
    inside. Built once per experiment so compiles are reused across evals.

    Returns ``evaluate(models) -> (acc_per_cluster, preds_c, labels_c,
    node_acc)`` — per-cluster mean node accuracy and the first node's
    predictions per cluster for DP/EO (the legacy contract), plus the
    per-NODE accuracy vector ``[n]`` the per-tier fairness tables
    (adaptive topology, :mod:`repro.topo`) consume.

    Empty clusters — the imbalanced-cluster grids can assign a cluster
    zero nodes — are SKIPPED, not crashed on: they contribute no entry to
    ``acc_per_cluster``/``preds_c``/``labels_c`` (and therefore drop out
    of fair-accuracy and DP/EO, which compare the clusters that exist).
    ``evaluate.cluster_ids`` records which cluster each returned entry
    belongs to; with no empty clusters it is exactly ``range(k)``.

    ``evaluate.begin(models)`` / ``evaluate.finish(pending)`` split the
    call at the dispatch boundary: ``begin`` enqueues every per-cluster
    prediction asynchronously (no host sync), ``finish`` drains and
    reduces. The pipelined engine driver uses the split to overlap eval
    compute/drain with the next segment's device compute;
    ``evaluate(models)`` == ``finish(begin(models))``.
    """
    cfg = binding.cfg
    node_cluster = np.asarray(node_cluster)
    clusters = []
    for c in range(len(test_x)):
        idx = np.where(node_cluster == c)[0]
        if idx.size == 0:
            continue        # empty cluster: nothing to evaluate
        x = np.asarray(test_x[c])
        # cap the batch at the test-set size: padding waste stays < one row
        xb, mask = pipeline.padded_eval_batches(
            x, min(batch, max(1, x.shape[0])))
        clusters.append((idx, jnp.asarray(xb),
                         mask.reshape(-1) > 0, np.asarray(test_y[c])))

    @jax.jit
    def predict(models_c, xb):                       # xb [nb, B, ...]
        def per_batch(x):
            logits = jax.vmap(
                lambda p: cnn_mod.forward(cfg, p, x))(models_c)
            return jnp.argmax(logits, -1)            # [m, B]

        return jax.lax.map(per_batch, xb)            # [nb, m, B]

    def begin(models):
        return [predict(jax.tree.map(lambda l: l[idx], models), xb)
                for idx, xb, _, _ in clusters]

    def finish(pending):
        accs, preds_c, labels_c = [], [], []
        node_acc = np.zeros(node_cluster.shape[0], np.float64)
        for (idx, _, valid, y), pred in zip(clusters, pending):
            p = np.asarray(pred)                     # [nb, m, B]
            p = np.moveaxis(p, 1, 0).reshape(len(idx), -1)[:, valid]
            eq = p == y[None, :]
            accs.append(float(eq.mean()))
            node_acc[idx] = eq.mean(axis=1)
            preds_c.append(p[0])
            labels_c.append(y)
        return accs, preds_c, labels_c, node_acc

    def evaluate(models):
        return finish(begin(models))

    evaluate.begin = begin
    evaluate.finish = finish
    evaluate.cluster_ids = tuple(int(node_cluster[idx[0]])
                                 for idx, _, _, _ in clusters)
    return evaluate


# --------------------------------------------------------------------------
class _History:
    """Shared bookkeeping for both drivers: comm log, eval histories,
    weighted mean accuracy and the target-accuracy stop condition."""

    def __init__(self, node_cluster, n: int, evaluator, models_of,
                 target_acc, verbose: bool, algo: str, n_classes: int,
                 tiers=None, obs=None):
        self.comm = CommLog()
        self.acc_hist, self.fair_hist, self.cluster_hist = [], [], []
        self.dp = self.eo = 0.0
        self.accs = []
        self.node_acc = None
        self.eval_frames = []           # per-eval EvalFrame trajectory
        self._prev_eval_cid = None      # cluster ids at the previous eval
        #                                 (the churn baseline)
        self._weights = np.asarray(node_cluster)
        self._n = n
        self._evaluator = evaluator
        self._models_of = models_of
        self._target = target_acc
        self._verbose = verbose
        self._algo = algo
        self._n_classes = n_classes
        self._tiers = None if tiers is None else np.asarray(tiers)
        self._obs = obs

    def eval_begin(self, state):
        """Enqueue the eval's per-cluster predictions asynchronously (no
        host sync) — the pipelined driver calls this BEFORE dispatching
        the next segment (which donates the state buffers), then settles
        with :meth:`eval_finish` while that segment computes.

        Alongside the prediction dispatches, an async device COPY of the
        state's cluster assignment is enqueued (FACADE only) for the
        EvalFrame's churn column — ``jnp.copy``, not a host read, so the
        buffer survives the next segment's donation without a sync."""
        cid = getattr(state, "cluster_id", None)
        return (self._evaluator.begin(self._models_of(state)),
                None if cid is None else jnp.copy(cid))

    def eval_round(self, state, rnd: int, round_bytes: float,
                   round_s: float) -> bool:
        """Evaluate at round ``rnd`` (1-based), record, and report whether
        ``target_acc`` is reached (the driver then stops)."""
        return self.eval_finish(self.eval_begin(state), rnd, round_bytes,
                                round_s)

    def eval_finish(self, pending, rnd: int, round_bytes: float,
                    round_s: float) -> bool:
        pending, eval_cid = pending
        accs, preds_c, labels_c, node_acc = self._evaluator.finish(pending)
        cids = getattr(self._evaluator, "cluster_ids",
                       tuple(range(len(accs))))
        self.accs = accs
        self.node_acc = node_acc
        self.acc_hist.append((rnd, accs))
        # node-weighted mean over the clusters that exist; with no empty
        # clusters ``cids == range(len(accs))`` and this is bit-for-bit
        # the historical enumerate() formula
        mean_acc = float(np.mean(
            [a * (self._weights == c).sum()
             for c, a in zip(cids, accs)]) * len(accs) / self._n)
        # ONE shared hook (the eval twin of compute_frame): DP/EO/fair-acc
        # are computed inside the frame with the same repro.fairness calls
        # this method historically made, and the run's final scalars are
        # read OFF the frame — the series' last entry IS the final scalar,
        # bit-for-bit, on both drivers
        eval_cid = None if eval_cid is None else np.asarray(eval_cid)
        frame = obs_mod.compute_eval_frame(
            rnd, accs, cids, preds_c, labels_c, node_acc,
            self._n_classes, mean_acc=mean_acc, tiers=self._tiers,
            prev_cid=self._prev_eval_cid, cid=eval_cid)
        self._prev_eval_cid = eval_cid
        self.eval_frames.append(frame)
        if self._obs is not None:
            self._obs.record_eval(frame)
        self.fair_hist.append((rnd, frame.fair_acc))
        self.dp = frame.dp
        self.eo = frame.eo
        self.comm.record(rnd, round_bytes, mean_acc, round_s=round_s)
        if self._verbose:
            print(f"  [{self._algo}] round {rnd}: acc={accs} "
                  f"fair={frame.fair_acc:.3f}")
        return self._target is not None and mean_acc >= self._target

    def result(self, algo: str) -> RunResult:
        return RunResult(algo=algo, acc_per_cluster=self.acc_hist,
                         fair_acc=self.fair_hist, dp=self.dp, eo=self.eo,
                         comm=self.comm, cluster_history=self.cluster_hist,
                         final_acc=self.accs, node_acc=self.node_acc,
                         eval_frames=self.eval_frames)


# --------------------------------------------------------------------------
def run_experiment(algo: str, cfg, dataset, *, rounds: int, k: int | None = None,
                   degree: int = 4, local_steps: int = 10, batch_size: int = 8,
                   lr: float = 0.05, eval_every: int = 20, seed: int = 0,
                   warmup_rounds: int = 0, head_jitter: float = 0.0,
                   target_acc: float | None = None,
                   net: "netsim.NetworkConfig | None" = None,
                   topo: "topo_mod.TopoConfig | None" = None,
                   engine: bool = True,
                   pipeline: bool = False,
                   mesh=None,
                   cache: EngineCache | None = None,
                   eval_batch: int = 256,
                   obs: "obs_mod.Obs | None" = None,
                   ckpt: "str | None" = None,
                   verbose: bool = False) -> RunResult:
    """Run one (algorithm, dataset) experiment end to end (CNN models).

    ``net``: optional :class:`repro.netsim.NetworkConfig` — simulate churn,
    message loss, stragglers and link latency/bandwidth for ANY algorithm
    (e.g. ``net=NetworkConfig.preset("edge-churn")``). The returned
    ``CommLog`` then carries simulated wall-clock seconds next to bytes.
    ``None`` keeps the historical ideal-medium path untouched.

    ``topo``: optional :class:`repro.topo.TopoConfig` — an adaptive,
    netsim-aware topology policy (per-link delivery/time EWMAs carried
    on device, Gumbel-top-k sampling, ``min_inclusion`` fairness floor).
    ``None`` and ``TopoConfig(policy="uniform")`` are bit-for-bit the
    legacy sampling path for every algorithm and both drivers.

    ``engine``: ``True`` compiles whole eval-to-eval spans into one XLA
    dispatch (scan-fused segment engine, the fast path); ``False`` runs the
    legacy per-round loop. Same seed => bit-identical trajectories.

    ``mesh`` (engine driver only): shard the node axis across devices —
    an int / 1-tuple device count or a 1-D ``jax.sharding.Mesh`` (see
    :mod:`repro.core.meshctx`; ``launch.mesh.make_node_mesh`` builds one).
    The donated carry is row-sharded over the mesh, gossip mixing becomes
    a shard_map row-block matmul, and everything else (vmapped local
    training, netsim/topo/resil row ops) partitions via GSPMD. The node
    count must divide evenly by the mesh size. ``mesh=None`` (default) is
    bit-for-bit the historical single-device path; on a mesh, per-row
    state is identical but cross-node scalar REDUCTIONS (round bytes /
    seconds, obs frames) can sum in a different order — compare those
    with a tolerance. The mesh shape is part of the cache key, so
    sharded and unsharded programs never collide in an ``EngineCache``.

    ``pipeline`` (engine driver only): double-buffer the segment loop —
    segment ``t+1`` is dispatched (and ``t``'s eval enqueued) BEFORE
    segment ``t``'s stacked scalars are drained, so host-side bookkeeping
    (``device_get``, ``CommLog.record_bulk``, eval reduction, checkpoint
    writes) overlaps device compute of ``t+1``. Bit-for-bit identical to
    ``pipeline=False``: ``t+1`` consumes exactly the fresh carry ``t``
    produced and the host processes segments in order; a ``target_acc``
    hit discards at most one speculatively dispatched segment.

    ``cache``: optional :class:`repro.core.cache.EngineCache` shared across
    calls — a sweep of seeds over one config then pays the XLA compiles
    once (see :mod:`repro.sweep`). ``None`` (the default) uses a fresh
    private cache, which is bit-identical to the historical
    build-everything-per-call behavior.

    ``obs``: optional :class:`repro.obs.Obs` — in-scan per-round metric
    frames (when ``obs.config`` is set), nested tracer spans around
    compile / dispatch / drain / eval, cache hit/miss events, and a
    :class:`repro.obs.RunManifest` at the end of the run. ``None`` is
    bit-for-bit the untelemetered path; an attached ``Obs`` never
    perturbs the trajectory either (telemetry is pure observation).

    ``ckpt``: optional checkpoint path (engine driver only). After every
    segment the full :class:`EngineCarry`, the ``CommLog``/eval histories
    and the drained obs frames are snapshotted atomically
    (write-temp-then-rename, :mod:`repro.checkpoint`); rerunning the SAME
    call with the same path resumes from the last completed segment and
    finishes bit-for-bit identical to an uninterrupted run — segment
    boundaries are exactly the eval boundaries, and everything that
    crosses them (data PRNG, netsim channel, async gossip, topo EWMAs,
    crash chain) lives in the carry. A checkpoint written by a DIFFERENT
    run configuration is refused (fingerprint mismatch), never silently
    reused.
    """
    if ckpt is not None and not engine:
        raise ValueError(
            "ckpt= needs the segment engine (engine=True): the legacy "
            "per-round loop has no segment boundaries to snapshot at")
    if pipeline and not engine:
        raise ValueError(
            "pipeline=True needs the segment engine (engine=True): the "
            "legacy per-round loop has no segment dispatch to overlap")
    mesh = meshctx.normalize(mesh)
    if mesh is not None and not engine:
        raise ValueError(
            "mesh= needs the segment engine (engine=True): the legacy "
            "per-round loop is the single-device parity reference and "
            "never shards")
    if eval_every <= 0:
        raise ValueError(
            f"eval_every={eval_every} must be a positive round count: the "
            "drivers schedule an eval every eval_every-th round, so 0 "
            "divides by zero and negative values silently degrade to a "
            "single final-round eval")
    if target_acc is not None and eval_every > rounds:
        raise ValueError(
            f"target_acc={target_acc} can never trigger an early exit with "
            f"eval_every={eval_every} > rounds={rounds}: no eval is "
            "scheduled before the run's final round. Lower eval_every (or "
            "raise rounds, or drop target_acc).")
    if algo != "facade":
        warmup_rounds = 0   # only FACADE has a warmup phase; normalizing
                            # here keeps baseline cache keys from forking
    n = dataset.n_nodes
    k = k if k is not None else dataset.k
    if mesh is not None and n % mesh[0] != 0:
        raise ValueError(
            f"mesh={mesh} must divide n={n} nodes evenly: the engine "
            "row-shards the node axis in equal blocks per device")
    for r in {degree, topo_mod.budget(topo, degree)}:
        if not 1 <= r < n:
            raise ValueError(
                f"degree={r} out of range for n={n} nodes: the topology "
                "builders silently collapse multi-edges at degree >= n; "
                "pick 1 <= degree <= n - 1")
    key = jax.random.PRNGKey(seed)
    k_init, k_data = jax.random.split(key)

    train_x = jnp.asarray(dataset.train_x)
    train_y = jnp.asarray(dataset.train_y)

    cache = cache if cache is not None else EngineCache()
    tracer = obs.tracer if obs is not None else None
    spec = EngineSpec(
        algo=algo, cfg=cfg, n=n, k=k, degree=degree,
        local_steps=local_steps, batch_size=batch_size, lr=lr,
        warmup_rounds=warmup_rounds, head_jitter=head_jitter, net=net,
        eval_batch=eval_batch, topo=topo,
        obs=obs.config if obs is not None else None, mesh=mesh)
    if obs is not None:
        obs.begin_run(algo=algo, seed=seed, rounds=rounds, engine=engine)
    misses0 = cache.misses
    with _sp(tracer, "cache.entry", algo=algo):
        entry = cache.entry(spec, tracer=tracer)
    if tracer is not None:
        tracer.event("cache.miss" if cache.misses > misses0
                     else "cache.hit", algo=algo, seed=seed)
    builds0 = cache.evaluator_builds
    # commit the node-stacked train arrays to the entry's node mesh (a
    # no-op when mesh=None) so every segment reads its shard locally
    train_x, train_y = entry.engine.place_data(train_x, train_y)
    setup = entry.setup(k_init)
    evaluator = cache.evaluator(entry.binding, dataset,
                                batch=spec.eval_batch)
    if tracer is not None and cache.evaluator_builds > builds0:
        tracer.event("evaluator.build", batch=spec.eval_batch)
    hist = _History(dataset.node_cluster, n, evaluator, setup.models_of,
                    target_acc, verbose, algo, entry.binding.cfg.n_classes,
                    tiers=(np.asarray(obs_mod.tiers_of(net, n))
                           if net is not None else None),
                    obs=obs)
    ckpt_fp = None
    if ckpt is not None:
        # everything that shapes the trajectory or the resume schedule;
        # a stale checkpoint from any other configuration is refused
        ckpt_fp = obs_mod.fingerprint({
            "spec": repr(spec), "seed": seed, "rounds": rounds,
            "eval_every": eval_every, "warmup_rounds": warmup_rounds,
            "target": repr(target_acc)})
    prof = obs.profile() if obs is not None else contextlib.nullcontext()
    # pin the entry while the run is live: an LRU-bounded cache must never
    # evict the engine whose donated carry/segment programs are in flight
    with prof, cache.pin(spec), \
            _sp(tracer, "run", algo=algo, seed=seed, engine=engine):
        if engine:
            _drive_engine(entry.engine, setup, hist, k_data, train_x,
                          train_y, rounds=rounds, eval_every=eval_every,
                          warmup_rounds=warmup_rounds, obs=obs,
                          ckpt=ckpt, ckpt_fp=ckpt_fp, pipeline=pipeline)
        else:
            _drive_legacy(setup, hist, k_data, train_x, train_y,
                          rounds=rounds, eval_every=eval_every,
                          warmup_rounds=warmup_rounds,
                          local_steps=local_steps, batch_size=batch_size,
                          net=net, n=n, topo=topo, obs=obs)
    if obs is not None:
        health = None
        if obs.health_config is not None:
            ctx = obs_mod.HealthContext(
                n=n, warmup_rounds=warmup_rounds,
                inclusion_floor=(topo.min_inclusion
                                 if topo_mod.adaptive(topo) else None),
                faults=net is not None and net.faults is not None)
            health = obs_mod.evaluate_health(
                obs.health_config, ctx, obs.run_frames_table(),
                obs.run_eval_table(), tracer=obs.tracer).to_json()
        sink_path = getattr(obs.sink, "path", None)
        obs.end_run(obs_mod.RunManifest.build(
            kind="run", name=f"{algo}-seed{seed}", spec=spec,
            settings={"rounds": rounds, "eval_every": eval_every,
                      "engine": engine, "pipeline": pipeline, "seed": seed,
                      "net": repr(net),
                      "topo": repr(topo), "obs": repr(obs.config),
                      "jsonl": (None if sink_path is None
                                else str(sink_path))},
            timing=obs.tracer.rollup(), cache=cache.stats(),
            health=health))
    return hist.result(algo)


# --------------------------------------------------------------------------
def _hist_snapshot(hist: _History) -> dict:
    """The :class:`_History` as a checkpoint-able pytree (plain arrays);
    inverse of :func:`_hist_restore`. float64/int64 round-trip exactly, so
    a restored history is bit-for-bit the live one."""
    c = hist.comm
    return {
        "comm": {"rounds": np.asarray(c.rounds, np.int64),
                 "bytes": np.asarray(c.bytes, np.float64),
                 "seconds": np.asarray(c.seconds, np.float64),
                 "acc": np.asarray(c.acc, np.float64),
                 "evaled": np.asarray(c.evaled, np.bool_)},
        "acc_hist": [{"round": np.asarray(r, np.int64),
                      "accs": np.asarray(a, np.float64)}
                     for r, a in hist.acc_hist],
        "fair_hist": {
            "rounds": np.asarray([r for r, _ in hist.fair_hist], np.int64),
            "vals": np.asarray([v for _, v in hist.fair_hist], np.float64)},
        "cluster_hist": [{"round": np.asarray(r, np.int64),
                          "cid": np.asarray(cid)}
                         for r, cid in hist.cluster_hist],
        "dp": np.asarray(hist.dp, np.float64),
        "eo": np.asarray(hist.eo, np.float64),
        "accs": np.asarray(hist.accs, np.float64),
        "node_acc": (None if hist.node_acc is None
                     else np.asarray(hist.node_acc)),
        # the per-eval fairness trajectory: one dict of float64/int64
        # arrays per EvalFrame (plain floats round-trip exactly, so the
        # resumed trajectory is bit-for-bit the live one)
        "eval_frames": [
            {name: np.asarray(getattr(f, name),
                              np.int64 if name in ("round", "cluster_ids")
                              else np.float64)
             for name in obs_mod.EVAL_FIELDS}
            for f in hist.eval_frames],
        "prev_eval_cid": (None if hist._prev_eval_cid is None
                          else np.asarray(hist._prev_eval_cid)),
    }


def _hist_restore(hist: _History, snap: dict):
    """Rehydrate ``hist`` from a :func:`_hist_snapshot` pytree, restoring
    the exact Python container types the drivers append (lists of ints /
    floats / tuples) so downstream consumers can't tell a resumed run
    from an uninterrupted one."""
    c = hist.comm
    c.rounds = [int(v) for v in snap["comm"]["rounds"]]
    c.bytes = [float(v) for v in snap["comm"]["bytes"]]
    c.seconds = [float(v) for v in snap["comm"]["seconds"]]
    c.acc = [float(v) for v in snap["comm"]["acc"]]
    c.evaled = [bool(v) for v in snap["comm"]["evaled"]]
    hist.acc_hist = [(int(e["round"]), [float(a) for a in e["accs"]])
                     for e in snap["acc_hist"]]
    hist.fair_hist = [(int(r), float(v))
                      for r, v in zip(snap["fair_hist"]["rounds"],
                                      snap["fair_hist"]["vals"])]
    hist.cluster_hist = [(int(e["round"]), np.asarray(e["cid"]))
                         for e in snap["cluster_hist"]]
    hist.dp = float(snap["dp"])
    hist.eo = float(snap["eo"])
    hist.accs = [float(a) for a in snap["accs"]]
    hist.node_acc = (None if snap["node_acc"] is None
                     else np.asarray(snap["node_acc"]))
    # defensive .get: checkpoints written before the eval-frame series
    # existed restore to an empty trajectory instead of KeyError-ing
    hist.eval_frames = []
    for e in snap.get("eval_frames", []):
        frame = obs_mod.EvalFrame(
            round=int(e["round"]),
            acc=tuple(float(a) for a in np.atleast_1d(e["acc"])),
            cluster_ids=tuple(int(c)
                              for c in np.atleast_1d(e["cluster_ids"])),
            **{name: float(e[name]) for name in obs_mod.EVAL_SCALAR_FIELDS
               if name != "round"})
        hist.eval_frames.append(frame)
        if hist._obs is not None:
            # replay into the live Obs, like the metrics-frame sidecars:
            # eval_table / health / JSONL see the pre-crash evals too
            hist._obs.record_eval(frame)
    prev = snap.get("prev_eval_cid")
    hist._prev_eval_cid = None if prev is None else np.asarray(prev)


def _frame_path(ckpt: str, index: int) -> str:
    return f"{ckpt}.frames-{index}.npz"


def _ckpt_save(path: str, fp: str, carry: EngineCarry, hist: _History,
               new_frames, n_frame_files: int, next_segment: int,
               finished: bool) -> int:
    """Snapshot the whole resumable run state at a segment boundary:
    the drained :class:`EngineCarry` (algorithm state + data PRNG + netsim
    channel + async gossip + topo EWMAs + crash chain), the eval/comm
    histories, and — when obs frames are enabled — THIS segment's drained
    frames (``new_frames = (rounds, MetricsFrame)`` or ``None``).

    Frames are append-only sidecar files (``<path>.frames-<i>.npz``), one
    per frame-bearing segment, so the per-segment write cost stays ~flat:
    the main archive rewrites only the carry + the (scalar-sized)
    histories, never the accumulated frame payloads — checkpoint I/O is
    O(segments), not the O(segments^2) a rewrite-everything layout costs
    on long obs-enabled runs. The sidecar is written BEFORE the main
    archive, whose meta records how many sidecars are valid
    (``frame_files``); a crash in between leaves an orphan the next run
    deterministically overwrites. Each write is atomic via
    :func:`repro.checkpoint.save`. Returns the updated sidecar count."""
    if new_frames is not None:
        rnds, fr = new_frames
        checkpoint.save(
            _frame_path(path, n_frame_files),
            {"rounds": np.asarray(rnds, np.int64),
             "frame": tuple(None if l is None else np.asarray(l)
                            for l in fr)},
            meta={"fingerprint": fp, "index": int(n_frame_files)})
        n_frame_files += 1
    checkpoint.save(path, {"carry": jax.device_get(carry),
                           "hist": _hist_snapshot(hist)},
                    meta={"fingerprint": fp,
                          "next_segment": int(next_segment),
                          "finished": bool(finished),
                          "frame_files": int(n_frame_files)})
    return n_frame_files


def _ckpt_resume(ckpt, ckpt_fp, carry, hist, obs, tracer):
    """Fast-forward a checkpointed run: rebuild the carry leaf-for-leaf on
    the freshly minted template (the checkpoint stores plain tuples/dicts,
    the template restores the NamedTuple treedef and None placement the
    engine donates), rehydrate the histories, and replay every frame
    sidecar into the new ``Obs``. Returns ``(carry, start_idx,
    n_frame_files, finished)``."""
    payload, meta = checkpoint.load(ckpt)
    if meta.get("fingerprint") != ckpt_fp:
        raise ValueError(
            f"checkpoint {ckpt!r} was written by a different run "
            "configuration (fingerprint mismatch) — refusing to "
            "resume from it; delete the file or pick a fresh path")
    carry = jax.tree.unflatten(
        jax.tree.structure(carry),
        [jnp.asarray(l) for l in jax.tree.leaves(payload["carry"])])
    _hist_restore(hist, payload["hist"])
    n_frame_files = int(meta.get("frame_files", 0))
    for j in range(n_frame_files):
        rec, fmeta = checkpoint.load(_frame_path(ckpt, j))
        if fmeta.get("fingerprint") != ckpt_fp:
            raise ValueError(
                f"frame sidecar {_frame_path(ckpt, j)!r} does not match "
                f"checkpoint {ckpt!r} (fingerprint mismatch) — refusing "
                "to resume; delete the checkpoint files to restart")
        if obs is not None:
            obs.record_frames(np.asarray(rec["rounds"]),
                              obs_mod.MetricsFrame(*rec["frame"]))
    if tracer is not None:
        tracer.event("ckpt.resume", segment=int(meta["next_segment"]),
                     finished=bool(meta.get("finished")))
    return (carry, int(meta["next_segment"]), n_frame_files,
            bool(meta.get("finished")))


def _drive_engine(eng, setup: AlgoSetup, hist: _History, k_data,
                  train_x, train_y, *, rounds, eval_every, warmup_rounds,
                  obs=None, ckpt=None, ckpt_fp=None, pipeline=False):
    """Segment-engine driver: one dispatch + one host transfer per span.
    ``eng`` comes from the run's :class:`EngineCache` entry, so repeated
    runs of one config reuse its compiled segment programs. ``obs``: the
    run's :class:`repro.obs.Obs` — its tracer instruments every segment
    (compile/dispatch/drain spans) and eval, and the segment's stacked
    ``MetricsFrame`` (already drained in the one bulk ``device_get``) is
    handed over whole — on a ``target_acc`` hit the full segment is
    recorded (frames are pure observation; the early exit only truncates
    the comm/cluster histories, matching the legacy loop's break).

    ``ckpt``/``ckpt_fp``: crash-safe resume. After every segment the carry
    + histories + frames are checkpointed (atomically); on entry, an
    existing checkpoint with a matching fingerprint fast-forwards the run
    to its ``next_segment``. Segments are deterministic functions of the
    carry, so the resumed trajectory is bit-for-bit the uninterrupted one.

    ``pipeline``: double-buffered variant — see :func:`_drive_pipelined`.
    ``False`` keeps this serialized loop bit-for-bit.
    """
    tracer = obs.tracer if obs is not None else None
    plan = segment_plan(rounds, eval_every, warmup_rounds)
    carry = eng.init_carry(setup.state, k_data)
    start_idx = 0
    n_frames = 0        # frame sidecar files already on disk
    if ckpt is not None and os.path.exists(ckpt):
        carry, start_idx, n_frames, finished = _ckpt_resume(
            ckpt, ckpt_fp, carry, hist, obs, tracer)
        # re-commit the rebuilt carry to the engine's node-mesh layout
        # (identity off-mesh): donation needs correctly sharded buffers
        carry = eng.place_carry(carry)
        if finished:
            return
    if pipeline:
        _drive_pipelined(eng, setup, hist, carry, plan, start_idx,
                         n_frames, train_x, train_y, rounds=rounds,
                         obs=obs, ckpt=ckpt, ckpt_fp=ckpt_fp)
        return
    for idx in range(start_idx, len(plan)):
        seg = plan[idx]
        carry, outs = eng.run_segment(carry, seg.start, seg.length,
                                      train_x, train_y, warmup=seg.warmup,
                                      tracer=tracer)
        rnds = np.arange(seg.start + 1, seg.start + seg.length + 1)
        if obs is not None and "frame" in outs:
            obs.record_frames(rnds, outs["frame"])
        hit = False
        if seg.eval_at_end:
            hist.comm.record_bulk(rnds[:-1], outs["round_bytes"][:-1],
                                  outs["round_s"][:-1])
            state = carry.state
            if seg.start + seg.length == rounds:
                state = setup.finalize(state)
                carry = carry._replace(state=state)
            with _sp(tracer, "eval", round=int(rnds[-1])):
                hit = hist.eval_round(state, int(rnds[-1]),
                                      float(outs["round_bytes"][-1]),
                                      float(outs["round_s"][-1]))
        else:
            hist.comm.record_bulk(rnds, outs["round_bytes"],
                                  outs["round_s"])
        if setup.track_cluster:
            # legacy parity: on a target_acc hit the loop broke BEFORE
            # appending the eval round's cluster ids
            upto = len(rnds) - 1 if hit else len(rnds)
            for i in range(upto):
                hist.cluster_hist.append(
                    (int(rnds[i]), np.asarray(outs["cluster_id"][i])))
        if ckpt is not None:
            new_fr = (rnds, outs["frame"]) if "frame" in outs else None
            finished = hit or idx + 1 == len(plan)
            with _sp(tracer, "ckpt.save", segment=idx, finished=finished):
                n_frames = _ckpt_save(ckpt, ckpt_fp, carry, hist, new_fr,
                                      n_frames, idx + 1, finished)
        if hit:
            break


def _drive_pipelined(eng, setup: AlgoSetup, hist: _History, carry, plan,
                     start_idx, n_frames, train_x, train_y, *, rounds,
                     obs=None, ckpt=None, ckpt_fp=None):
    """Double-buffered segment loop: while the host drains and processes
    segment ``t``, the device already computes segment ``t+1``.

    Order per iteration — the ordering is what makes donation safe:

    1. enqueue segment ``t``'s eval (async ``predict`` dispatches reading
       ``carry.state``) and, under ``ckpt``, an async device-side COPY of
       the carry — both capture the buffers BEFORE they are donated;
    2. dispatch segment ``t+1`` off the fresh carry (donates it);
    3. drain segment ``t``'s stacked scalars and do all host bookkeeping
       (``record_bulk``, eval reduction, cluster history, checkpoint
       write) — now overlapping ``t+1``'s device compute.

    Host-side processing happens strictly in segment order with the same
    values as the serialized loop, so results are bit-for-bit identical.
    A ``target_acc`` hit abandons the one speculatively dispatched
    segment (its carry was consumed, its outs are never drained)."""
    tracer = obs.tracer if obs is not None else None
    if start_idx >= len(plan):
        return

    def dispatch(i, c):
        s = plan[i]
        return eng.dispatch_segment(c, s.start, s.length, train_x,
                                    train_y, warmup=s.warmup,
                                    tracer=tracer)

    next_carry, pending = dispatch(start_idx, carry)
    for idx in range(start_idx, len(plan)):
        seg = plan[idx]
        carry = next_carry
        ev = None
        if seg.eval_at_end:
            state = carry.state
            if seg.start + seg.length == rounds:
                state = setup.finalize(state)
                carry = carry._replace(state=state)
            ev = hist.eval_begin(state)
        snap = None
        if idx + 1 < len(plan):
            if ckpt is not None:
                # async device copy: the checkpoint needs this carry's
                # values AFTER the next dispatch has donated its buffers
                snap = jax.tree.map(jnp.copy, carry)
            next_carry, pending_next = dispatch(idx + 1, carry)
        outs = eng.drain(pending, tracer=tracer, length=seg.length)
        if idx + 1 < len(plan):
            pending = pending_next
        rnds = np.arange(seg.start + 1, seg.start + seg.length + 1)
        if obs is not None and "frame" in outs:
            obs.record_frames(rnds, outs["frame"])
        hit = False
        if seg.eval_at_end:
            hist.comm.record_bulk(rnds[:-1], outs["round_bytes"][:-1],
                                  outs["round_s"][:-1])
            with _sp(tracer, "eval", round=int(rnds[-1])):
                hit = hist.eval_finish(ev, int(rnds[-1]),
                                       float(outs["round_bytes"][-1]),
                                       float(outs["round_s"][-1]))
        else:
            hist.comm.record_bulk(rnds, outs["round_bytes"],
                                  outs["round_s"])
        if setup.track_cluster:
            upto = len(rnds) - 1 if hit else len(rnds)
            for i in range(upto):
                hist.cluster_hist.append(
                    (int(rnds[i]), np.asarray(outs["cluster_id"][i])))
        if ckpt is not None:
            new_fr = (rnds, outs["frame"]) if "frame" in outs else None
            finished = hit or idx + 1 == len(plan)
            with _sp(tracer, "ckpt.save", segment=idx, finished=finished):
                n_frames = _ckpt_save(ckpt, ckpt_fp,
                                      snap if snap is not None else carry,
                                      hist, new_fr, n_frames, idx + 1,
                                      finished)
        if hit:
            break


def _drive_legacy(setup: AlgoSetup, hist: _History, k_data, train_x, train_y,
                  *, rounds, eval_every, warmup_rounds, local_steps,
                  batch_size, net, n, topo=None, obs=None):
    """Legacy per-round driver: eager sampling, one jitted dispatch per
    round, per-round host syncs. Kept as the engine's parity reference and
    the benchmark baseline. ``topo`` is the static TopoConfig; its EWMA
    state is threaded through Python and advanced by the SAME
    ``repro.topo.advance`` the engine scans over. ``obs``: frames come
    from the SAME :func:`repro.obs.compute_frame` the engine scans over,
    at the same point in the round (after ``fold_gossip`` and the topo
    advance, before ``finalize``), so engine and legacy frames agree
    bit-for-bit like the trajectories do."""
    tracer = obs.tracer if obs is not None else None
    ocfg = obs.config if obs is not None else None
    round_main = jax.jit(setup.round_fn)
    round_warm = jax.jit(setup.warmup_fn)
    chan = gossip = None
    tstate = topo_mod.init_state(topo, net, n)
    topo_fn = None
    if tstate is not None and net is not None:
        topo_fn = jax.jit(functools.partial(topo_mod.advance, topo, net))
    fstate = fault_fn = reset_fn = None
    if net is not None:
        conds_fn = jax.jit(
            lambda rnd, chan: netsim.advance_conditions(net, n, rnd, chan))
        time_fn = jax.jit(functools.partial(
            netwire.round_seconds, net, local_steps=local_steps))
        chan = netsim.init_channel(net, n)
        gossip = netsim.init_gossip(net, n, setup.mixable_of(setup.state))
        if net.faults is not None:
            # the SAME per-round hook the engine scans over (resil.advance /
            # resil.reset_nodes), threaded through Python like chan/tstate
            fstate = resil_mod.init_state(net, n, setup.state)
            fault_fn = jax.jit(functools.partial(resil_mod.advance, net, n))
            reset_fn = jax.jit(functools.partial(resil_mod.reset_nodes, n))
    frame_fn = None
    if ocfg is not None:
        tiers = obs_mod.tiers_of(net, n)
        mix_of = setup.mixable_of

        @jax.jit
        def frame_fn(prev, state, info, conds, gossip):
            return obs_mod.compute_frame(
                ocfg, n, tiers, mix_of(prev), mix_of(state),
                getattr(prev, "cluster_id", None),
                getattr(state, "cluster_id", None), info, conds, gossip)

    state = setup.state
    for rnd in range(rounds):
        k_data, k_b = jax.random.split(k_data)
        batches = pipeline.sample_round_batches(
            k_b, train_x, train_y, local_steps, batch_size)
        conds = published = None
        if net is not None:
            conds, chan = conds_fn(rnd, chan)
            if fault_fn is not None:
                conds, fstate, restarted = fault_fn(rnd, conds, fstate)
                if restarted is not None:
                    # engine parity: factory-reset BEFORE the round, so the
                    # round (and the obs frame's prev mix) sees fresh state
                    state = reset_fn(restarted, fstate.init, state)
            conds, published = netsim.apply_async(net, conds, gossip)
        prev = state
        fn = round_warm if rnd < warmup_rounds else round_main
        state, info = fn(prev, batches, net=conds, gossip=published,
                         topo=tstate)
        if published is not None:
            gossip = netsim.fold_gossip(net, gossip, conds,
                                        setup.mixable_of(state))
        if topo_fn is not None:
            tstate = topo_fn(tstate, conds)
        if frame_fn is not None:
            fr = jax.device_get(frame_fn(prev, state, info, conds, gossip))
            obs.record_frames(
                np.asarray([rnd + 1]),
                jax.tree.map(lambda l: np.asarray(l)[None], fr))
        round_s = 0.0
        if net is not None:
            round_s = float(time_fn(info, conds))

        last_round = rnd == rounds - 1
        if last_round:
            state = setup.finalize(state)
        if (rnd + 1) % eval_every == 0 or last_round:
            with _sp(tracer, "eval", round=rnd + 1):
                hit = hist.eval_round(state, rnd + 1,
                                      float(info["round_bytes"]), round_s)
            if hit:
                break
        else:
            hist.comm.record(rnd + 1, float(info["round_bytes"]),
                             round_s=round_s)
        if setup.track_cluster:
            hist.cluster_hist.append(
                (rnd + 1, np.asarray(state.cluster_id)))
