"""Experiment runner: drives any DL algorithm (FACADE / EL / D-PSGD / DEPRL
/ DAC) over a clustered dataset, evaluating per-cluster accuracy, fairness
metrics and communication volume — the harness behind every paper table.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommLog
from repro.data import pipeline
from repro.fairness import demographic_parity, equalized_odds, fair_accuracy
from repro.models import cnn as cnn_mod
from repro import netsim

from . import facade as facade_mod
from . import split
from .baselines import (DACConfig, DeprlConfig, DpsgdConfig, ELConfig,
                        dac_round, deprl_round, dpsgd_round, el_round,
                        init_dac_extra)
from .bindings import Binding, make_binding
from .state import (init_baseline_state, init_facade_state)


@dataclasses.dataclass
class RunResult:
    algo: str
    acc_per_cluster: list      # history: [(round, [acc_c0, acc_c1, ...])]
    fair_acc: list             # [(round, fair_acc)]
    dp: float                  # final demographic parity
    eo: float                  # final equalized odds
    comm: CommLog
    cluster_history: list      # FACADE: [(round, cluster_id array)]
    final_acc: list            # per-cluster accuracy at the end

    def best_fair_acc(self) -> float:
        return max(v for _, v in self.fair_acc) if self.fair_acc else 0.0


# --------------------------------------------------------------------------
def _eval_models(binding: Binding, models, node_cluster, test_x, test_y,
                 batch: int = 256):
    """models: stacked [n, ...]; evaluate each node on ITS cluster's test
    set; returns (acc_per_cluster, preds/labels per cluster for DP/EO)."""
    cfg = binding.cfg
    k = len(test_x)
    n = len(node_cluster)

    @jax.jit
    def predict(params, x):
        logits = cnn_mod.forward(cfg, params, x)
        return jnp.argmax(logits, -1)

    accs, preds_c, labels_c = [], [], []
    for c in range(k):
        nodes = [i for i in range(n) if node_cluster[i] == c]
        cluster_accs, cluster_preds = [], []
        for i in nodes:
            params_i = jax.tree.map(lambda l: l[i], models)
            preds = []
            for xb, yb in zip(pipeline.eval_batches(test_x[c], batch),
                              pipeline.eval_batches(test_y[c], batch)):
                preds.append(np.asarray(predict(params_i, xb)))
            preds = np.concatenate(preds)
            cluster_accs.append((preds == test_y[c]).mean())
            cluster_preds.append(preds)
        accs.append(float(np.mean(cluster_accs)))
        # use the first node of the cluster as the DP/EO representative
        preds_c.append(cluster_preds[0])
        labels_c.append(test_y[c])
    return accs, preds_c, labels_c


# --------------------------------------------------------------------------
def run_experiment(algo: str, cfg, dataset, *, rounds: int, k: int | None = None,
                   degree: int = 4, local_steps: int = 10, batch_size: int = 8,
                   lr: float = 0.05, eval_every: int = 20, seed: int = 0,
                   warmup_rounds: int = 0, head_jitter: float = 0.0,
                   target_acc: float | None = None,
                   net: "netsim.NetworkConfig | None" = None,
                   verbose: bool = False) -> RunResult:
    """Run one (algorithm, dataset) experiment end to end (CNN models).

    ``net``: optional :class:`repro.netsim.NetworkConfig` — simulate churn,
    message loss, stragglers and link latency/bandwidth for ANY algorithm
    (e.g. ``net=NetworkConfig.preset("edge-churn")``). The returned
    ``CommLog`` then carries simulated wall-clock seconds next to bytes.
    ``None`` keeps the historical ideal-medium path untouched.
    """
    binding = make_binding(cfg)
    n = dataset.n_nodes
    k = k if k is not None else dataset.k
    key = jax.random.PRNGKey(seed)
    k_init, k_data = jax.random.split(key)

    train_x = jnp.asarray(dataset.train_x)
    train_y = jnp.asarray(dataset.train_y)

    # --- algorithm setup ---
    if algo == "facade":
        fcfg = facade_mod.FacadeConfig(
            n_nodes=n, k=k, degree=degree, local_steps=local_steps, lr=lr,
            warmup_rounds=warmup_rounds, head_jitter=head_jitter)
        state = init_facade_state(binding, k_init, n, k,
                                  head_jitter=head_jitter)
        round_warm = jax.jit(functools.partial(
            facade_mod.facade_round, fcfg, binding, warmup=True))
        round_main = jax.jit(functools.partial(
            facade_mod.facade_round, fcfg, binding, warmup=False))

        def do_round(state, batches, rnd, conds):
            fn = round_warm if rnd < warmup_rounds else round_main
            return fn(state, batches, net=conds)

        def models_of(state):
            return facade_mod.node_models(state, binding)
    elif algo in ("el", "dpsgd", "deprl", "dac"):
        cfg_cls = {"el": ELConfig, "dpsgd": DpsgdConfig,
                   "deprl": DeprlConfig, "dac": DACConfig}[algo]
        acfg = cfg_cls(n_nodes=n, degree=degree, local_steps=local_steps,
                       lr=lr)
        extra = init_dac_extra(n) if algo == "dac" else None
        state = init_baseline_state(binding, k_init, n, extra=extra)
        round_fn = {"el": el_round, "dpsgd": dpsgd_round,
                    "deprl": deprl_round, "dac": dac_round}[algo]
        stepper = jax.jit(functools.partial(round_fn, acfg, binding))

        def do_round(state, batches, rnd, conds):
            return stepper(state, batches, net=conds)

        def models_of(state):
            return state.params
    else:
        raise ValueError(f"unknown algorithm {algo!r}")

    # --- netsim: per-round condition masks + timing model ---
    if net is not None:
        conds_fn = jax.jit(lambda rnd: netsim.round_conditions(net, n, rnd))
        time_fn = jax.jit(functools.partial(
            netsim.round_time, net, local_steps=local_steps))

    # --- training loop ---
    comm = CommLog()
    acc_hist, fair_hist, cluster_hist = [], [], []
    dp = eo = 0.0
    accs = []
    for rnd in range(rounds):
        k_data, k_b = jax.random.split(k_data)
        batches = pipeline.sample_round_batches(
            k_b, train_x, train_y, local_steps, batch_size)
        conds = conds_fn(rnd) if net is not None else None
        state, info = do_round(state, batches, rnd, conds)
        round_s = 0.0
        if net is not None:
            round_s = float(time_fn(info["adj_eff"], info["payload_bytes"],
                                    conds.active, conds.straggler))

        last_round = rnd == rounds - 1
        if last_round and algo == "facade":
            state = facade_mod.final_allreduce(
                facade_mod.FacadeConfig(n_nodes=n, k=k, degree=degree), state)
        if (rnd + 1) % eval_every == 0 or last_round:
            models = models_of(state)
            accs, preds_c, labels_c = _eval_models(
                binding, models, dataset.node_cluster,
                dataset.test_x, dataset.test_y)
            acc_hist.append((rnd + 1, accs))
            fa = fair_accuracy(accs)
            fair_hist.append((rnd + 1, fa))
            dp = demographic_parity(preds_c, binding.cfg.n_classes)
            eo = equalized_odds(preds_c, labels_c, binding.cfg.n_classes)
            mean_acc = float(np.mean(
                [a * (np.asarray(dataset.node_cluster) == c).sum()
                 for c, a in enumerate(accs)]) * len(accs) / n)
            comm.record(rnd + 1, float(info["round_bytes"]), mean_acc,
                        round_s=round_s)
            if verbose:
                print(f"  [{algo}] round {rnd+1}: acc={accs} fair={fa:.3f}")
            if target_acc is not None and mean_acc >= target_acc:
                break
        else:
            comm.record(rnd + 1, float(info["round_bytes"]), round_s=round_s)
        if algo == "facade":
            cluster_hist.append((rnd + 1, np.asarray(state.cluster_id)))

    return RunResult(algo=algo, acc_per_cluster=acc_hist, fair_acc=fair_hist,
                     dp=dp, eo=eo, comm=comm, cluster_history=cluster_hist,
                     final_acc=accs)
