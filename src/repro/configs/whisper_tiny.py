"""whisper-tiny [arXiv:2212.04356] — enc-dec audio backbone.
4L(enc)+4L(dec) d_model=384 6H d_ff=1536 vocab=51865; conv/mel frontend is a
STUB (input_specs provides 1500 frame embeddings). Decoder context cap 448
per the family spec — decode shapes clamp the self-attn cache accordingly."""
from repro.models.base import ModelConfig


def make(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="whisper-tiny-smoke", arch_type="audio", n_layers=2,
            d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=512,
            encoder_layers=2, encoder_seq=32, cross_attention=True,
            max_decoder_len=64, tie_embeddings=True, dtype="float32")
    return ModelConfig(
        name="whisper-tiny", arch_type="audio", n_layers=4, d_model=384,
        n_heads=6, n_kv_heads=6, d_ff=1536, vocab_size=51865,
        encoder_layers=4, encoder_seq=1500, cross_attention=True,
        max_decoder_len=448, tie_embeddings=True)
