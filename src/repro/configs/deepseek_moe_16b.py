"""deepseek-moe-16b [arXiv:2401.06066] — fine-grained MoE, 2 shared + 64
routed top-6. 28L d_model=2048 16H d_ff(expert)=1408 vocab=102400."""
from repro.models.base import ModelConfig


def make(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="deepseek-moe-16b-smoke", arch_type="moe", n_layers=2,
            d_model=256, n_heads=4, n_kv_heads=4, d_ff=512, vocab_size=512,
            n_experts=4, n_shared_experts=1, experts_per_token=2,
            moe_d_ff=128, capacity_factor=8.0, dtype="float32")
    return ModelConfig(
        name="deepseek-moe-16b", arch_type="moe", n_layers=28, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=1408, vocab_size=102400,
        n_experts=64, n_shared_experts=2, experts_per_token=6, moe_d_ff=1408)
