"""grok-1-314b [hf:xai-org/grok-1] — MoE, 8 experts top-2.
64L d_model=6144 48H (GQA kv=8) expert d_ff=32768 vocab=131072."""
from repro.models.base import ModelConfig


def make(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="grok-1-314b-smoke", arch_type="moe", n_layers=2,
            d_model=256, n_heads=8, n_kv_heads=2, d_ff=512, vocab_size=512,
            n_experts=4, experts_per_token=2, moe_d_ff=512, capacity_factor=8.0, dtype="float32")
    return ModelConfig(
        name="grok-1-314b", arch_type="moe", n_layers=64, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=32768, vocab_size=131072,
        n_experts=8, experts_per_token=2, moe_d_ff=32768)
