"""minicpm3-4b [hf:openbmb/MiniCPM3-4B] — dense, MLA attention.
62L d_model=2560 40H (kv=40) d_ff=6400 vocab=73448."""
from repro.models.base import ModelConfig


def make(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="minicpm3-4b-smoke", arch_type="dense", n_layers=2,
            d_model=256, n_heads=4, n_kv_heads=4, d_ff=512, vocab_size=512,
            attention="mla", q_lora_rank=96, kv_lora_rank=64, qk_rope_dim=16,
            qk_nope_dim=32, v_head_dim=32, dtype="float32")
    return ModelConfig(
        name="minicpm3-4b", arch_type="dense", n_layers=62, d_model=2560,
        n_heads=40, n_kv_heads=40, d_ff=6400, vocab_size=73448,
        attention="mla", q_lora_rank=768, kv_lora_rank=256, qk_rope_dim=32,
        qk_nope_dim=64, v_head_dim=64)
