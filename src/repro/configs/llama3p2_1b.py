"""llama3.2-1b [hf:meta-llama/Llama-3.2-1B] — small dense llama3, tied embeds.
16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256."""
from repro.models.base import ModelConfig


def make(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="llama3.2-1b-smoke", arch_type="dense", n_layers=2,
            d_model=256, n_heads=8, n_kv_heads=2, d_ff=512, vocab_size=512,
            tie_embeddings=True, dtype="float32")
    return ModelConfig(
        name="llama3.2-1b", arch_type="dense", n_layers=16, d_model=2048,
        n_heads=32, n_kv_heads=8, d_ff=8192, vocab_size=128256,
        tie_embeddings=True, rope_theta=500000.0)
