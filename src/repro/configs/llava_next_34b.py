"""llava-next-34b [hf:llava-hf/llava-v1.6 family] — VLM language decoder.
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000, anyres tiling.

The ViT/SigLIP tower + projector is STUBBED per the assignment:
``input_specs()`` supplies anyres patch embeddings [B, 2880, d_model]
(5 tiles x 576 patches) which the decoder consumes as prefix tokens."""
from repro.models.base import ModelConfig

ANYRES_TILES = 5
PATCHES_PER_TILE = 576


def make(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="llava-next-34b-smoke", arch_type="vlm", n_layers=2,
            d_model=256, n_heads=8, n_kv_heads=2, d_ff=512, vocab_size=512,
            n_image_tokens=16, dtype="float32")
    return ModelConfig(
        name="llava-next-34b", arch_type="vlm", n_layers=60, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=20480, vocab_size=64000,
        n_image_tokens=ANYRES_TILES * PATCHES_PER_TILE)
