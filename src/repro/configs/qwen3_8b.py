"""qwen3-8b [hf:Qwen/Qwen3-8B] — dense GQA with qk-norm, head_dim=128.
36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936."""
from repro.models.base import ModelConfig


def make(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="qwen3-8b-smoke", arch_type="dense", n_layers=2,
            d_model=256, n_heads=8, n_kv_heads=2, d_ff=512, vocab_size=512,
            qk_norm=True, head_dim=32, dtype="float32")
    return ModelConfig(
        name="qwen3-8b", arch_type="dense", n_layers=36, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=12288, vocab_size=151936,
        qk_norm=True, head_dim=128, rope_theta=1e6)
