"""Architecture registry: ``--arch <id>`` ids map 1:1 to the assignment."""
from repro.models.base import register

from . import (deepseek_moe_16b, facade_paper, grok1_314b, hymba_1p5b,
               llama3p2_1b, llava_next_34b, minicpm3_4b, qwen3_8b,
               rwkv6_1p6b, stablelm_12b, whisper_tiny)
from .base import INPUT_SHAPES, LONG_CTX_SWA_WINDOW, InputShape  # noqa: F401

ARCH_MODULES = {
    "minicpm3-4b": minicpm3_4b,
    "grok-1-314b": grok1_314b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "hymba-1.5b": hymba_1p5b,
    "stablelm-12b": stablelm_12b,
    "llava-next-34b": llava_next_34b,
    "whisper-tiny": whisper_tiny,
    "qwen3-8b": qwen3_8b,
    "llama3.2-1b": llama3p2_1b,
    "rwkv6-1.6b": rwkv6_1p6b,
}

for _id, _mod in ARCH_MODULES.items():
    register(_id, lambda smoke=False, _m=_mod: _m.make(smoke=smoke))

# dense archs whose long_500k decode uses the sliding-window variant
LONG_CTX_SWA_ARCHS = {"minicpm3-4b", "stablelm-12b", "qwen3-8b", "llama3.2-1b"}
# archs for which long_500k is skipped (pure full attention, no SWA variant)
LONG_CTX_SKIP = {"grok-1-314b", "deepseek-moe-16b", "llava-next-34b",
                 "whisper-tiny"}
