"""hymba-1.5b [arXiv:2411.13676] — hybrid: parallel attention + mamba heads.
32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Attention is natively sliding-window (global attn in a few layers in the
paper; we use SWA uniformly), which is what makes long_500k decode viable."""
from repro.models.base import ModelConfig


def make(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="hymba-1.5b-smoke", arch_type="hybrid", n_layers=2,
            d_model=256, n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=512,
            ssm_state=8, ssm_expand=1, sliding_window=64, dtype="float32")
    return ModelConfig(
        name="hymba-1.5b", arch_type="hybrid", n_layers=32, d_model=1600,
        n_heads=25, n_kv_heads=5, d_ff=5504, vocab_size=32001,
        ssm_state=16, ssm_expand=1, sliding_window=1024)
