"""rwkv6-1.6b "Finch" [arXiv:2404.05892] — attention-free, data-dependent
decay. 24L d_model=2048 d_ff=7168 vocab=65536. Decode state is O(1), so all
decode shapes (incl. long_500k) run natively."""
from repro.models.base import ModelConfig


def make(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="rwkv6-1.6b-smoke", arch_type="ssm", n_layers=2,
            d_model=256, n_heads=4, n_kv_heads=4, d_ff=512, vocab_size=512,
            attention="none", rwkv=True, dtype="float32")
    return ModelConfig(
        name="rwkv6-1.6b", arch_type="ssm", n_layers=24, d_model=2048,
        n_heads=32, n_kv_heads=32, d_ff=7168, vocab_size=65536,
        attention="none", rwkv=True)
