"""The paper's own experimental models (Sec. V-A):
GN-LeNet (CIFAR-10 / Imagenette) and ResNet8 (Flickr-Mammals)."""
from repro.models.base import CNNConfig


def lenet(smoke: bool = False) -> CNNConfig:
    if smoke:
        return CNNConfig(name="gn-lenet-smoke", kind="lenet", image_size=16,
                         width=8, n_classes=10)
    return CNNConfig(name="gn-lenet", kind="lenet", image_size=32, width=32,
                     n_classes=10)


def resnet8(smoke: bool = False) -> CNNConfig:
    if smoke:
        return CNNConfig(name="resnet8-smoke", kind="resnet8", image_size=16,
                         width=16, n_classes=10)
    return CNNConfig(name="resnet8", kind="resnet8", image_size=64, width=32,
                     n_classes=41)  # Flickr-Mammals: 41 species
