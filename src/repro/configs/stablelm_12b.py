"""stablelm-12b [hf:stabilityai/stablelm-2-12b family] — dense GQA.
40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352."""
from repro.models.base import ModelConfig


def make(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="stablelm-12b-smoke", arch_type="dense", n_layers=2,
            d_model=256, n_heads=8, n_kv_heads=2, d_ff=512, vocab_size=512,
            dtype="float32")
    return ModelConfig(
        name="stablelm-12b", arch_type="dense", n_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, d_ff=13824, vocab_size=100352)
