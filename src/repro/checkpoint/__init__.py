from .io import CheckpointError, load, save  # noqa: F401
