"""Pytree checkpointing to .npz (offline container: no orbax/tensorstore).

Paths are '/'-joined pytree keys; dataclass-free dicts/lists/tuples
round-trip exactly. Works for model params, optimizer slots and full
DL states.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        arr = np.asarray(tree)
        if arr.dtype == np.dtype("bfloat16"):
            # npz has no bf16: store the raw bits; dtype recorded in struct
            arr = arr.view(np.uint16)
        out[prefix[:-1]] = arr
    return out


def save(path: str, tree, meta: dict | None = None):
    flat = _flatten(tree)
    struct = jax.tree.map(lambda _: None, tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, __meta__=json.dumps(meta or {}),
             __struct__=json.dumps(_structure(tree)), **flat)


def _structure(tree):
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _structure(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {"__kind__": type(tree).__name__,
                "items": [_structure(v) for v in tree]}
    return {"__kind__": "leaf",
            "dtype": str(np.asarray(tree).dtype)}


def _rebuild(struct, flat, prefix=""):
    kind = struct["__kind__"]
    if kind == "dict":
        return {k: _rebuild(v, flat, f"{prefix}{k}/")
                for k, v in struct["items"].items()}
    if kind in ("list", "tuple"):
        seq = [_rebuild(v, flat, f"{prefix}{i}/")
               for i, v in enumerate(struct["items"])]
        return tuple(seq) if kind == "tuple" else seq
    arr = flat[prefix[:-1]]
    if struct.get("dtype") == "bfloat16":
        arr = arr.view(np.dtype("bfloat16"))
    return arr


def load(path: str):
    with np.load(path, allow_pickle=False) as z:
        flat = {k: z[k] for k in z.files
                if k not in ("__meta__", "__struct__")}
        struct = json.loads(str(z["__struct__"]))
        meta = json.loads(str(z["__meta__"]))
    return _rebuild(struct, flat), meta
