"""Pytree checkpointing to .npz (offline container: no orbax/tensorstore).

Paths are '/'-joined pytree keys; dataclass-free dicts/lists/tuples (and
``None``, for optional components like a disabled netsim channel or crash
chain) round-trip exactly. Works for model params, optimizer slots and
full DL states — including the engine's whole :class:`EngineCarry`, which
is how ``run_experiment(ckpt=...)`` gets crash-safe resume.

:func:`save` is atomic: the archive is written to ``<path>.tmp`` and
``os.replace``'d over ``path``, so a run killed mid-save leaves either the
previous complete checkpoint or none at all — never a truncated file. A
truncated/garbled file at load time raises :class:`CheckpointError` naming
the path instead of a bare zipfile/KeyError traceback.
"""
from __future__ import annotations

import json
import os

import numpy as np


class CheckpointError(ValueError):
    """A checkpoint file exists but cannot be parsed (corrupt/truncated,
    or not a repro checkpoint at all)."""


def _flatten(tree, prefix=""):
    out = {}
    if tree is None:
        pass                      # structure-only: recorded in __struct__
    elif isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        arr = np.asarray(tree)
        if arr.dtype == np.dtype("bfloat16"):
            # npz has no bf16: store the raw bits; dtype recorded in struct
            arr = arr.view(np.uint16)
        out[prefix[:-1]] = arr
    return out


def save(path: str, tree, meta: dict | None = None):
    """Atomically write ``tree`` (+ a small JSON-able ``meta`` dict) to
    ``path``: the archive lands under a temp name first and is renamed
    into place, so concurrent readers and mid-write crashes only ever see
    a complete file."""
    flat = _flatten(tree)
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    tmp = path + ".tmp"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta or {}),
                     __struct__=json.dumps(_structure(tree)), **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def _structure(tree):
    if tree is None:
        return {"__kind__": "none"}
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _structure(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        # NamedTuples (EngineCarry, ChannelState, ...) are recorded as
        # plain tuples: the container survives, the class doesn't —
        # resume rebuilds typed carries by unflattening onto a freshly
        # minted template treedef
        return {"__kind__": "list" if isinstance(tree, list) else "tuple",
                "items": [_structure(v) for v in tree]}
    return {"__kind__": "leaf",
            "dtype": str(np.asarray(tree).dtype)}


def _rebuild(struct, flat, prefix=""):
    kind = struct["__kind__"]
    if kind == "none":
        return None
    if kind == "dict":
        return {k: _rebuild(v, flat, f"{prefix}{k}/")
                for k, v in struct["items"].items()}
    if kind in ("list", "tuple"):
        seq = [_rebuild(v, flat, f"{prefix}{i}/")
               for i, v in enumerate(struct["items"])]
        return tuple(seq) if kind == "tuple" else seq
    arr = flat[prefix[:-1]]
    if struct.get("dtype") == "bfloat16":
        arr = arr.view(np.dtype("bfloat16"))
    return arr


def load(path: str):
    if not os.path.exists(path):
        raise FileNotFoundError(f"no checkpoint at {path!r}")
    try:
        with np.load(path, allow_pickle=False) as z:
            flat = {k: z[k] for k in z.files
                    if k not in ("__meta__", "__struct__")}
            struct = json.loads(str(z["__struct__"]))
            meta = json.loads(str(z["__meta__"]))
        return _rebuild(struct, flat), meta
    except (FileNotFoundError, CheckpointError):
        raise
    except Exception as e:
        raise CheckpointError(
            f"corrupt or truncated checkpoint at {path!r} "
            f"({type(e).__name__}: {e}); delete it to restart the run "
            "from scratch") from e
