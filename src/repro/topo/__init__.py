"""repro.topo — adaptive, netsim-aware topology policies with a
fairness floor.

Instead of sampling every round's gossip graph blind
(``core/topology.py``'s uniform r-regular draw), a
:class:`~repro.topo.policy.TopoConfig` makes the sampler a carried,
learned, on-device policy: per-link EWMAs of observed delivery and link
seconds (:class:`~repro.topo.policy.TopoState`, riding in the engine's
donated carry next to the netsim channel/gossip state) drive
Gumbel-top-k sampling toward reliable/fast links, while a
``min_inclusion`` participation floor guarantees edge-tier nodes are
throttled, never starved.

Usage — any algorithm, any netsim preset::

    from repro.core.runner import run_experiment
    from repro.netsim import NetworkConfig
    from repro.topo import TopoConfig

    res = run_experiment("facade", cfg, ds, rounds=100,
                         net=NetworkConfig.preset("core-edge"),
                         topo=TopoConfig(policy="reliability",
                                         min_inclusion=0.2))

``topo=None`` and ``TopoConfig(policy="uniform")`` are bit-for-bit the
legacy sampling path for every algorithm and both drivers
(``tests/test_topo.py``); ``TopoConfig`` is an ``EngineSpec`` cache-key
component, so every field perturbation forks the sweep cache.
"""
from .diagnostics import inclusion_stats  # noqa: F401
from .policy import (POLICIES, TopoConfig, TopoState, adaptive,  # noqa: F401
                     advance, budget, gumbel_graph, init_state, link_logits,
                     link_scores, participants, participation_probs, sample,
                     static_key)
