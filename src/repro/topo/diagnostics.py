"""Empirical diagnostics over the adaptive topology sampler.

The fairness floor makes a claim — every node participates in at least
``min_inclusion`` of the rounds no matter how the learned scores rank it
— that tests and benchmark smokes want to check against *measured*
behavior, the way ``netsim.channel_stats`` measures the bursty channel.
:func:`inclusion_stats` rolls the exact production path (per round:
``netsim.advance_conditions`` -> :func:`repro.topo.sample` ->
:func:`repro.topo.advance`) in one ``lax.scan`` and reduces it to
host-side statistics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import netsim

from . import policy as policy_mod


def inclusion_stats(cfg, net, n: int, rounds: int, degree: int,
                    seed: int = 0) -> dict:
    """Roll the adaptive sampler for ``rounds`` rounds and measure it.

    Returns per-node ``inclusion`` frequency (fraction of rounds with
    degree >= 1), ``participation`` frequency (the sampler's coin, the
    quantity the floor bounds), mean/max degree, the mean undirected
    edge count per round, and structural flags (``symmetric`` /
    ``binary`` over every drawn adjacency). ``cfg`` must be adaptive.
    """
    if not policy_mod.adaptive(cfg):
        raise ValueError("inclusion_stats needs an adaptive TopoConfig "
                         "(policy 'reliability' or 'bandwidth')")
    r = policy_mod.budget(cfg, degree)
    state0 = policy_mod.init_state(cfg, net, n)
    chan0 = netsim.init_channel(net, n) if net is not None else None
    key = jax.random.PRNGKey(seed)

    def step(carry, rnd):
        state, chan = carry
        conds = None
        if net is not None:
            conds, chan = netsim.advance_conditions(net, n, rnd, chan)
        k_rnd = jax.random.fold_in(key, rnd)
        k_part, _ = jax.random.split(k_rnd)
        part = policy_mod.participants(cfg, state, k_part, n)
        adj = policy_mod.sample(cfg, state, k_rnd, n, r)
        state = policy_mod.advance(cfg, net, state, conds)
        return (state, chan), (adj, part)

    (_, _), (adjs, parts) = jax.lax.scan(
        step, (state0, chan0), jnp.arange(rounds, dtype=jnp.int32))
    adjs, parts = np.asarray(adjs), np.asarray(parts)

    deg = adjs.sum(axis=2)                                  # [rounds, n]
    return {
        "inclusion": (deg > 0).mean(axis=0),                # [n]
        "participation": parts.mean(axis=0),                # [n]
        "mean_degree": float(deg.mean()),
        "max_degree": float(deg.max()),
        "mean_edges": float(adjs.sum(axis=(1, 2)).mean() / 2.0),
        "edge_budget": n * max(1, r // 2),
        "symmetric": bool((adjs == np.swapaxes(adjs, 1, 2)).all()),
        "binary": bool(set(np.unique(adjs)) <= {0.0, 1.0}),
    }
