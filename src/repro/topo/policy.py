"""Adaptive, netsim-aware topology policies with a fairness floor.

``core/topology.py`` draws every round's graph blind: a uniform
r-regular sample happily spends its degree budget on links netsim knows
are bursty, slow, or churned out. This module turns graph sampling into
a carried, learned, on-device policy:

* :class:`TopoConfig` — frozen, hashable policy description (a component
  of the ``EngineSpec`` cache key). ``policy="uniform"`` is the
  contract-preserving default: the algorithm's legacy sampler runs
  bit-for-bit (the round functions never call into this module's
  sampler), and no state rides in the carry.
* :class:`TopoState` — per-link EWMAs of observed *delivery* (from the
  round's edge/churn masks, which fold in the Gilbert–Elliott channel
  and event schedules) and observed *link seconds* (straggler-stretched
  transfer time of a reference payload). A pytree that rides in the
  donated ``EngineCarry`` next to ``chan``/``gossip`` and advances once
  per scanned round (:func:`advance`) — both drivers share the exact
  same entry points, the way ``netsim.advance_conditions`` is shared.
* :func:`sample` — the next round's graph via Gumbel-top-k over link
  scores. Each *participating* node picks ``max(1, r//2)`` peers by
  score (union-symmetrized, the DAC idiom), so the drawn graph never
  spends more than the legacy edge budget (``<= n * max(1, r//2)``
  undirected edges). Participation is where adaptation bites AND where the
  fairness floor lives: a node's participation probability scales with
  its link quality but is clamped to ``>= min_inclusion``, so edge-tier
  nodes are throttled, never starved — the failure mode naive
  reliability-weighted selection is known for (arXiv:2012.10069).

Observation model: the EWMAs observe the round's *conditions* (masks
are defined for every pair in simulation), not just the drawn links —
a deliberate simulation-side simplification that keeps ``advance``
independent of the sampled graph and therefore identical across
drivers. Scores:

* ``reliability``: ``delivery / link_s`` — expected delivered payload
  per simulated second ("goodput"); dropped-out AND slow links both
  score low, so it learns Gilbert–Elliott burst state (bursts persist
  ``~1/p_recover`` rounds — within an EWMA's memory) and static
  core/edge tiers alike;
* ``bandwidth``: ``1 / link_s`` — pure speed, ignores loss.

This module never imports ``repro.core`` (the round functions import
it), only jax + ``repro.netsim``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro import netsim

POLICIES = ("uniform", "reliability", "bandwidth")

_EPS = 1e-6
_NEG = -1e9
_TOPO_STREAM = 7     # fold_in tag for static-topology algorithms (ring
#                      baselines have no per-round PRNG to reuse)


@dataclasses.dataclass(frozen=True)
class TopoConfig:
    """Static topology-policy description (an ``EngineSpec`` component:
    every field here forks the sweep cache key).

    ``degree`` overrides the run's degree budget when set (``None``
    inherits ``run_experiment(degree=...)``); ``min_inclusion`` is the
    fairness floor — a per-round, per-node participation probability
    guaranteed regardless of how hostile the learned scores are;
    ``ref_payload_bytes`` is the reference message size the link-time
    EWMA observes (ordering between links can depend on it when latency
    and bandwidth trade off); ``seed`` drives the sampling stream of
    algorithms whose legacy topology is static (ring baselines).
    """
    policy: str = "uniform"
    decay: float = 0.8               # EWMA weight on history
    degree: "int | None" = None      # degree budget (None -> run degree)
    min_inclusion: float = 0.1       # fairness floor on participation
    ref_payload_bytes: float = 1e6   # payload for link-time observations
    seed: int = 0                    # stream for static-topology algos

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(
                f"unknown topology policy {self.policy!r}; know {POLICIES}")
        if not 0.0 <= self.min_inclusion <= 1.0:
            raise ValueError(
                f"min_inclusion must be in [0, 1], got {self.min_inclusion}")
        if not 0.0 <= self.decay < 1.0:
            raise ValueError(
                f"decay must be in [0, 1), got {self.decay}")


class TopoState(NamedTuple):
    """On-device policy state (symmetric ``[n, n]`` float32, zero diag),
    carried in the engine's donated scan carry / threaded through the
    legacy loop."""
    delivery: Any    # EWMA of observed per-link delivery in [0, 1]
    link_s: Any      # EWMA of observed per-link seconds (ref payload)


def adaptive(cfg: "TopoConfig | None") -> bool:
    """True iff the policy actually overrides the legacy sampler."""
    return cfg is not None and cfg.policy != "uniform"


def budget(cfg: "TopoConfig | None", degree: int) -> int:
    return degree if cfg is None or cfg.degree is None else cfg.degree


# --------------------------------------------------------------------------
def _offdiag(n: int):
    return 1.0 - jnp.eye(n)


def _base_link_s(net, n: int, payload: float):
    """Per-link base transfer seconds for the reference payload: the
    tiered matrices when ``net.classes`` is set, the uniform scalar
    otherwise, ones without netsim (nothing to observe)."""
    if net is None:
        return jnp.ones((n, n), jnp.float32)
    if net.classes is None:
        return jnp.full((n, n), netsim.link_seconds(net, payload),
                        jnp.float32)
    lat, bw = netsim.link_matrices(net, n)
    return (lat + 8.0 * payload / bw).astype(jnp.float32)


def init_state(cfg: "TopoConfig | None", net, n: int):
    """Fresh neutral state (``None`` for uniform/off — the carry then
    costs nothing). Neutral means *learned from scratch*: all links
    start equally deliverable and equally fast; the policy discovers
    tiers and bursts from observations, it is not seeded with the
    simulator's ground truth."""
    if not adaptive(cfg):
        return None
    off = _offdiag(n).astype(jnp.float32)
    # distinct buffers: the carry is donated, and two leaves aliasing one
    # array would be donated twice
    return TopoState(delivery=off, link_s=jnp.copy(off))


def advance(cfg: "TopoConfig | None", net, state, conds):
    """Fold one round's observed conditions into the EWMAs.

    THE shared per-round entry point for both drivers (the scan engine
    calls it inside ``lax.scan`` with the state in the donated carry;
    the legacy loop threads the same object through Python) — called
    AFTER the round, so round ``t`` is always sampled from conditions
    observed up to ``t-1``. A no-op without netsim conditions (nothing
    was observed) or without an adaptive policy.
    """
    if state is None or conds is None or net is None:
        return state
    n = conds.active.shape[0]
    off = _offdiag(n)
    obs_d = (conds.edge_mask * conds.active[:, None]
             * conds.active[None, :]) * off
    slow = 1.0 + (net.straggler_slowdown - 1.0) * conds.straggler
    pair_slow = jnp.maximum(slow[:, None], slow[None, :])
    obs_t = pair_slow * _base_link_s(net, n, cfg.ref_payload_bytes) * off
    d = cfg.decay
    return TopoState(
        delivery=(d * state.delivery + (1.0 - d) * obs_d).astype(jnp.float32),
        link_s=(d * state.link_s + (1.0 - d) * obs_t).astype(jnp.float32))


# --------------------------------------------------------------------------
def link_scores(cfg: TopoConfig, state: TopoState):
    """Nonnegative per-link preference ``[n, n]`` (symmetric; diagonal
    meaningless — mask it before use)."""
    if cfg.policy == "reliability":
        return state.delivery / (state.link_s + _EPS)
    if cfg.policy == "bandwidth":
        return 1.0 / (state.link_s + _EPS)
    raise ValueError(f"policy {cfg.policy!r} has no link scores")


def link_logits(cfg: TopoConfig, state: TopoState, n: int):
    """log-scores with the diagonal masked, ready for Gumbel-top-k —
    also the additive term DAC folds into its similarity logits."""
    return jnp.log(link_scores(cfg, state) + 1e-9) + _NEG * jnp.eye(n)


def participation_probs(cfg: TopoConfig, state: TopoState):
    """Per-node participation probability ``[n]``.

    ``p_i = min_inclusion + (1 - min_inclusion) * q_i / max(q)`` where
    ``q_i`` is the node's mean off-diagonal link score. The best-connected
    node always participates; the floor is EXACT — ``p_i >=
    min_inclusion`` for every node under ANY score matrix (including the
    all-zero hostile one, where ``q/max(q)`` is defined as 0) — which is
    the deterministic guarantee the fairness tests pin.
    """
    s = link_scores(cfg, state)
    n = s.shape[0]
    q = (s * _offdiag(n)).sum(axis=1) / max(n - 1, 1)
    qhat = q / jnp.maximum(q.max(), _EPS)
    p = cfg.min_inclusion + (1.0 - cfg.min_inclusion) * qhat
    return jnp.clip(p, cfg.min_inclusion, 1.0)


def participants(cfg: TopoConfig, state: TopoState, key, n: int):
    """{0,1} [n]: the round's participation draw (fairness floor
    applied)."""
    del n  # shape comes from the state
    p = participation_probs(cfg, state)
    return (jax.random.uniform(key, p.shape) < p).astype(jnp.float32)


def gumbel_graph(cfg: TopoConfig, state: TopoState, key, n: int,
                 kpick: int, extra_logits=None):
    """Participation-gated Gumbel-top-k graph — the one sampling pipeline
    shared by :func:`sample` and DAC's similarity sampler.

    Each participating node picks ``kpick`` peers by link score (plus
    optional caller logits, e.g. DAC's data-similarity term); the picks
    are union-symmetrized (push-pull exchange) and gated so edges only
    join participants. Returns ``(adj, nbr, part)`` — the adjacency, the
    raw per-row pick indices ``[n, kpick]`` (DAC scores peer losses at
    them), and the participation mask.
    """
    k_part, k_gum = jax.random.split(key)
    part = participants(cfg, state, k_part, n)
    logits = link_logits(cfg, state, n) + _NEG * (1.0 - part)[None, :]
    if extra_logits is not None:
        logits = logits + extra_logits
    gumbel = jax.random.gumbel(k_gum, (n, n))
    _, nbr = jax.lax.top_k(logits + gumbel, kpick)            # [n, kpick]
    adj = jnp.zeros((n, n), jnp.float32)
    adj = adj.at[jnp.arange(n)[:, None], nbr].set(1.0)
    adj = jnp.maximum(adj, adj.T)
    return adj * part[:, None] * part[None, :] * _offdiag(n), nbr, part


def sample(cfg: TopoConfig, state: TopoState, key, n: int, degree: int):
    """Draw one adaptive round graph (adjacency ``[n, n]``, float 0/1).

    Guarantees (pinned by ``tests/test_topo.py`` / ``test_property.py``):
    symmetric, zero diagonal, edges only between participants, at most
    ``n * max(1, r//2)`` undirected edges — never more than the legacy
    r-regular draw spends at ANY degree (legacy builds ``r//2`` cycles
    of ``n`` edges, plus an ``n/2`` matching for odd ``r``), so
    adaptive-vs-uniform byte comparisons are never budget-inflated —
    and every participant with a participating peer has degree >= 1.
    Inclusion (participation) probability >= ``min_inclusion`` per node
    per round regardless of the learned scores.
    """
    r = budget(cfg, degree)
    adj, _, _ = gumbel_graph(cfg, state, key, n, max(1, r // 2))
    return adj


def static_key(cfg: TopoConfig, rnd):
    """Sampling key for algorithms whose legacy topology is static (the
    ring baselines): a seeded stream folded on the round counter, so the
    schedule replays and never touches the algorithm's own PRNG."""
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), _TOPO_STREAM), rnd)
