"""Parameter / activation partition rules.

One generic rule engine covers all 10 architectures: leaf paths are matched
against patterns that name a *preferred* layout; every axis placement is
divisibility-checked against the mesh and dropped (or moved) when it does
not divide — so odd head counts (minicpm3's 40 heads) or odd vocabs (73448)
degrade gracefully instead of failing to lower.

Layout philosophy (MaxText-style 2D):
  * ``model`` axis — tensor parallel: column-parallel in-projections
    (wq/wk/wv/w_gate/w_up, MoE expert axis when divisible), row-parallel
    out-projections (wo/w_down).
  * ``data`` axis — batch for activations; with ``fsdp=True`` also shards
    the largest remaining dim of every big weight (ZeRO-3) — required for
    grok-1-314b to fit 16 GB/chip.
  * leading ``layers`` scan axis and the FACADE ``node`` axis are never
    model-sharded; the node axis maps to ``pod``.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# pattern -> layout over the TRAILING dims (applied right-aligned).
# "col": last dim on model; "row": second-to-last dim on model;
# "expert": dim -3 on model (MoE stacks), falling back to "col".
_RULES = [
    (r"(^|/)moe/router$", "rep"),
    (r"(^|/)moe/w_(gate|up)$", "expert_col"),
    (r"(^|/)moe/w_down$", "expert_row"),
    (r"(^|/)(attn|self_attn|cross_attn)/wo$", "row"),
    (r"(^|/)(attn|self_attn|cross_attn)/w", "col"),
    (r"(^|/)(mlp|shared|channel_mix|time_mix)/w_(down|out|v)$", "row"),
    (r"(^|/)(mlp|shared|channel_mix|time_mix)/w", "col"),
    (r"(^|/)ssm/w_(in|xproj)$", "col"),
    (r"(^|/)ssm/w_out$", "row"),
    (r"(^|/)embed$", "col"),       # [V, D] -> shard D
    (r"(^|/)lm_head$", "col"),     # [D, V] -> shard V
    (r"(^|/)pos_embed$", "rep"),
]

_BIG_LEAF = 1 << 20  # fsdp only bothers with leaves > 1M elements


def _path_str(path) -> str:
    parts = []
    for pp in path:
        if hasattr(pp, "key"):
            parts.append(str(pp.key))
        elif hasattr(pp, "idx"):
            parts.append(str(pp.idx))
    return "/".join(parts)


def _divisible(shape, dim, size) -> bool:
    return 0 <= dim < len(shape) and shape[dim] % size == 0 and shape[dim] >= size


def leaf_spec(path_str: str, shape, mesh: Mesh, *, fsdp: bool = True,
              skip_leading: int = 0, extra_leading: tuple = ()) -> P:
    """Partition spec for one leaf. ``skip_leading`` protects scan/stack
    axes; ``extra_leading`` are specs for those axes (e.g. node -> 'pod')."""
    ndim = len(shape)
    model = mesh.shape.get("model", 1)
    data = mesh.shape.get("data", 1)
    spec: list = [None] * ndim
    for i, ax in enumerate(extra_leading):
        if ax is not None and _divisible(shape, i, mesh.shape.get(ax, 1)):
            spec[i] = ax

    layout = "rep"
    for pat, lay in _RULES:
        if re.search(pat, path_str):
            layout = lay
            break

    lo = skip_leading + len(extra_leading)

    def place_model(dim):
        if _divisible(shape, dim, model) and spec[dim] is None:
            spec[dim] = "model"
            return True
        return False

    if layout in ("col", "expert_col"):
        if layout == "expert_col" and ndim - 3 >= lo and _divisible(
                shape, ndim - 3, model):
            spec[ndim - 3] = "model"        # expert parallelism
        elif not place_model(ndim - 1):
            place_model(ndim - 2)
    elif layout in ("row", "expert_row"):
        if layout == "expert_row" and ndim - 3 >= lo and _divisible(
                shape, ndim - 3, model):
            spec[ndim - 3] = "model"
        elif ndim - 2 >= lo and not place_model(ndim - 2):
            place_model(ndim - 1)

    if fsdp and data > 1 and int(np.prod(shape)) > _BIG_LEAF:
        # ZeRO-3: shard the largest remaining dim over (pod,)data —
        # including the pod axis halves per-device param/grad/slot bytes
        # on the multi-pod mesh (grok-1 would not fit otherwise).
        # Axes already placed (e.g. 'pod' on the FACADE node dim) are
        # excluded: a mesh axis may appear at most once per spec.
        used = {a for sp in spec if sp is not None
                for a in (sp if isinstance(sp, tuple) else (sp,))}
        fs_axes = tuple(a for a in ("pod", "data")
                        if mesh.shape.get(a, 1) > 1 and a not in used)
        fs_size = int(np.prod([mesh.shape[a] for a in fs_axes]))
        cands = sorted(range(lo, ndim), key=lambda d: -shape[d])
        for d in cands:
            if spec[d] is None and _divisible(shape, d, fs_size):
                spec[d] = fs_axes if len(fs_axes) > 1 else fs_axes[0]
                break
        else:  # fall back to data-only when the pod product doesn't divide
            for d in cands:
                if spec[d] is None and _divisible(shape, d, data):
                    spec[d] = "data"
                    break
    return P(*spec)


def param_specs(params_shape, mesh: Mesh, *, fsdp: bool = True,
                node_axis: bool = False):
    """Pytree of PartitionSpecs for a (possibly node-stacked) param tree.

    node_axis=True: leading dim of every leaf is the FACADE node axis
    (-> 'pod' when present in the mesh)."""
    extra = (("pod" if "pod" in mesh.shape else None),) if node_axis else ()

    def assign(path, leaf):
        ps = _path_str(path)
        skip = 1 if re.search(r"(^|/)layers(/|$)", ps) else 0
        if node_axis and re.match(r"^heads/", ps):
            pass  # head stacks get an extra k axis; handled by caller
        return leaf_spec(ps, leaf.shape, mesh, fsdp=fsdp,
                         skip_leading=skip, extra_leading=extra)

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def batch_specs(batch_shape, mesh: Mesh, *, node_axis: bool = False):
    """Activations: batch dim on ('pod','data') [plain] or node on 'pod' +
    batch on 'data' [FACADE]. Falls back to replication when not divisible."""
    data_axes = []
    if not node_axis and "pod" in mesh.shape:
        data_axes.append("pod")
    data_axes.append("data")
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))

    def assign(path, leaf):
        shape = leaf.shape
        spec: list = [None] * len(shape)
        i = 0
        if node_axis:
            if "pod" in mesh.shape and _divisible(shape, 0,
                                                  mesh.shape["pod"]):
                spec[0] = "pod"
            i = 1
        # find first dim >= i divisible by the data axes product = batch
        for d in range(i, len(shape)):
            if _divisible(shape, d, dsize):
                spec[d] = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(assign, batch_shape)


def cache_specs(cache_shape, mesh: Mesh):
    """KV caches: batch on 'data' when divisible, else slot/seq dim on
    'data' (long_500k: batch=1); kv-head dims on 'model' when divisible,
    else the slots dim takes 'model' (sequence-sharded cache — kv-head
    counts like 5 or 8 rarely divide a 16-way model axis, but 32k slots
    always do)."""
    data = mesh.shape.get("data", 1)
    model = mesh.shape.get("model", 1)

    def assign(path, leaf):
        shape = leaf.shape  # [L, B, slots, ...] or [L, B, ...]
        spec: list = [None] * len(shape)
        if len(shape) >= 2 and _divisible(shape, 1, data):
            spec[1] = "data"
        elif len(shape) >= 3 and _divisible(shape, 2, data):
            spec[2] = "data"
        # head dim (gqa k/v: [L,B,S,H,hd]) on model; fallback: slots dim
        if len(shape) >= 5 and _divisible(shape, 3, model):
            spec[3] = "model"
        elif (len(shape) >= 4 and spec[2] is None
                and _divisible(shape, 2, model)):
            spec[2] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(assign, cache_shape)


def opt_specs(opt_sds, pspecs):
    """Optimizer slots mirror the param specs; counters are replicated."""
    out = {}
    for k, v in opt_sds.items():
        out[k] = P() if k == "count" else pspecs
    return out


def node_carry_specs(carry, n: int):
    """Partition specs for a segment-engine :class:`EngineCarry` (or any
    node-stacked pytree) over a 1-D ``node`` mesh — the sharded engine's
    layout contract, delegated to :func:`repro.core.meshctx.node_spec`:
    leading dim == ``n`` -> ``P('node', None, ...)`` (so ``[n, n]``
    mixing weights / channel state / link matrices shard along ROWS),
    everything else (scalars, PRNG keys) replicated. Pair with
    :func:`named` over a ``make_node_mesh()`` mesh for shardings."""
    from repro.core import meshctx

    return jax.tree.map(lambda l: meshctx.node_spec(l, n), carry)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
