import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x input-shape x mesh) combination
lowers, compiles, and fits — without TPU hardware.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder devices.

Usage:
    python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    python -m repro.launch.dryrun --all              # full matrix, one proc
    python -m repro.launch.dryrun --all --multi-pod  # (2,16,16) mesh
    python -m repro.launch.dryrun --facade ARCH      # paper technique @ pods

Each case prints one JSON line and appends it to results/dryrun/*.jsonl —
EXPERIMENTS.md §Dry-run / §Roofline are generated from those records.
"""
import argparse
import json
import pathlib
import sys
import time
import traceback

import jax

from repro import configs as _configs  # noqa: F401  (registry)
from repro.configs import INPUT_SHAPES
from repro.launch import shardings, steps
from repro.launch.mesh import HW, make_production_mesh
from repro.models import api
from repro.models.base import get_config, list_archs
from repro.roofline import analyze_compiled

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


# --------------------------------------------------------------------------
def active_param_count(cfg, params_sds) -> int:
    """Params touched per token: MoE expert stacks count at
    (shared + experts_per_token) / n_experts of their size."""
    import re as _re
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_sds)[0]:
        ps = "/".join(str(getattr(p, "key", getattr(p, "idx", ""))) for p in path)
        size = int(leaf.size)
        if cfg.n_experts and _re.search(r"moe/w_(gate|up|down)", ps):
            frac = cfg.experts_per_token / cfg.n_experts
            size = int(size * frac)
        total += size
    return total


def run_case(arch: str, shape: str, *, multi_pod: bool = False,
             remat: bool = True, fsdp: bool = True, unroll: bool = False,
             act_sharding: bool = True, seq_model: bool = False,
             tag: str = "") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "tag": tag,
           "status": "?"}
    t0 = time.time()
    try:
        if not steps.is_supported(arch, shape):
            rec["status"] = "skipped"
            rec["reason"] = "full-attention arch; no 500k decode variant"
            return rec
        mesh = make_production_mesh(multi_pod=multi_pod)
        case = steps.build_case(arch, shape, mesh, remat=remat, fsdp=fsdp,
                                unroll=unroll, act_sharding=act_sharding,
                                seq_model=seq_model)
        cfg = steps.resolve_config(arch, shape)
        shp = INPUT_SHAPES[shape]

        in_sh = shardings.named(mesh, case.in_shardings)
        with jax.set_mesh(mesh):
            jitted = jax.jit(case.step_fn, in_shardings=in_sh)
            lowered = jitted.lower(*case.args_sds)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        n_tokens = shp.global_batch * (shp.seq_len if shp.kind != "decode"
                                       else 1)
        rep = analyze_compiled(
            compiled, arch=arch, shape=shape, mesh_name=mesh_name,
            chips=mesh.size, hw=HW,
            n_params_active=active_param_count(cfg, case.args_sds[0]),
            n_tokens=n_tokens, kind=shp.kind)
        rec.update(rep.row())
        rec.update(status="ok", t_lower_s=round(t_lower, 1),
                   t_compile_s=round(t_compile, 1))
    except Exception as e:  # a failure here is a sharding bug — record it
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def run_facade_case(arch: str, *, multi_pod: bool = True) -> dict:
    """The paper's technique at pod scale: 2 FACADE nodes == 2 pods
    gossiping (core, head, cluster-id) across the 'pod' mesh axis."""
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": "facade_pod", "mesh": mesh_name,
           "status": "?", "tag": "facade"}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        case = steps.build_facade_case(arch, mesh)
        cfg = get_config(arch)
        in_sh = shardings.named(mesh, case.in_shardings)
        with jax.set_mesh(mesh):
            jitted = jax.jit(case.step_fn, in_shardings=in_sh)
            lowered = jitted.lower(*case.args_sds)
            compiled = lowered.compile()
        rep = analyze_compiled(
            compiled, arch=arch, shape="facade_pod", mesh_name=mesh_name,
            chips=mesh.size, hw=HW,
            n_params_active=active_param_count(cfg, case.args_sds[0].cores),
            n_tokens=2 * 8 * 4096, kind="train")
        rec.update(rep.row())
        rec["status"] = "ok"
    except Exception as e:
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


# --------------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--facade", metavar="ARCH", default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scan for exact HLO cost accounting")
    ap.add_argument("--no-act-sharding", action="store_true",
                    help="drop activation sharding constraints (baseline)")
    ap.add_argument("--seq-model", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="sequence-parallel residual anchors (Megatron SP); "
                         "--no-seq-model reproduces the v1 baseline")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=None, help="jsonl output path")
    args = ap.parse_args(argv)

    RESULTS.mkdir(parents=True, exist_ok=True)
    suffix = "multi" if args.multi_pod else "single"
    out = pathlib.Path(args.out) if args.out else (
        RESULTS / f"dryrun_{suffix}{('_' + args.tag) if args.tag else ''}.jsonl")

    cases = []
    if args.facade:
        recs = [run_facade_case(args.facade, multi_pod=args.multi_pod)]
    else:
        if args.all:
            cases = [(a, s) for a in list_archs() for s in INPUT_SHAPES]
        elif args.arch and args.shape:
            cases = [(args.arch, args.shape)]
        else:
            ap.error("need --arch + --shape, --all, or --facade ARCH")
        recs = []
        for a, s in cases:
            rec = run_case(a, s, multi_pod=args.multi_pod,
                           remat=not args.no_remat, fsdp=not args.no_fsdp,
                           unroll=args.unroll,
                           act_sharding=not args.no_act_sharding,
                           seq_model=args.seq_model, tag=args.tag)
            recs.append(rec)
            print(json.dumps({k: v for k, v in rec.items()
                              if k != "traceback"}), flush=True)

    with out.open("a") as f:
        for rec in recs:
            f.write(json.dumps(rec) + "\n")
    n_fail = sum(r["status"] == "fail" for r in recs)
    print(f"# {len(recs)} cases, {n_fail} failures -> {out}", file=sys.stderr)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
