"""Launchers: production mesh, sharding rules, dry-run, train/serve CLIs.

NOTE: ``dryrun`` must be run as a script/module (it pins
``xla_force_host_platform_device_count=512`` before importing jax); do not
import it from here.
"""
from .mesh import HW, make_debug_mesh, make_production_mesh  # noqa: F401
