"""Step builders + abstract input specs for every (arch x input-shape) pair.

Three step kinds (per the assignment):
  * train_4k      -> train_step(params, opt_state, batch)
  * prefill_32k   -> prefill_step(params, batch)      (logits + filled cache)
  * decode_32k /
    long_500k     -> serve_step(params, cache, tokens, pos)  (1 new token)

plus the FACADE production step (the paper's technique across pods):
  * facade_step(state, batches) — 2 pod-scale nodes gossiping cluster heads.

``input_specs`` returns ShapeDtypeStructs only — nothing is allocated; the
dry-run lowers and compiles against them.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import optim
from repro.configs import (INPUT_SHAPES, LONG_CTX_SKIP, LONG_CTX_SWA_ARCHS,
                           LONG_CTX_SWA_WINDOW)
from repro.core import facade as facade_mod
from repro.core import make_binding, split
from repro.core.state import FacadeState
from repro.models import api, get_config, hooks, transformer, whisper
from repro.models.base import ModelConfig

from . import shardings


# --------------------------------------------------------------------------
def resolve_config(arch_id: str, shape_name: str,
                   unroll: bool = False) -> ModelConfig:
    cfg = get_config(arch_id)
    if shape_name == "long_500k" and arch_id in LONG_CTX_SWA_ARCHS:
        cfg = cfg.replace(sliding_window=LONG_CTX_SWA_WINDOW)
    if unroll:
        # exact HLO cost accounting: unroll the layer scan so cost_analysis
        # counts every layer (a while body is otherwise counted once)
        cfg = cfg.replace(scan_unroll=max(cfg.n_layers, cfg.encoder_layers))
    return cfg


def is_supported(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k" and arch_id in LONG_CTX_SKIP:
        return False
    return True


def make_optimizer(arch_id: str, cfg: ModelConfig):
    """grok-1: bf16 momentum slots (314B params must fit 16GB/chip HBM —
    DESIGN.md §7); everything else AdamW fp32 slots."""
    if arch_id == "grok-1-314b":
        return optim.momentum(1e-4, slot_dtype=jnp.bfloat16)
    return optim.adamw(3e-4)


# --------------------------------------------------------------------------
def _lm_batch_sds(cfg: ModelConfig, b: int, s: int):
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
    }
    if cfg.arch_type == "vlm":
        # image tokens are part of the sequence budget
        s_txt = s - cfg.n_image_tokens
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s_txt), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s_txt), jnp.int32),
            "mask": jax.ShapeDtypeStruct((b, s_txt), jnp.float32),
            "img_embeds": jax.ShapeDtypeStruct(
                (b, cfg.n_image_tokens, cfg.d_model), cfg.dt),
        }
    if cfg.encoder_layers > 0:
        s_dec = min(s, cfg.max_decoder_len)
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s_dec), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s_dec), jnp.int32),
            "mask": jax.ShapeDtypeStruct((b, s_dec), jnp.float32),
            "frames": jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), cfg.dt),
        }
    return batch


def _abstract_params(cfg, init_fn):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(init_fn, key)


@dataclasses.dataclass
class DryRunCase:
    arch: str
    shape: str
    step_fn: Callable
    args_sds: tuple
    in_shardings: tuple
    donate: tuple = ()


# --------------------------------------------------------------------------
def build_case(arch_id: str, shape_name: str, mesh, *, remat: bool = True,
               fsdp: bool = True, unroll: bool = False,
               act_sharding: bool = True,
               seq_model: bool = False) -> DryRunCase:
    cfg = resolve_config(arch_id, shape_name, unroll=unroll)
    if act_sharding:
        batch_axes = (("pod", "data") if "pod" in mesh.shape else ("data",))
        # sequence-parallel anchors pay off for TRAINING (the saved
        # activation carry dominates); for prefill/decode they add
        # per-layer gathers (measured: minicpm3 prefill t_coll 0.12->0.55),
        # and for RWKV the seq axis is the recurrence axis (measured:
        # 26->110 GB regression). EXPERIMENTS.md §Perf fleet notes.
        sm = (seq_model and not cfg.rwkv
              and INPUT_SHAPES[shape_name].kind == "train")
        hooks.set_activation_sharding(batch_axes, "model", seq_model=sm)
    else:
        hooks.clear()
    shp = INPUT_SHAPES[shape_name]
    b, s = shp.global_batch, shp.seq_len
    init_fn = functools.partial(api.init_params, cfg)
    params_sds = _abstract_params(cfg, lambda k: init_fn(k))
    pspecs = shardings.param_specs(params_sds, mesh, fsdp=fsdp)

    if shp.kind == "train":
        opt = make_optimizer(arch_id, cfg)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        ospecs = shardings.opt_specs(opt_sds, pspecs)
        batch_sds = _lm_batch_sds(cfg, b, s)
        bspecs = shardings.batch_specs(batch_sds, mesh)

        def train_step(params, opt_state, batch):
            def lf(p):
                return api.loss_fn(cfg, p, batch, remat=remat)

            (loss, metrics), grads = jax.value_and_grad(
                lf, has_aux=True)(params)
            ups, opt_state = opt.update(grads, opt_state, params)
            params = optim.apply_updates(params, ups)
            return params, opt_state, metrics

        return DryRunCase(arch_id, shape_name, train_step,
                          (params_sds, opt_sds, batch_sds),
                          (pspecs, ospecs, bspecs))

    if shp.kind == "prefill":
        batch_sds = _lm_batch_sds(cfg, b, s)
        bspecs = shardings.batch_specs(batch_sds, mesh)

        if cfg.encoder_layers > 0:
            def prefill_step(params, batch):
                enc = whisper.encode(cfg, params, batch["frames"])
                feats, _ = whisper.forward(cfg, params, batch["tokens"],
                                           batch["frames"])
                logits = (feats[:, -1] @ whisper.lm_head_weight(params))
                return logits.astype(jnp.float32), enc
        else:
            def prefill_step(params, batch):
                return transformer.prefill(
                    cfg, params, batch["tokens"],
                    img_embeds=batch.get("img_embeds"))

        return DryRunCase(arch_id, shape_name, prefill_step,
                          (params_sds, batch_sds), (pspecs, bspecs))

    # ---- decode ----
    if cfg.encoder_layers > 0:
        cache_len = min(s, cfg.max_decoder_len)
        hd = cfg.d_model // cfg.n_heads
        cache_sds = {
            "self": jax.tree.map(
                lambda a: jax.ShapeDtypeStruct((cfg.n_layers,) + a.shape,
                                               a.dtype),
                {"k": jax.ShapeDtypeStruct((b, cache_len, cfg.n_heads, hd),
                                           cfg.dt),
                 "v": jax.ShapeDtypeStruct((b, cache_len, cfg.n_heads, hd),
                                           cfg.dt),
                 "slot_pos": jax.ShapeDtypeStruct((b, cache_len), jnp.int32)}),
            "cross": {
                "k": jax.ShapeDtypeStruct(
                    (cfg.n_layers, b, cfg.encoder_seq, cfg.n_heads, hd),
                    cfg.dt),
                "v": jax.ShapeDtypeStruct(
                    (cfg.n_layers, b, cfg.encoder_seq, cfg.n_heads, hd),
                    cfg.dt)},
        }

        def serve_step(params, cache, tokens, pos):
            return whisper.decode_step(cfg, params, cache, tokens, pos)
    else:
        cache_len = transformer.cache_physical_len(cfg, s)
        cache_sds = jax.eval_shape(
            lambda: transformer.init_cache(cfg, b, cache_len))

        def serve_step(params, cache, tokens, pos):
            return transformer.decode_step(cfg, params, cache, tokens, pos)

    cspecs = shardings.cache_specs(cache_sds, mesh)
    tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((b,), jnp.int32)
    dsize = mesh.shape.get("data", 1)
    tspec = P("data", None) if b % dsize == 0 and b >= dsize else P(None, None)
    pspec_tok = P("data") if b % dsize == 0 and b >= dsize else P(None)

    return DryRunCase(arch_id, shape_name, serve_step,
                      (params_sds, cache_sds, tok_sds, pos_sds),
                      (pspecs, cspecs, tspec, pspec_tok))


# --------------------------------------------------------------------------
# FACADE production step: 2 pod-scale nodes, gossip across the 'pod' axis
def build_facade_case(arch_id: str, mesh, *, n_nodes: int = 2, k: int = 2,
                      batch_per_node: int = 16, seq: int = 4096,
                      local_steps: int = 1,
                      act_sharding: bool = True) -> DryRunCase:
    cfg = get_config(arch_id)
    binding = make_binding(cfg)
    if act_sharding:
        # within a FACADE node the batch lives on 'data' only (the node
        # axis owns 'pod'); batch_per_node defaults to the data-axis size
        hooks.set_activation_sharding(("data",), "model", seq_model=True)
    else:
        hooks.clear()
    fcfg = facade_mod.FacadeConfig(n_nodes=n_nodes, k=k, degree=1,
                                   local_steps=local_steps, lr=1e-3)

    def init_state(key):
        from repro.core.state import init_facade_state
        return init_facade_state(binding, key, n_nodes, k)

    state_sds = jax.eval_shape(init_state, jax.ShapeDtypeStruct((2,),
                                                                jnp.uint32))
    pod = "pod" if "pod" in mesh.shape else None
    core_specs = shardings.param_specs(state_sds.cores, mesh, fsdp=True,
                                       node_axis=True)
    head_specs = jax.tree_util.tree_map_with_path(
        lambda path, leaf: shardings.leaf_spec(
            shardings._path_str(path), leaf.shape, mesh, fsdp=True,
            skip_leading=0, extra_leading=(pod, None)),
        state_sds.heads)
    state_specs = FacadeState(
        cores=core_specs, heads=head_specs,
        cluster_id=P(pod), round=P(), rng=P())

    bsds = {
        "tokens": jax.ShapeDtypeStruct(
            (n_nodes, local_steps, batch_per_node, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct(
            (n_nodes, local_steps, batch_per_node, seq), jnp.int32),
        "mask": jax.ShapeDtypeStruct(
            (n_nodes, local_steps, batch_per_node, seq), jnp.float32),
    }
    bspecs = jax.tree.map(
        lambda sds: P(pod, None, "data" if batch_per_node % mesh.shape.get(
            "data", 1) == 0 else None, None), bsds)

    def facade_step(state, batches):
        return facade_mod.facade_round(fcfg, binding, state, batches)

    return DryRunCase(arch_id, "facade_pod", facade_step,
                      (state_sds, bsds), (state_specs, bspecs))
