"""Training launcher.

Two modes:

* ``paper`` (default) — the paper's experiments: FACADE / EL / D-PSGD /
  DEPRL / DAC over a synthetic clustered dataset with feature skew
  (CNN models, CPU-sized). This is the end-to-end driver behind every
  table in EXPERIMENTS.md.

      python -m repro.launch.train --algo facade --clusters 30 2 \\
          --rounds 200 --k 2

* ``lm`` — one-process LM pretraining of any assigned architecture's
  SMOKE variant on synthetic clustered token streams (proves the full
  substrate — data pipeline, optimizer, checkpointing — end to end).

      python -m repro.launch.train --mode lm --arch llama3.2-1b \\
          --steps 200 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as _configs  # noqa: F401
from repro import optim
from repro.checkpoint import io as ckpt_io
from repro.core.runner import run_experiment
from repro.configs.facade_paper import lenet, resnet8
from repro.data import tokens as tokens_mod
from repro.data.synthetic import SynthSpec, make_clustered_data
from repro.models import api
from repro.models.base import get_config, list_archs


def paper_main(args) -> None:
    spec = SynthSpec(n_classes=args.n_classes, image_size=args.image_size,
                     samples_per_class=args.samples_per_class, seed=args.seed)
    transforms = args.transforms or None
    ds = make_clustered_data(spec, tuple(args.clusters), transforms)
    cfg = (resnet8(smoke=args.smoke) if args.model == "resnet8"
           else lenet(smoke=args.smoke))
    cfg = cfg.replace(n_classes=args.n_classes, image_size=args.image_size)

    res = run_experiment(
        args.algo, cfg, ds, rounds=args.rounds, k=args.k,
        degree=args.degree, local_steps=args.local_steps,
        batch_size=args.batch, lr=args.lr, eval_every=args.eval_every,
        seed=args.seed, warmup_rounds=args.warmup_rounds,
        target_acc=args.target_acc, verbose=True)

    print(json.dumps({
        "algo": args.algo, "clusters": args.clusters,
        "final_acc_per_cluster": res.final_acc,
        "best_fair_acc": res.best_fair_acc(),
        "dp": res.dp, "eo": res.eo,
        "total_gb": res.comm.total_gb,
    }, indent=2))
    if args.out:
        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        with open(args.out, "a") as f:
            f.write(json.dumps({
                "algo": args.algo, "clusters": args.clusters,
                "acc_hist": res.acc_per_cluster, "fair_hist": res.fair_acc,
                "dp": res.dp, "eo": res.eo,
                "comm": {"rounds": res.comm.rounds, "bytes": res.comm.bytes, "acc": res.comm.acc}}) + "\n")


def lm_main(args) -> None:
    cfg = get_config(args.arch, smoke=True)
    key = jax.random.PRNGKey(args.seed)
    k_init, k_data = jax.random.split(key)
    params = api.init_params(cfg, k_init)
    opt = optim.adamw(args.lr)
    opt_state = opt.init(params)

    tspec = tokens_mod.TokenSpec(vocab_size=cfg.vocab_size,
                                 seq_len=args.seq + 1, seed=args.seed)
    stream = tokens_mod.make_clustered_tokens(
        tspec, (1,), seqs_per_node=args.steps * args.batch)
    train = stream["train"][0]  # [N, S+1]

    def extra(batch):
        if cfg.arch_type == "vlm":
            batch["img_embeds"] = jnp.zeros(
                (args.batch, cfg.n_image_tokens, cfg.d_model), cfg.dt)
        if cfg.encoder_layers > 0:
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), cfg.dt)
        return batch

    @jax.jit
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: api.loss_fn(cfg, p, batch), has_aux=True)(params)
        ups, opt_state = opt.update(grads, opt_state, params)
        return optim.apply_updates(params, ups), opt_state, loss, metrics

    t0 = time.time()
    for step in range(args.steps):
        rows = train[step * args.batch:(step + 1) * args.batch]
        batch = extra({k: jnp.asarray(v)
                       for k, v in tokens_mod.lm_batch(rows).items()})
        params, opt_state, loss, metrics = train_step(
            params, opt_state, batch)
        if (step + 1) % args.log_every == 0 or step == 0:
            print(f"step {step+1:5d}  loss {float(loss):.4f}  "
                  f"acc {float(metrics['acc']):.3f}  "
                  f"{(step+1)/(time.time()-t0):.2f} it/s", flush=True)
    if args.ckpt:
        ckpt_io.save(args.ckpt, {"params": params, "step": args.steps})
        print(f"checkpoint -> {args.ckpt}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=("paper", "lm"), default="paper")
    # paper mode
    ap.add_argument("--algo", default="facade",
                    choices=("facade", "el", "dpsgd", "deprl", "dac"))
    ap.add_argument("--model", default="lenet", choices=("lenet", "resnet8"))
    ap.add_argument("--clusters", type=int, nargs="+", default=[30, 2])
    ap.add_argument("--transforms", nargs="+", default=None)
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--degree", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=10)
    ap.add_argument("--warmup-rounds", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=20)
    ap.add_argument("--target-acc", type=float, default=None)
    ap.add_argument("--n-classes", type=int, default=10)
    ap.add_argument("--image-size", type=int, default=16)
    ap.add_argument("--samples-per-class", type=int, default=16)
    ap.add_argument("--smoke", action="store_true", default=True)
    # lm mode
    ap.add_argument("--arch", default="llama3.2-1b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default=None)
    # shared
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    (lm_main if args.mode == "lm" else paper_main)(args)


if __name__ == "__main__":
    main()
