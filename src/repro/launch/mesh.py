"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — required because the dry-run pins
``xla_force_host_platform_device_count=512`` before first jax init while
tests/benches must keep seeing 1 device.
"""
from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """single pod: (data=16, model=16) = 256 chips (v5e pod);
    multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax (dryrun.py does this)")
    return jax.sharding.Mesh(
        np.asarray(devices[:need]).reshape(shape), axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Tiny mesh for tests (run in a subprocess with forced device count)."""
    need = math.prod(shape)
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:need]).reshape(shape), axes)


def make_node_mesh(n_devices: int | None = None):
    """1-D ``node`` mesh for the sharded segment engine
    (``run_experiment(mesh=...)`` / ``SegmentEngine(mesh=...)``): the
    FACADE node axis is data-parallel across devices, gossip mixing
    becomes a shard_map row-block matmul (:mod:`repro.core.meshctx`).

    ``n_devices=None`` takes every visible device. On a 1-device box,
    force host devices BEFORE importing jax (the dryrun.py pattern):
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    from repro.core import meshctx

    if n_devices is None:
        n_devices = len(jax.devices())
    return meshctx.build((int(n_devices),))


HW = {
    # TPU v5e per chip
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # bytes/s
    "ici_bw": 50e9,              # bytes/s/link
}
