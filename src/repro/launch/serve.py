"""Batched serving driver: prefill a request batch, then decode N tokens.

Runs the SMOKE variant of any assigned architecture on CPU (the full
configs are exercised by the dry-run). Demonstrates the production decode
path: prefill -> KV cache -> serve_step (one token per call), with
continuous batching over a request queue.

    python -m repro.launch.serve --arch llama3.2-1b --requests 8 \\
        --prompt-len 64 --gen-len 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as _configs  # noqa: F401
from repro.models import api, transformer
from repro.models.base import get_config, list_archs


def make_requests(rng, n, prompt_len, vocab):
    return [rng.integers(1, vocab, size=(rng.integers(
        prompt_len // 2, prompt_len + 1),)).astype(np.int32)
        for _ in range(n)]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    if cfg.encoder_layers > 0:
        raise SystemExit("enc-dec serving: use examples/serve_batched.py "
                         "(audio frontend is stubbed)")
    key = jax.random.PRNGKey(args.seed)
    params = api.init_params(cfg, key)
    rng = np.random.default_rng(args.seed)
    queue = make_requests(rng, args.requests, args.prompt_len, cfg.vocab_size)

    pad_to = args.prompt_len
    cache_len = transformer.cache_physical_len(
        cfg, args.prompt_len + args.gen_len)

    @jax.jit
    def prefill_fn(params, tokens):
        return transformer.prefill(cfg, params, tokens,
                                   cache_extra=cache_len - tokens.shape[1])

    @jax.jit
    def decode_fn(params, cache, tokens, pos):
        return transformer.decode_step(cfg, params, cache, tokens, pos)

    t0 = time.time()
    done = 0
    while queue:
        batch_reqs = [queue.pop(0) for _ in range(min(args.batch, len(queue)))]
        b = len(batch_reqs)
        lens = np.array([len(r) for r in batch_reqs], np.int32)
        toks = np.zeros((b, pad_to), np.int32)
        for i, r in enumerate(batch_reqs):
            toks[i, :len(r)] = r

        logits, cache = prefill_fn(params, jnp.asarray(toks))
        out_tokens = np.zeros((b, args.gen_len), np.int32)
        pos = jnp.asarray(lens)  # next position per request
        # greedy (or sampled) continuation
        last = jnp.argmax(logits, -1).astype(jnp.int32)
        for t in range(args.gen_len):
            out_tokens[:, t] = np.asarray(last)
            logits, cache = decode_fn(params, cache, last[:, None], pos)
            if args.temperature > 0:
                key_t = jax.random.fold_in(key, t)
                last = jax.random.categorical(
                    key_t, logits / args.temperature).astype(jnp.int32)
            else:
                last = jnp.argmax(logits, -1).astype(jnp.int32)
            pos = pos + 1
        done += b
        print(f"batch of {b}: prompts {lens.tolist()} -> "
              f"{args.gen_len} tokens each "
              f"(first req head: {out_tokens[0, :8].tolist()})", flush=True)

    dt = time.time() - t0
    total_tok = done * args.gen_len
    print(f"served {done} requests, {total_tok} tokens "
          f"in {dt:.1f}s = {total_tok / dt:.1f} tok/s")


if __name__ == "__main__":
    main()
