"""Batched serving driver: prefill a request batch, then decode N tokens.

Runs the SMOKE variant of any assigned architecture on CPU (the full
configs are exercised by the dry-run). Demonstrates the production decode
path: prefill -> KV cache -> serve_step (one token per call), with
continuous batching over a request queue.

    python -m repro.launch.serve --arch llama3.2-1b --requests 8 \\
        --prompt-len 64 --gen-len 32

``--net PRESET`` overlays a :mod:`repro.netsim` link model on the served
traffic and turns the final line into an SLO report: request/response
bytes flow through the preset's latency/bandwidth cost model into a
:class:`repro.comm.CommLog` (the same accounting the training benchmarks
use), which reports simulated network hours (``total_hours``) and
simulated seconds to drain 50% / 100% of the request queue
(``seconds_to_target``).

``--trace-jsonl PATH`` attaches a :class:`repro.obs.Tracer` through the
SAME JSONL sink format the training drivers use: per-batch ``prefill`` /
``decode`` spans, ``queue.wait`` events (how long each batch's requests
sat in the queue before being scheduled) and a final ``slo`` event, so
serving traces and training traces can be read with one
:func:`repro.obs.read_jsonl` and joined on ``type``/``name``.
"""
from __future__ import annotations

import argparse
import contextlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as _configs  # noqa: F401
from repro import netsim
from repro.comm import CommLog
from repro.models import api, transformer
from repro.models.base import get_config, list_archs

TOKEN_BYTES = 4  # int32 token ids on the wire


def make_requests(rng, n, prompt_len, vocab):
    return [rng.integers(1, vocab, size=(rng.integers(
        prompt_len // 2, prompt_len + 1),)).astype(np.int32)
        for _ in range(n)]


def wire_params(net) -> tuple:
    """Scalar ``(latency_s, bandwidth_bps)`` for the client link: tiered
    presets (``net.classes``) serve at their WORST link class — clients
    are the edge devices — everything else at the uniform scalars (which
    tiered presets leave at core defaults, so using them would silently
    report an all-core SLO)."""
    if net.classes is None:
        return net.latency_s, net.bandwidth_bps
    cl = net.classes
    return (max(cl.core_latency_s, cl.edge_latency_s),
            min(cl.core_bandwidth_bps, cl.edge_bandwidth_bps))


def batch_net_seconds(net, prompt_bytes: float, gen_len: int,
                      response_bytes: float) -> float:
    """Simulated network seconds for one served batch: the prompts arrive
    in one transfer, then each decoded token streams back to its client —
    one latency hit per step plus serialization of the full response."""
    lat, bw = wire_params(net)
    upload = lat + 8.0 * prompt_bytes / bw
    stream = gen_len * lat + 8.0 * response_bytes / bw
    return float(upload + stream)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--net", default=None,
                    choices=sorted(netsim.PRESETS),
                    help="netsim preset overlay: report simulated network "
                         "time (CommLog total_hours / seconds_to_target) "
                         "next to the real tok/s")
    ap.add_argument("--trace-jsonl", default=None,
                    help="write repro.obs tracer spans (prefill / decode / "
                         "queue.wait / slo) to this JSONL file")
    args = ap.parse_args(argv)

    tracer = None
    if args.trace_jsonl:
        from repro.obs import JsonlSink, Tracer
        tracer = Tracer(sink=JsonlSink(args.trace_jsonl))

    def _sp(name, **attrs):
        if tracer is None:
            return contextlib.nullcontext()
        return tracer.span(name, **attrs)

    cfg = get_config(args.arch, smoke=True)
    if cfg.encoder_layers > 0:
        raise SystemExit("enc-dec serving: use examples/serve_batched.py "
                         "(audio frontend is stubbed)")
    key = jax.random.PRNGKey(args.seed)
    params = api.init_params(cfg, key)
    rng = np.random.default_rng(args.seed)
    queue = make_requests(rng, args.requests, args.prompt_len, cfg.vocab_size)

    pad_to = args.prompt_len
    cache_len = transformer.cache_physical_len(
        cfg, args.prompt_len + args.gen_len)

    @jax.jit
    def prefill_fn(params, tokens):
        return transformer.prefill(cfg, params, tokens,
                                   cache_extra=cache_len - tokens.shape[1])

    @jax.jit
    def decode_fn(params, cache, tokens, pos):
        return transformer.decode_step(cfg, params, cache, tokens, pos)

    net = netsim.NetworkConfig.preset(args.net) if args.net else None
    comm = CommLog()
    n_requests = len(queue)

    t0 = time.time()
    done = 0
    batch_no = 0
    while queue:
        if tracer is not None:
            # queue wait: every request arrived at t0, so a batch's wait
            # is simply how long serving the earlier batches took
            tracer.event("queue.wait", batch=batch_no,
                         wait_s=time.time() - t0,
                         queued=len(queue))
        batch_reqs = [queue.pop(0) for _ in range(min(args.batch, len(queue)))]
        b = len(batch_reqs)
        lens = np.array([len(r) for r in batch_reqs], np.int32)
        toks = np.zeros((b, pad_to), np.int32)
        for i, r in enumerate(batch_reqs):
            toks[i, :len(r)] = r

        with _sp("prefill", batch=batch_no, size=b):
            logits, cache = prefill_fn(params, jnp.asarray(toks))
            # sample the first token inside the span so it absorbs the
            # prefill compute (dispatch is async; argmax forces it)
            last = jnp.argmax(logits, -1).astype(jnp.int32)
            last.block_until_ready()
        out_tokens = np.zeros((b, args.gen_len), np.int32)
        pos = jnp.asarray(lens)  # next position per request
        # greedy (or sampled) continuation
        with _sp("decode", batch=batch_no, size=b, steps=args.gen_len):
            for t in range(args.gen_len):
                out_tokens[:, t] = np.asarray(last)
                logits, cache = decode_fn(params, cache, last[:, None], pos)
                if args.temperature > 0:
                    key_t = jax.random.fold_in(key, t)
                    last = jax.random.categorical(
                        key_t, logits / args.temperature).astype(jnp.int32)
                else:
                    last = jnp.argmax(logits, -1).astype(jnp.int32)
                pos = pos + 1
        done += b
        batch_no += 1
        if net is not None:
            # SLO accounting: prompts in + streamed tokens out, through
            # the preset's latency/bandwidth model; "accuracy" is the
            # drained fraction of the queue, so seconds_to_target(f) is
            # the simulated time to serve fraction f of the requests
            prompt_bytes = float(lens.sum()) * TOKEN_BYTES
            response_bytes = float(b * args.gen_len) * TOKEN_BYTES
            comm.record(batch_no, prompt_bytes + response_bytes,
                        acc=done / n_requests,
                        round_s=batch_net_seconds(net, prompt_bytes,
                                                  args.gen_len,
                                                  response_bytes))
        print(f"batch of {b}: prompts {lens.tolist()} -> "
              f"{args.gen_len} tokens each "
              f"(first req head: {out_tokens[0, :8].tolist()})", flush=True)

    dt = time.time() - t0
    total_tok = done * args.gen_len
    print(f"served {done} requests, {total_tok} tokens "
          f"in {dt:.1f}s = {total_tok / dt:.1f} tok/s")
    if net is not None:
        half = comm.seconds_to_target(0.5)
        full = comm.seconds_to_target(1.0)

        def _drain(v):  # None = that drain fraction was never reached
            return "not reached" if v is None else f"{v:.3f}s"

        print(f"SLO [{net.name}]: {comm.total_hours * 3600:.3f} simulated "
              f"network seconds total ({comm.total_hours:.6f} h, "
              f"{comm.total_gb * 1e3:.3f} MB on the wire); "
              f"p50 queue drain {_drain(half)}, full drain {_drain(full)}")
    if tracer is not None:
        tracer.event(
            "slo", requests=done, tokens=total_tok, wall_s=dt,
            tok_s=total_tok / dt,
            net=net.name if net is not None else None,
            sim_net_s=comm.total_hours * 3600 if net is not None else 0.0,
            rollup=tracer.rollup()["spans"])
        tracer.sink.close()
        print(f"trace: {tracer.sink.n_emitted} records -> "
              f"{tracer.sink.path}")


if __name__ == "__main__":
    main()
