"""Synthetic clustered token streams for running FACADE over LM backbones.

Feature heterogeneity for language: every cluster observes the same
underlying sequence process through a cluster-specific *vocabulary
permutation* — the LM analogue of the paper's image rotations (structure
preserved, surface features shifted). Sequences follow a sparse first-order
Markov chain so they are learnable by small models in few steps.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenSpec:
    vocab_size: int = 512
    seq_len: int = 64
    branching: int = 4     # successors per token in the Markov chain
    seed: int = 0


def _chain(rng, spec: TokenSpec):
    succ = rng.integers(0, spec.vocab_size,
                        size=(spec.vocab_size, spec.branching))
    return succ


def _gen(rng, succ, spec: TokenSpec, n_seq: int):
    toks = np.empty((n_seq, spec.seq_len), np.int64)
    cur = rng.integers(0, spec.vocab_size, size=n_seq)
    for t in range(spec.seq_len):
        toks[:, t] = cur
        pick = rng.integers(0, succ.shape[1], size=n_seq)
        cur = succ[cur, pick]
    return toks


def make_clustered_tokens(spec: TokenSpec, cluster_sizes, seqs_per_node: int,
                          test_seqs: int = 64):
    """Returns dict with train [n, N, S], per-cluster test [k][M, S],
    node_cluster [n]."""
    rng = np.random.default_rng(spec.seed)
    succ = _chain(rng, spec)
    k = len(cluster_sizes)
    perms = [np.arange(spec.vocab_size)]
    for _ in range(k - 1):
        perms.append(rng.permutation(spec.vocab_size))

    train, node_cluster = [], []
    for c, size in enumerate(cluster_sizes):
        for _ in range(size):
            seq = _gen(rng, succ, spec, seqs_per_node)
            train.append(perms[c][seq])
            node_cluster.append(c)
    test = [perms[c][_gen(rng, succ, spec, test_seqs)] for c in range(k)]
    return {
        "train": np.stack(train).astype(np.int32),
        "test": [t.astype(np.int32) for t in test],
        "node_cluster": np.asarray(node_cluster, np.int32),
    }


def lm_batch(tokens: np.ndarray):
    """next-token-prediction batch dict from [., S] token block."""
    return {
        "tokens": tokens[..., :-1],
        "labels": tokens[..., 1:],
        "mask": np.ones(tokens[..., 1:].shape, np.float32),
    }
