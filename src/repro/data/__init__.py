from .pipeline import (padded_eval_batches, sample_round_batches,  # noqa: F401
                       sample_round_token_batches)
from .synthetic import (ClusteredDataset, SynthSpec, apply_transform,  # noqa: F401
                        make_clustered_data)
from .tokens import TokenSpec, lm_batch, make_clustered_tokens  # noqa: F401
