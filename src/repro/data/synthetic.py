"""Synthetic clustered image data with feature skew (offline stand-in for
CIFAR-10 / Imagenette / Flickr-Mammals).

Class structure: each class has a smooth random 'blob' prototype; samples are
prototype + small spatial jitter + Gaussian noise. Feature heterogeneity is
created exactly as in the paper: per-cluster image transforms — rotations
(Sec. V-A) or color filters (Appendix H). Labels stay uniform per node
(paper: 'uniform partitioning ... heterogeneity must be reflected in the
feature composition').
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class SynthSpec:
    n_classes: int = 10
    image_size: int = 16
    channels: int = 3
    samples_per_class: int = 32   # per node
    test_per_class: int = 32      # per cluster test set
    noise: float = 0.35
    jitter: int = 2               # max +/- pixel shift
    seed: int = 0


# --------------------------------------------------------------------------
# transforms (the paper's feature-skew generators)
def rotate(x, quarter_turns: int):
    return np.rot90(x, k=quarter_turns, axes=(-3, -2))


_SEPIA = np.array([[0.393, 0.769, 0.189],
                   [0.349, 0.686, 0.168],
                   [0.272, 0.534, 0.131]]).T


def apply_transform(x: np.ndarray, name: str) -> np.ndarray:
    """x [..., H, W, C] in [-1, 1]."""
    if name == "rot0" or name == "none":
        return x
    if name.startswith("rot"):
        deg = int(name[3:])
        return rotate(x, deg // 90)
    if name == "gray":
        g = x.mean(axis=-1, keepdims=True)
        return np.repeat(g, x.shape[-1], axis=-1)
    if name == "sepia":
        return np.clip((x * 0.5 + 0.5) @ _SEPIA, 0, 1) * 2.0 - 1.0
    if name == "saturate":
        g = x.mean(axis=-1, keepdims=True)
        return np.clip(g + 1.8 * (x - g), -1, 1)
    raise ValueError(f"unknown transform {name!r}")


# --------------------------------------------------------------------------
def _prototypes(rng, spec: SynthSpec):
    """Smooth per-class patterns: random coarse grids, bilinear-upsampled."""
    coarse = spec.image_size // 4
    protos = rng.normal(size=(spec.n_classes, coarse, coarse, spec.channels))
    # bilinear upsample x4 via repeat + box blur
    up = np.repeat(np.repeat(protos, 4, axis=1), 4, axis=2)
    kernel = np.ones((5,)) / 5.0
    for ax in (1, 2):
        up = np.apply_along_axis(
            lambda m: np.convolve(m, kernel, mode="same"), ax, up)
    up = up / (np.abs(up).max(axis=(1, 2, 3), keepdims=True) + 1e-9)
    return up.astype(np.float32)


def _sample(rng, protos, labels, spec: SynthSpec):
    """Prototype + random shift + noise for each label."""
    n = len(labels)
    x = protos[labels].copy()
    if spec.jitter > 0:
        sh = rng.integers(-spec.jitter, spec.jitter + 1, size=(n, 2))
        for i in range(n):
            x[i] = np.roll(x[i], sh[i], axis=(0, 1))
    x += rng.normal(scale=spec.noise, size=x.shape).astype(np.float32)
    return np.clip(x, -2.0, 2.0).astype(np.float32)


@dataclasses.dataclass
class ClusteredDataset:
    train_x: np.ndarray      # [n_nodes, N, H, W, C]
    train_y: np.ndarray      # [n_nodes, N]
    test_x: list             # per cluster: [M, H, W, C]
    test_y: list             # per cluster: [M]
    node_cluster: np.ndarray  # [n_nodes] true cluster id
    spec: SynthSpec
    transforms: tuple

    @property
    def n_nodes(self) -> int:
        return self.train_x.shape[0]

    @property
    def k(self) -> int:
        return len(self.test_x)


def make_clustered_data(spec: SynthSpec, cluster_sizes: Sequence[int],
                        transforms: Sequence[str] | None = None,
                        label_split: Sequence[Sequence[int]] | None = None
                        ) -> ClusteredDataset:
    """cluster_sizes e.g. (30, 2); transforms e.g. ("rot0", "rot180").

    ``label_split`` (Appendix G) restricts each cluster to a label subset
    (e.g. vehicles vs animals) instead of / in addition to feature skew.
    """
    k = len(cluster_sizes)
    if transforms is None:
        transforms = [f"rot{(i * 90) % 360}" for i in range(k)]
    assert len(transforms) == k
    rng = np.random.default_rng(spec.seed)
    protos = _prototypes(rng, spec)

    train_x, train_y, node_cluster = [], [], []
    for c, size in enumerate(cluster_sizes):
        allowed = (np.arange(spec.n_classes) if label_split is None
                   else np.asarray(label_split[c]))
        for _ in range(size):
            labels = np.repeat(allowed, spec.samples_per_class)
            rng.shuffle(labels)
            x = _sample(rng, protos, labels, spec)
            x = apply_transform(x, transforms[c])
            train_x.append(x)
            train_y.append(labels)
            node_cluster.append(c)

    test_x, test_y = [], []
    for c in range(k):
        allowed = (np.arange(spec.n_classes) if label_split is None
                   else np.asarray(label_split[c]))
        labels = np.repeat(allowed, spec.test_per_class)
        x = _sample(rng, protos, labels, spec)
        x = apply_transform(x, transforms[c])
        test_x.append(x.astype(np.float32))
        test_y.append(labels.astype(np.int32))

    return ClusteredDataset(
        train_x=np.stack(train_x), train_y=np.stack(train_y).astype(np.int32),
        test_x=test_x, test_y=test_y,
        node_cluster=np.asarray(node_cluster, np.int32),
        spec=spec, transforms=tuple(transforms))
