"""Deterministic per-node batch sampling for the DL training loop.

``sample_round_batches`` draws, for every node, H local-step batches of size
B (paper: H=tau local steps on batches of B=8) — returned stacked
[n, H, B, ...] so one jit'd round consumes the whole round's data.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sample_round_batches(key, train_x, train_y, h: int, b: int):
    """train_x [n, N, ...], train_y [n, N] -> batches pytree [n, H, B, ...]."""
    n, per_node = train_x.shape[0], train_x.shape[1]
    idx = jax.random.randint(key, (n, h, b), 0, per_node)
    gx = jax.vmap(lambda x, i: x[i])(train_x, idx.reshape(n, h * b))
    gy = jax.vmap(lambda y, i: y[i])(train_y, idx.reshape(n, h * b))
    return {
        "x": gx.reshape((n, h, b) + train_x.shape[2:]),
        "y": gy.reshape(n, h, b),
    }


def sample_round_token_batches(key, train_tokens, h: int, b: int):
    """train_tokens [n, N, S] -> {tokens, labels, mask} with [n,H,B,S-1]."""
    n, per_node, s = train_tokens.shape
    idx = jax.random.randint(key, (n, h, b), 0, per_node)
    g = jax.vmap(lambda x, i: x[i])(train_tokens, idx.reshape(n, h * b))
    g = g.reshape(n, h, b, s)
    return {
        "tokens": g[..., :-1],
        "labels": g[..., 1:],
        "mask": jnp.ones((n, h, b, s - 1), jnp.float32),
    }


def padded_eval_batches(x: np.ndarray, batch: int):
    """[N, ...] -> (batches [nb, B, ...], mask [nb, B] float32).

    Shape-stable eval batching: the trailing partial batch is zero-padded
    and masked out instead of yielded ragged, so the evaluator can jit/vmap
    over a fixed [nb, B, ...] block (one compile per test-set shape).
    """
    x = np.asarray(x)
    n = x.shape[0]
    nb = max(1, -(-n // batch))
    pad = nb * batch - n
    mask = np.ones((n,), np.float32)
    if pad:
        x = np.concatenate([x, np.zeros((pad,) + x.shape[1:], x.dtype)])
        mask = np.concatenate([mask, np.zeros((pad,), np.float32)])
    return (x.reshape((nb, batch) + x.shape[1:]), mask.reshape(nb, batch))
