from .kernel import head_select_losses  # noqa: F401
from .ops import facade_head_losses  # noqa: F401
from .ref import head_losses_ref  # noqa: F401
