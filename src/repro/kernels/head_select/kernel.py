"""FACADE head-selection kernel: cross-entropy of ALL k candidate heads in
one pass, vocab-blocked, without ever materializing [T, V] logits (let alone
k of them).

This is the paper's hot spot on TPU: step 2c evaluates k losses per node per
round; for LM heads the k x (T x D x V) logit matmuls dominate. The kernel
streams vocab blocks through VMEM with an online log-sum-exp (flash-style),
accumulating per-token running (m, l, gold) in scratch, and emits one
partial NLL sum per (head, token-block).

Grid: (K, T/bt, V/bv) with the vocab axis sequential ("arbitrary").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(f_ref, w_ref, lab_ref, out_ref, m_ref, l_ref, g_ref, *,
            block_v: int, n_v: int):
    vi = pl.program_id(2)

    @pl.when(vi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    f = f_ref[...].astype(jnp.float32)                   # [bt, d]
    w = w_ref[0].astype(jnp.float32)                     # [d, bv]
    logits = jax.lax.dot_general(f, w, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    labs = lab_ref[...][:, 0]                            # [bt]
    cols = vi * block_v + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 1)
    gold_hit = labs[:, None] == cols
    g_ref[...] += jnp.where(gold_hit, logits, 0.0).sum(
        axis=1, keepdims=True)

    m_prev = m_ref[...]                                  # [bt, 1]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.exp(logits - m_new).sum(
        axis=1, keepdims=True)
    m_ref[...] = m_new

    @pl.when(vi == n_v - 1)
    def _done():
        lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
        valid = (labs >= 0)[:, None]
        nll = jnp.where(valid, lse - g_ref[...], 0.0)
        out_ref[0, 0] = nll.sum()


def head_select_losses(features, heads, labels, *, block_t: int = 128,
                       block_v: int = 512, interpret: bool = False):
    """features [T,D], heads [K,D,V], labels [T] (−1 = padding)
    -> summed NLL per head [K] (divide by valid count outside)."""
    t, d = features.shape
    k, _, v = heads.shape
    block_t = min(block_t, t)
    block_v = min(block_v, v)
    assert t % block_t == 0 and v % block_v == 0
    n_t, n_v = t // block_t, v // block_v

    kernel = functools.partial(_kernel, block_v=block_v, n_v=n_v)
    partial = pl.pallas_call(
        kernel,
        grid=(k, n_t, n_v),
        in_specs=[
            pl.BlockSpec((block_t, d), lambda ki, ti, vi: (ti, 0)),
            pl.BlockSpec((1, d, block_v), lambda ki, ti, vi: (ki, 0, vi)),
            pl.BlockSpec((block_t, 1), lambda ki, ti, vi: (ti, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda ki, ti, vi: (ki, ti)),
        out_shape=jax.ShapeDtypeStruct((k, n_t), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_t, 1), jnp.float32),
            pltpu.VMEM((block_t, 1), jnp.float32),
            pltpu.VMEM((block_t, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(features, heads, labels[:, None].astype(jnp.int32))
    return partial.sum(axis=1)
