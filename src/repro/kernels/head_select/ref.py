"""Oracle for the fused k-head cross-entropy (FACADE head selection)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def head_losses_ref(features, heads, labels, mask=None):
    """features [T,D], heads [K,D,V], labels [T] -> [K] mean NLL per head.

    mask [T] (1=count); labels < 0 are also excluded.
    """
    t = features.shape[0]
    valid = labels >= 0
    if mask is not None:
        valid &= mask > 0
    denom = jnp.maximum(valid.sum(), 1)
    labs = jnp.maximum(labels, 0)

    def one(w):
        logits = (features.astype(jnp.float32) @ w.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labs[:, None], axis=-1)[:, 0]
        return jnp.where(valid, lse - gold, 0.0).sum() / denom

    return jax.vmap(one)(heads)
