"""Public wrapper: FACADE step-2c head selection over cached core features."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import head_select_losses
from .ref import head_losses_ref


def _pad_to(x, m: int, axis: int, fill=0):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, pad)
    return jnp.pad(x, pads, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("block_t", "block_v",
                                             "interpret", "use_kernel"))
def facade_head_losses(features, heads, labels, mask=None, *,
                       block_t: int = 128, block_v: int = 512,
                       interpret: bool = False, use_kernel: bool = True):
    """features [B,S,D] or [T,D]; heads [K,D,V]; labels/mask [B,S] or [T].
    Returns mean NLL per head [K] — argmin of this is the FACADE cluster ID.
    """
    if features.ndim == 3:
        features = features.reshape(-1, features.shape[-1])
        labels = labels.reshape(-1)
        if mask is not None:
            mask = mask.reshape(-1)
    labels = jnp.where((mask > 0) if mask is not None else True,
                       labels, -1).astype(jnp.int32)
    denom = jnp.maximum((labels >= 0).sum(), 1).astype(jnp.float32)

    if not use_kernel:
        return head_losses_ref(features, heads, labels)

    f = _pad_to(features, block_t, 0)
    lab = _pad_to(labels, block_t, 0, fill=-1)
    h = _pad_to(heads, block_v, 2)  # padded vocab cols: logits can only
    # lower the lse by adding exp(w@f)=... zero-weight cols give logit 0;
    # mask them to -inf by padding with large negative bias via labels trick
    # is unnecessary: zero columns add exp(0 - m) terms. To stay exact we
    # require V % block_v == 0 from callers; assert here.
    assert heads.shape[2] % block_v == 0 or heads.shape[2] < block_v, (
        "vocab must divide block_v (or be smaller); zero-padding would "
        "perturb the log-sum-exp")
    if heads.shape[2] < block_v:
        block_v = heads.shape[2]
        h = heads
    sums = head_select_losses(f, h, lab, block_t=block_t, block_v=block_v,
                              interpret=interpret)
    return sums / denom
