"""Pallas TPU kernels (validated in interpret mode on CPU):

  * flash_attention — blocked causal GQA attention (train/prefill hot spot)
  * head_select     — FACADE step-2c fused k-head cross-entropy
  * rwkv6           — wkv recurrence with VMEM-resident state
"""
from . import flash_attention, head_select, rwkv6  # noqa: F401
