from .kernel import flash_attention  # noqa: F401
from .ops import attention_auto, flash_attention_op  # noqa: F401
from .ref import attention_ref  # noqa: F401
