"""Pure-jnp oracle for the blocked causal GQA attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    """q [B,Hq,S,D], k/v [B,Hkv,S,D] -> [B,Hq,S,D]. fp32 softmax."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    qg = q.reshape(b, hkv, g, s, d)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(s)
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window > 0:
        mask &= (pos[:, None] - pos[None, :]) < window
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w.astype(v.dtype), v)
    return out.reshape(b, hq, s, d)
