"""Blocked causal GQA flash attention — Pallas TPU kernel.

TPU adaptation notes:
  * grid = (batch*q_heads, q_blocks, kv_blocks); kv dimension is the
    sequential ("arbitrary") axis, so the fp32 accumulator / running max /
    running sum live in VMEM scratch across kv steps (online softmax).
  * BlockSpec tiles are (block_q, head_dim) / (block_kv, head_dim) with
    head_dim a multiple of 128-friendly MXU shapes (64/128 typical).
  * GQA is handled in the kv index_map (q head h reads kv head h // group)
    — no repeated k/v materialization in HBM.
  * causal + sliding-window masking by absolute positions derived from
    program ids; fully-masked kv blocks still iterate (grid is static) but
    write nothing — the cost model in benchmarks accounts for this.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, block_q: int, block_kv: int, n_kv: int,
                 causal: bool, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale             # [bq, d]
    k = k_ref[0].astype(jnp.float32)                     # [bkv, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq,bkv]

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_kv), 0)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_q, block_kv), 1)
    mask = jnp.ones((block_q, block_kv), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0]                                    # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                               # [bq, bkv]
    alpha = jnp.exp(m_prev - m_new)                      # [bq, 1]

    l_ref[0] = l_ref[0] * alpha + p.sum(axis=1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_ref[0] = acc_ref[0] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[0] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        l = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0] = (acc_ref[0] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = False):
    """q [B,Hq,S,D], k/v [B,Hkv,S,D] -> [B,Hq,S,D]."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    n_q, n_kv = s // block_q, s // block_kv

    qr = q.reshape(b * hq, s, d)
    kr = k.reshape(b * hkv, s, d)
    vr = v.reshape(b * hkv, s, d)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        # bh = b * hq + h  ->  kv index = b * hkv + h // g
        return ((bh // hq) * hkv + (bh % hq) // g, ki, 0)

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_kv=block_kv,
        n_kv=n_kv, causal=causal, window=window)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_kv, d), kv_map),
            pl.BlockSpec((1, block_kv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, block_q, d), jnp.float32),
            pltpu.VMEM((1, block_q, 1), jnp.float32),
            pltpu.VMEM((1, block_q, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, s, d)
