"""Jit'd public wrapper for the flash-attention kernel.

On this CPU container the kernel executes in interpret mode (the TPU
lowering is the target); ``attention_auto`` picks the kernel on TPU and the
oracle elsewhere, so the model code can call one function everywhere.
"""
from __future__ import annotations

import functools

import jax

from .kernel import flash_attention
from .ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                       block_q: int = 128, block_kv: int = 128,
                       interpret: bool = False):
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_kv=block_kv,
                           interpret=interpret)


def attention_auto(q, k, v, *, causal: bool = True, window: int = 0):
    if jax.default_backend() == "tpu":
        return flash_attention_op(q, k, v, causal=causal, window=window)
    return attention_ref(q, k, v, causal=causal, window=window)
