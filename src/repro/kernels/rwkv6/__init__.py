from .kernel import wkv_kernel  # noqa: F401
from .ops import wkv_auto, wkv_op  # noqa: F401
from .ref import wkv_ref  # noqa: F401
