"""RWKV6 wkv recurrence — Pallas TPU kernel.

The HBM-resident lax.scan implementation rereads and rewrites the
[hd x hd] per-head state every timestep. This kernel keeps the state in
VMEM scratch for an entire time block (the roofline win: state traffic
drops from O(T * hd^2) HBM bytes to O(T/block * hd^2)), iterating time
blocks sequentially in the grid.

    y_t = r_t @ (S + diag(u) k_t^T v_t);  S <- diag(w_t) S + k_t^T v_t

Grid: (B*H, T/block_t) with time the sequential axis. The final state is
emitted for chaining into decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, s_ref, *,
            block_t: int, n_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0]                                         # [hd]

    def step(t, _):
        r = r_ref[0, t]                                  # [hd]
        k = k_ref[0, t]
        v = v_ref[0, t]
        w = w_ref[0, t]
        s = s_ref[...]                                   # [hd, hd]
        bonus = jnp.sum(r * u * k)                       # scalar
        y = r @ s + bonus * v                            # [hd]
        y_ref[0, t] = y.astype(y_ref.dtype)
        s_ref[...] = w[:, None] * s + k[:, None] * v[None, :]
        return 0

    jax.lax.fori_loop(0, block_t, step, 0)

    @pl.when(ti == n_t - 1)
    def _done():
        s_out_ref[0] = s_ref[...]


def wkv_kernel(r, k, v, w, u, *, block_t: int = 64, interpret: bool = False):
    """r,k,v,w [B,S,H,hd] fp32; u [H,hd] -> (y [B,S,H,hd], S_f [B,H,hd,hd])."""
    b, s, h, hd = r.shape
    block_t = min(block_t, s)
    assert s % block_t == 0
    n_t = s // block_t

    def flat(x):  # [B,S,H,hd] -> [B*H, S, hd]
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, hd)

    rf, kf, vf, wf = map(flat, (r, k, v, w))
    uf = jnp.broadcast_to(u[None], (b, h, hd)).reshape(b * h, hd)

    kernel = functools.partial(_kernel, block_t=block_t, n_t=n_t)
    y, s_f = pl.pallas_call(
        kernel,
        grid=(b * h, n_t),
        in_specs=[
            pl.BlockSpec((1, block_t, hd), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, block_t, hd), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, block_t, hd), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, block_t, hd), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, hd), lambda bh, ti: (bh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, hd), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, hd, hd), lambda bh, ti: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, hd), jnp.float32),
            jax.ShapeDtypeStruct((b * h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, wf, uf)
    y = y.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    return y, s_f.reshape(b, h, hd, hd)
