"""Jit'd wrapper + backend dispatch for the RWKV6 wkv kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import wkv_kernel
from .ref import wkv_ref


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def wkv_op(r, k, v, w, u, *, block_t: int = 64, interpret: bool = False):
    return wkv_kernel(r, k, v, w, u, block_t=block_t, interpret=interpret)


def wkv_auto(r, k, v, w, u):
    if jax.default_backend() == "tpu":
        return wkv_op(r, k, v, w, u)
    return wkv_ref(r, k, v, w, u)
