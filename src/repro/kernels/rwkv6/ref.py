"""Oracle for the RWKV6 wkv recurrence — delegates to the model's scan."""
from repro.models.rwkv import wkv_scan as wkv_ref  # noqa: F401
