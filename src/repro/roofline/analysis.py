"""Roofline-term derivation from a compiled (dry-run) XLA executable.

Three terms, each a lower-bound execution time in seconds on the target
hardware (TPU v5e constants live in ``launch.mesh.HW``):

    compute    = HLO_FLOPs      / (chips * peak_FLOP/s)
    memory     = HLO_bytes      / (chips * HBM_bw)
    collective = collective_B   / (chips * link_bw)

``cost_analysis()`` reports per-device FLOPs/bytes for the SPMD-partitioned
module, so we multiply back by chip count where needed — the convention here
is: cost_analysis numbers are PER DEVICE (post-partitioning), and the terms
divide per-device work by per-chip peak. collective bytes are parsed from the
optimized HLO text (cost_analysis does not expose them).
"""
from __future__ import annotations

import dataclasses
import re

_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                   "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

# shapes like  bf16[2,16,128]{2,1,0}  or  f32[] (scalar)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nb


def parse_shape_list(text: str) -> int:
    """Total bytes of every shape literal in an HLO type string
    (handles tuple types '(bf16[..], f32[..])')."""
    return sum(_shape_bytes(m.group(1), m.group(2))
               for m in _SHAPE_RE.finditer(text))


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum result-operand bytes of every collective op in an HLO module.

    Returns {op_name: bytes, ..., 'total': bytes, 'count': n_ops}.
    HLO instruction form:  %name = TYPE opcode(args), ...
    """
    out = {op: 0 for op in _COLLECTIVE_OPS}
    counts = {op: 0 for op in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
                     r"([a-z\-]+)", line)
        if not m:
            continue
        opcode = m.group(2)
        # match exact collective opcodes (all-gather-start etc. count once)
        for op in _COLLECTIVE_OPS:
            if opcode == op or opcode == op + "-start":
                out[op] += parse_shape_list(m.group(1))
                counts[op] += 1
                break
    total = sum(out.values())
    return {**out, "total": total,
            "count": sum(counts.values()), "counts": counts}


# --------------------------------------------------------------------------
@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float          # per-device
    hlo_bytes: float          # per-device HBM traffic estimate
    collective_bytes: float   # per-device bytes moved over ICI
    collective_counts: dict
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float        # 6·N·D useful flops (global)
    bytes_per_device: float   # from memory_analysis (peak allocation)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / global HLO_FLOPs — how much compiled compute is
        'useful' model math (catches remat/dead-code waste)."""
        tot = self.hlo_flops * self.chips
        return self.model_flops / tot if tot else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "hlo_gflops_per_dev": self.hlo_flops / 1e9,
            "hlo_gbytes_per_dev": self.hlo_bytes / 1e9,
            "coll_gbytes_per_dev": self.collective_bytes / 1e9,
            "model_gflops": self.model_flops / 1e9,
            "useful_ratio": self.useful_ratio,
            "peak_gbytes_per_dev": self.bytes_per_device / 1e9,
        }


def model_flops(n_params_active: int, n_tokens: int, kind: str) -> float:
    """6·N·D for training; 2·N·D for inference fwd-only."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * float(n_params_active) * float(n_tokens)


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     chips: int, hw: dict, n_params_active: int,
                     n_tokens: int, kind: str) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)

    mem = compiled.memory_analysis()
    peak = 0.0
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes"):
        peak += float(getattr(mem, attr, 0.0) or 0.0)

    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=bytes_accessed,
        collective_bytes=float(coll["total"]),
        collective_counts=coll["counts"],
        t_compute=flops / hw["peak_flops_bf16"],
        t_memory=bytes_accessed / hw["hbm_bw"],
        t_collective=float(coll["total"]) / hw["ici_bw"],
        model_flops=model_flops(n_params_active, n_tokens, kind),
        bytes_per_device=peak,
    )
