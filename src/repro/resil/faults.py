"""On-device node-fault injection: crashes, restarts, payload corruption.

netsim (PRs 1/4) stresses the *links* — drops, churn blocks, bursty loss,
stragglers. This module stresses the *nodes*: a process can crash and stay
down for a random number of rounds (a two-state Markov chain, the node
analogue of the Gilbert–Elliott link channel), come back either with the
state it crashed with (``rejoin-stale``) or factory-reset to its round-0
init (``reset``), and a live node can ship a corrupted payload — additive
noise, a blown-up scale, or NaNs — to every neighbor for a round.

Everything is seeded and static: a frozen :class:`FaultConfig` lives on
``NetworkConfig.faults`` (so it is an ``EngineSpec`` cache-key component
for free), the carried :class:`FaultState` rides the donated
``EngineCarry`` next to ``chan``/``gossip``, and :func:`advance` is THE
shared per-round entry point both drivers call — the scan engine inside
``lax.scan``, the legacy loop through Python — the same discipline that
keeps ``netsim.advance_conditions`` / ``topo.advance`` engine/legacy
bit-identical.

Semantics, composed entirely through existing netsim contracts:

* a crashed node is ``active == 0`` for the round:
  ``topology.effective_adjacency`` zeroes its rows AND columns (it
  neither sends nor receives, so its directed edges cost 0 bytes), and
  ``netsim.round_time`` multiplies by ``active`` (it never gates
  ``round_seconds``) — byte/time honesty needs no new accounting code;
* a corrupting node stays active: its payload is mangled in
  :func:`corrupt_view` (composed with the async stale view by
  ``netwire.sent_view``) but its OWN state is untouched — corruption is
  per-transmission, not persistent;
* the robust-aggregation guard (:func:`guard_of`,
  ``bindings.gossip_mix(guard=...)``) quarantines non-finite senders and
  norm-clips the rest; it is statically OFF unless ``robust`` is set and
  ``corrupt_rate > 0``, so every zero-rate off-switch stays bit-for-bit
  the legacy arithmetic.

All randomness shares netsim's ``fold_in(fold_in(PRNGKey(seed), tag),
round)`` stream scheme with tags disjoint from every existing consumer
(conditions.py uses 1–6, repro.topo uses 7, events.py uses 1000).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.netsim.conditions import _stream

# fold_in stream tags — MUST stay disjoint from netsim.conditions (1-6),
# repro.topo (7) and netsim.events (1000)
_CRASH, _RESTART, _CORRUPT, _PAYLOAD = 8, 9, 10, 11

RESTART_MODES = ("rejoin-stale", "reset")
CORRUPT_MODES = ("noise", "scale", "nan")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Static node-fault model. Lives on ``NetworkConfig.faults``, so every
    field forks the ``EngineSpec`` cache key through the ``net`` component
    (pinned by ``tests/test_resil.py`` / ``tests/test_property.py``).

    Crash chain (per node, per round): an up node goes down with
    ``crash_rate``; a down node comes back with ``restart_rate`` —
    expected outage length is ``1 / restart_rate`` rounds. ``restart_mode``
    picks what a restarted node rejoins with: the state it crashed with
    (``rejoin-stale``, the frozen-params churn semantics) or its round-0
    init (``reset``, a factory-fresh process).

    Corruption (per live node, per round, rate ``corrupt_rate``): the
    node's outgoing payload — never its own state — is mangled per
    ``corrupt_mode``: ``noise`` adds ``corrupt_scale``-scaled Gaussian
    noise, ``scale`` multiplies by ``corrupt_scale``, ``nan`` poisons
    every float leaf. ``robust``/``clip`` configure the receiving side's
    aggregation guard (see ``bindings.gossip_mix``): non-finite payloads
    are quarantined and finite ones norm-clipped to ``clip`` times the
    receiver's own norm. Zero rates disable the corresponding machinery
    bit-for-bit.
    """
    crash_rate: float = 0.0
    restart_rate: float = 0.5
    restart_mode: str = "rejoin-stale"
    corrupt_rate: float = 0.0
    corrupt_mode: str = "noise"
    corrupt_scale: float = 100.0
    robust: bool = True
    clip: float = 3.0

    def __post_init__(self):
        if self.restart_mode not in RESTART_MODES:
            raise ValueError(f"restart_mode must be one of {RESTART_MODES}, "
                             f"got {self.restart_mode!r}")
        if self.corrupt_mode not in CORRUPT_MODES:
            raise ValueError(f"corrupt_mode must be one of {CORRUPT_MODES}, "
                             f"got {self.corrupt_mode!r}")
        for name in ("crash_rate", "restart_rate", "corrupt_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.clip <= 0:
            raise ValueError(f"clip must be > 0, got {self.clip}")


class FaultState(NamedTuple):
    """On-device crash-chain state, carried through the engine's scan (or
    the legacy Python loop) like ``ChannelState``/``GossipState``.
    ``None`` in the carry whenever ``crash_rate == 0`` — corruption alone
    is memoryless and needs no state."""
    down: Any            # [n] float32 {0,1}: 1 = node is down this round
    init: Any = None     # round-0 state copy (restart_mode="reset" only)


def faults_of(net) -> "FaultConfig | None":
    """The run's fault model, ``None`` when faults are off (no ``net`` or
    no ``net.faults``)."""
    return None if net is None else net.faults


def guard_of(fcfg: "FaultConfig | None") -> "FaultConfig | None":
    """The robust-aggregation guard to hand ``bindings.gossip_mix`` —
    non-None ONLY when payloads can actually be corrupted AND the config
    asks for robustness. Gating on ``corrupt_rate > 0`` (not just
    ``robust``) keeps every zero-rate run on the exact legacy arithmetic:
    the guard's row renormalization would otherwise perturb bits even on
    honest data (``mixing_matrix`` rows are row-stochastic only to float
    tolerance)."""
    if fcfg is None or not fcfg.robust or fcfg.corrupt_rate <= 0:
        return None
    return fcfg


def init_state(net, n: int, state=None) -> "FaultState | None":
    """Mint the run's :class:`FaultState` (``None`` when the crash chain
    is off). ``state`` is the run's initial algorithm state; under
    ``restart_mode="reset"`` a leaf-for-leaf COPY is kept so restarted
    nodes can be factory-reset — copied so the buffer never aliases the
    donated training state (the ``init_gossip`` discipline)."""
    fcfg = faults_of(net)
    if fcfg is None or fcfg.crash_rate <= 0:
        return None
    init = None
    if fcfg.restart_mode == "reset":
        if state is None:
            raise ValueError('restart_mode="reset" needs the initial '
                             "algorithm state to restore nodes from")
        init = jax.tree.map(jnp.copy, state)
    return FaultState(down=jnp.zeros((n,), jnp.float32), init=init)


def advance(net, n: int, rnd, conds, fstate):
    """THE shared per-round fault hook for both drivers, called right
    after ``netsim.advance_conditions`` (and before ``apply_async``, so a
    corrupted payload corrupts whatever the node delivers — fresh or
    stale snapshot alike).

    Returns ``(conds', fstate', restarted)``: conditions with crashed
    nodes folded into ``active`` (+ the round's ``crashed``/``corrupt``
    masks and payload-noise key), the advanced crash chain, and — under
    ``restart_mode="reset"`` only — the {0,1} mask of nodes restarting
    THIS round (the driver then applies :func:`reset_nodes` before the
    round function; ``None`` means nothing to reset, statically). A
    ``None``/zero-rate fault config passes everything through untouched.
    """
    fcfg = faults_of(net)
    if fcfg is None or conds is None:
        return conds, fstate, None
    restarted = None
    if fcfg.crash_rate > 0:
        u_down = jax.random.uniform(_stream(net, _CRASH, rnd), (n,))
        u_up = jax.random.uniform(_stream(net, _RESTART, rnd), (n,))
        was_down = fstate.down > 0
        come_up = u_up < fcfg.restart_rate
        down = jnp.where(was_down, ~come_up,
                         u_down < fcfg.crash_rate).astype(jnp.float32)
        conds = conds._replace(active=conds.active * (1.0 - down),
                               crashed=down)
        if fcfg.restart_mode == "reset":
            restarted = (was_down & come_up).astype(jnp.float32)
        fstate = fstate._replace(down=down)
    if fcfg.corrupt_rate > 0:
        u_cor = jax.random.uniform(_stream(net, _CORRUPT, rnd), (n,))
        # crashed/churned-out nodes deliver nothing — only live senders
        # can corrupt, so the masks stay disjoint
        corrupt = (u_cor < fcfg.corrupt_rate).astype(jnp.float32)
        conds = conds._replace(corrupt=corrupt * conds.active,
                               fault_key=_stream(net, _PAYLOAD, rnd))
    return conds, fstate, restarted


def reset_nodes(n: int, restarted, init_state, state):
    """Factory-reset the restarting nodes: every node-stacked leaf
    (leading axis ``n``) takes its round-0 value where ``restarted == 1``.
    Scalars (round counters) and unsigned-int leaves (PRNG keys — shape
    ``(2,)`` uint32, which could collide with ``n == 2``) are shared, not
    per-node, and pass through untouched."""
    def pick(i, s):
        if getattr(s, "ndim", 0) < 1 or s.shape[0] != n:
            return s
        if jnp.issubdtype(s.dtype, jnp.unsignedinteger):
            return s
        m = restarted.reshape((n,) + (1,) * (s.ndim - 1))
        return jnp.where(m > 0, i, s).astype(s.dtype)

    return jax.tree.map(pick, init_state, state)


# ------------------------------------------------------ payload corruption
def corrupt_view(fcfg: FaultConfig, conds, tree):
    """Mangle the node-stacked payload ``tree`` along the leading axis
    where ``conds.corrupt == 1``. Float leaves only (cluster ids and
    round counters ship uncorrupted — int payloads are checksummed in any
    real transport); per-leaf noise keys fold the leaf index into the
    round's ``fault_key``, so both drivers draw identical noise."""
    mask, key = conds.corrupt, conds.fault_key
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for i, leaf in enumerate(leaves):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            out.append(leaf)
            continue
        if fcfg.corrupt_mode == "noise":
            noise = jax.random.normal(jax.random.fold_in(key, i),
                                      leaf.shape, jnp.float32)
            bad = leaf + (fcfg.corrupt_scale * noise).astype(leaf.dtype)
        elif fcfg.corrupt_mode == "scale":
            bad = leaf * jnp.asarray(fcfg.corrupt_scale, leaf.dtype)
        else:  # "nan"
            bad = leaf * jnp.asarray(jnp.nan, leaf.dtype)
        m = mask.reshape((mask.shape[0],) + (1,) * (leaf.ndim - 1))
        out.append(jnp.where(m > 0, bad, leaf).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)


# ------------------------------------------------- robust-guard primitives
def node_finite(tree):
    """[n] float32: 1 where EVERY float leaf of the node is finite — the
    quarantine predicate (int leaves carry no poison)."""
    ok = None
    for leaf in jax.tree.leaves(tree):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        n = leaf.shape[0]
        fin = jnp.all(jnp.isfinite(
            jnp.asarray(leaf, jnp.float32).reshape(n, -1)), axis=1)
        fin = fin.astype(jnp.float32)
        ok = fin if ok is None else ok * fin
    if ok is None:
        raise ValueError("node_finite needs at least one float leaf")
    return ok


def node_norm(tree):
    """[n] float32: per-node global L2 over float leaves. NaN/inf for
    quarantined nodes — callers sanitize with :func:`node_finite`."""
    sq = None
    for leaf in jax.tree.leaves(tree):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        n = leaf.shape[0]
        s = jnp.sum(jnp.square(
            jnp.asarray(leaf, jnp.float32)).reshape(n, -1), axis=1)
        sq = s if sq is None else sq + s
    if sq is None:
        raise ValueError("node_norm needs at least one float leaf")
    return jnp.sqrt(sq)


def quarantined_count(guard, delivered):
    """float32 scalar: number of senders the guard quarantined this round
    (0 statically when the guard is off) — the obs-frame counter."""
    if guard is None or delivered is None:
        return jnp.zeros((), jnp.float32)
    return jnp.sum(1.0 - node_finite(delivered))
