"""repro.resil — node-fault injection, robust gossip, crash-safe runs.

netsim simulates unreliable *links*; this subsystem simulates unreliable
*nodes* and the machinery that survives them:

* :mod:`.faults` — :class:`FaultConfig` (crash/restart Markov chain,
  restart mode, payload corruption) on ``NetworkConfig.faults``; the
  carried :class:`FaultState`; :func:`advance`, the per-round entry point
  shared by the scan engine and the legacy loop; :func:`corrupt_view`
  (per-transmission payload mangling composed into
  ``netwire.sent_view``); and the robust-aggregation primitives behind
  ``bindings.gossip_mix(guard=...)`` — non-finite quarantine + norm
  clipping so one poisoned node degrades accuracy smoothly instead of
  NaN'ing every cluster core.

Crash-safe checkpoint/resume lives in :mod:`repro.checkpoint` (atomic
saves) + ``run_experiment(ckpt=...)`` / ``run_sweep(ckpt_dir=...)``
(segment-boundary snapshots, bit-for-bit resume, preemption-safe grids).

Usage — any algorithm, either driver::

    from repro.netsim import NetworkConfig
    from repro.resil import FaultConfig

    net = NetworkConfig.preset(
        "edge-v2",
        faults=FaultConfig(crash_rate=0.05, restart_rate=0.5,
                           corrupt_rate=0.05, corrupt_mode="nan"))
    res = run_experiment("facade", cfg, ds, rounds=100, net=net,
                         ckpt="results/run.ckpt.npz")

``faults=None`` and every zero-rate off-switch are bit-for-bit the
legacy path for all five algorithms on both drivers
(``tests/test_resil.py``).
"""
from .faults import (CORRUPT_MODES, RESTART_MODES,  # noqa: F401
                     FaultConfig, FaultState, advance, corrupt_view,
                     faults_of, guard_of, init_state, node_finite,
                     node_norm, quarantined_count, reset_nodes)
