"""Communication-volume + simulated-time accounting (paper Sec. V-E).

The DL rounds report ``round_bytes`` (and, under ``repro.netsim``, a
simulated ``round_s``); this module accumulates both and answers 'how many
GB / simulated hours to reach target accuracy X' — the paper's Fig. 7 and
its wall-clock companion.

Accuracy is only known on rounds where an eval actually ran. Eval-less
rounds carry the last known accuracy for plotting convenience, but target
queries (``bytes_to_target`` / ``seconds_to_target``) consult only
real-eval rounds — otherwise the backfilled accuracy would attribute the
target crossing to a round where nothing was measured.

Sentinel contract: a target the log never measurably crossed answers
``None`` from BOTH queries, on every path — an empty log, a log fed only
by :meth:`CommLog.record_bulk` (eval-less by construction), and a log
whose measured accuracies all fall short. Consumers must treat ``None``
as "not reached" (render it, skip it, or propagate it) — never compare,
subtract or divide it; :func:`benchmarks.common.fmt_to_target` /
:func:`benchmarks.common.to_target_ratio` are the shared None-safe
helpers for tables and speedup ratios.
"""
from __future__ import annotations

import numpy as np


class CommLog:
    def __init__(self):
        self.rounds: list[int] = []
        self.bytes: list[float] = []     # cumulative bytes sent
        self.seconds: list[float] = []   # cumulative simulated wall-clock
        self.acc: list[float] = []       # last-known accuracy (plot-friendly)
        self.evaled: list[bool] = []     # True where acc was really measured

    def record(self, rnd: int, round_bytes: float, acc: float | None = None,
               round_s: float = 0.0):
        total = (self.bytes[-1] if self.bytes else 0.0) + float(round_bytes)
        total_s = (self.seconds[-1] if self.seconds else 0.0) + float(round_s)
        self.rounds.append(int(rnd))
        self.bytes.append(total)
        self.seconds.append(total_s)
        self.evaled.append(acc is not None)
        if acc is not None:
            self.acc.append(float(acc))
        else:
            self.acc.append(self.acc[-1] if self.acc else 0.0)

    def record_bulk(self, rounds, round_bytes, round_s=None):
        """Append a whole engine segment of eval-less rounds at once.

        ``rounds`` / ``round_bytes`` / ``round_s`` are equal-length numpy
        arrays (per-round values, NOT cumulative) drained from the device in
        one host transfer — no per-round ``float()`` sync. Accuracy
        backfills the last measured value (``evaled=False`` throughout), so
        target queries never credit these rounds.

        Accumulation matches :meth:`record` bit for bit: a sequential
        float64 running sum seeded with the current total.
        """
        rounds = np.asarray(rounds)
        rb = np.asarray(round_bytes, np.float64)
        rs = (np.zeros_like(rb) if round_s is None
              else np.asarray(round_s, np.float64))
        if rounds.shape != rb.shape or rb.shape != rs.shape:
            raise ValueError("record_bulk arrays must have equal length")
        if rb.size == 0:
            return
        base_b = self.bytes[-1] if self.bytes else 0.0
        base_s = self.seconds[-1] if self.seconds else 0.0
        cum_b = np.cumsum(np.concatenate([[base_b], rb]))[1:]
        cum_s = np.cumsum(np.concatenate([[base_s], rs]))[1:]
        self.rounds.extend(int(r) for r in rounds)
        self.bytes.extend(cum_b.tolist())
        self.seconds.extend(cum_s.tolist())
        last_acc = self.acc[-1] if self.acc else 0.0
        self.acc.extend([last_acc] * rb.size)
        self.evaled.extend([False] * rb.size)

    def _first_crossing(self, target_acc: float) -> int | None:
        for i, (a, e) in enumerate(zip(self.acc, self.evaled)):
            if e and a >= target_acc:
                return i
        return None

    def bytes_to_target(self, target_acc: float) -> float | None:
        """Cumulative bytes at the first MEASURED accuracy >= target, else
        None (backfilled eval-less rounds never count)."""
        i = self._first_crossing(target_acc)
        return None if i is None else self.bytes[i]

    def seconds_to_target(self, target_acc: float) -> float | None:
        """Simulated seconds at the first measured accuracy >= target."""
        i = self._first_crossing(target_acc)
        return None if i is None else self.seconds[i]

    @property
    def total_gb(self) -> float:
        return (self.bytes[-1] / 1e9) if self.bytes else 0.0

    @property
    def total_hours(self) -> float:
        return (self.seconds[-1] / 3600.0) if self.seconds else 0.0


def gb(x: float) -> float:
    return x / 1e9
