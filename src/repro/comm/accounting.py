"""Communication-volume accounting (paper Sec. V-E).

The DL rounds report ``round_bytes``; this module accumulates them and
answers 'how many GB to reach target accuracy X' — the paper's Fig. 7."""
from __future__ import annotations

import numpy as np


class CommLog:
    def __init__(self):
        self.rounds: list[int] = []
        self.bytes: list[float] = []
        self.acc: list[float] = []

    def record(self, rnd: int, round_bytes: float, acc: float | None = None):
        total = (self.bytes[-1] if self.bytes else 0.0) + float(round_bytes)
        self.rounds.append(int(rnd))
        self.bytes.append(total)
        if acc is not None:
            self.acc.append(float(acc))
        else:
            self.acc.append(self.acc[-1] if self.acc else 0.0)

    def bytes_to_target(self, target_acc: float) -> float | None:
        """Cumulative bytes when accuracy first reaches target, else None."""
        for b, a in zip(self.bytes, self.acc):
            if a >= target_acc:
                return b
        return None

    @property
    def total_gb(self) -> float:
        return (self.bytes[-1] / 1e9) if self.bytes else 0.0


def gb(x: float) -> float:
    return x / 1e9
