from .accounting import CommLog, gb  # noqa: F401
