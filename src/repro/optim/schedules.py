"""Learning-rate schedules (callables over the step count)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda count: jnp.asarray(value, jnp.float32)


def cosine_warmup(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.0):
    def sched(count):
        c = count.astype(jnp.float32)
        warm = peak * jnp.minimum(1.0, c / max(warmup_steps, 1))
        prog = jnp.clip((c - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(c < warmup_steps, warm, cos)

    return sched
