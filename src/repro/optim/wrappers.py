"""Production optimizer wrappers: master-weight mixed precision, gradient
clipping, and gradient accumulation (microbatching).

``master_weights(opt)`` keeps an fp32 master copy of bf16 params in the
optimizer state — the standard mixed-precision recipe: bf16 forward/
backward, fp32 update, params re-cast from the master each step (no drift
from repeated bf16 rounding).

``clip_by_global_norm`` composes in front of any optimizer.

``accumulate_gradients(loss_fn, params, batches)`` folds a leading
microbatch axis with lax.scan — the memory knob for train_4k-sized global
batches that don't fit activations at once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .optimizers import Optimizer


def master_weights(opt: Optimizer) -> Optimizer:
    """Wrap ``opt`` with fp32 master params. update() returns *delta* to be
    applied via apply_updates as usual, but params are reconstructed from
    the master copy so bf16 rounding never accumulates."""

    def init(params):
        return {
            "inner": opt.init(params),
            "master": jax.tree.map(
                lambda p: p.astype(jnp.float32), params),
        }

    def update(grads, state, params):
        ups, inner = opt.update(grads, state["inner"], state["master"])
        master = jax.tree.map(lambda mp, u: mp - u.astype(jnp.float32),
                              state["master"], ups)
        # delta that takes current (bf16) params exactly onto cast(master)
        delta = jax.tree.map(
            lambda p, mp: (p.astype(jnp.float32) - mp), params, master)
        return delta, {"inner": inner, "master": master}

    return Optimizer(init, update)


def clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    def init(params):
        return opt.init(params)

    def update(grads, state, params):
        sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                 for g in jax.tree.leaves(grads))
        norm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
        grads = jax.tree.map(
            lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)
        return opt.update(grads, state, params)

    return Optimizer(init, update)


def accumulate_gradients(loss_fn, params, batches, unroll: int = 1):
    """Mean loss + grads over a leading microbatch axis.

    batches: pytree with leading [n_micro, ...]. Returns
    ((loss, aux_of_last_micro), grads) matching
    jax.value_and_grad(..., has_aux=True) conventions.
    """
    n = jax.tree.leaves(batches)[0].shape[0]
    gfn = jax.value_and_grad(loss_fn, has_aux=True)

    def body(carry, micro):
        loss_acc, g_acc = carry
        (loss, aux), g = gfn(params, micro)
        g_acc = jax.tree.map(
            lambda a, b: a + b.astype(jnp.float32) / n, g_acc, g)
        return (loss_acc + loss / n, g_acc), aux

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), aux = jax.lax.scan(body, (jnp.zeros(()), g0), batches,
                                      unroll=unroll)
    aux_last = jax.tree.map(lambda a: a[-1], aux)
    grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
    return (loss, aux_last), grads
