"""Minimal pytree optimizers (pure JAX; no optax in this container).

Each optimizer is a (init, update) pair:
    opt.init(params)                     -> opt_state
    opt.update(grads, state, params)     -> (updates, new_state)
apply_updates(params, updates)           -> params - updates already scaled.

``slot_dtype`` lets gigantic configs (grok-1) keep momentum in bf16 to fit
HBM (see DESIGN.md §7); defaults to fp32 slots.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p - u).astype(p.dtype), params, updates)


def _lr_at(lr, count):
    return lr(count) if callable(lr) else lr


def sgd(lr) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = _lr_at(lr, state["count"])
        ups = jax.tree.map(lambda g: step * g.astype(jnp.float32), grads)
        return ups, {"count": state["count"] + 1}

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, slot_dtype=jnp.float32) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(
                    lambda p: jnp.zeros(p.shape, slot_dtype), params)}

    def update(grads, state, params=None):
        m = jax.tree.map(
            lambda mm, g: (beta * mm.astype(jnp.float32)
                           + g.astype(jnp.float32)).astype(slot_dtype),
            state["m"], grads)
        step = _lr_at(lr, state["count"])
        ups = jax.tree.map(lambda mm: step * mm.astype(jnp.float32), m)
        return ups, {"count": state["count"] + 1, "m": m}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, slot_dtype=jnp.float32) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, slot_dtype)
        return {"count": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params)}

    def update(grads, state, params):
        c = state["count"] + 1
        m = jax.tree.map(
            lambda mm, g: (b1 * mm.astype(jnp.float32)
                           + (1 - b1) * g.astype(jnp.float32)
                           ).astype(slot_dtype), state["m"], grads)
        v = jax.tree.map(
            lambda vv, g: (b2 * vv.astype(jnp.float32)
                           + (1 - b2) * jnp.square(g.astype(jnp.float32))
                           ).astype(slot_dtype), state["v"], grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        step = _lr_at(lr, state["count"])

        def upd(mm, vv, p):
            mhat = mm.astype(jnp.float32) / bc1
            vhat = vv.astype(jnp.float32) / bc2
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return step * u

        ups = jax.tree.map(upd, m, v, params)
        return ups, {"count": c, "m": m, "v": v}

    return Optimizer(init, update)
