from .optimizers import (Optimizer, adamw, apply_updates, momentum,  # noqa: F401
                         sgd)
from .schedules import constant, cosine_warmup  # noqa: F401
from .wrappers import (accumulate_gradients, clip_by_global_norm,  # noqa: F401
                       master_weights)
