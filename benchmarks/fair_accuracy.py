"""Paper Fig. 5 (+ App. D): highest observed fair accuracy (Eq. 5,
lambda = 2/3) per algorithm and cluster configuration."""
from __future__ import annotations

from . import common


def run(quick: bool = True) -> dict:
    cluster_cfgs, rounds, spec, cfg = common.scaled(quick)
    rows, payload = [], {}
    for sizes in cluster_cfgs:
        ds = common.make_ds(spec, sizes, ("rot0", "rot180"))
        best = {}
        for algo in common.ALGOS:
            res = common.run_algo(algo, cfg, ds, rounds, quick)
            best[algo] = res.best_fair_acc()
            payload[f"{sizes}/{algo}"] = {
                "best_fair_acc": best[algo],
                "fair_acc_history": res.fair_acc}
        winner = max(best, key=best.get)
        rows.append([f"{sizes[0]}:{sizes[1]}"]
                    + [f"{best[a]:.3f}" for a in common.ALGOS] + [winner])
    print(common.table(["config", *common.ALGOS, "best"], rows))
    common.save("fair_accuracy", payload)
    return payload


if __name__ == "__main__":
    run()
