"""Telemetry overhead: the segment engine with a full ``MetricsFrame``
enabled vs the untelemetered baseline, in rounds/sec.

The obs design claim is that in-scan telemetry is (nearly) free: the
frame is computed inside the already-compiled segment scan and drained
in the segment's existing single bulk ``device_get``, so enabling it
adds device FLOPs (a few norms and reductions) and host bytes but ZERO
extra dispatches and ZERO extra host syncs. This benchmark measures the
claim where it is hardest — the 32-node micro GN-LeNet config
(``common.micro_config``) whose per-round compute is a few ms, i.e. the
regime where any fixed per-round overhead shows up largest.

Both variants run warm through one shared :class:`EngineCache`
(``ObsConfig`` forks the cache key, so each variant owns its compiled
segment programs; the warm pass compiles both before timing starts).
At micro scale a single rep is a few hundred ms, so independent
best-of timings swing far more than the effect being measured; the
overhead estimate is instead the MEDIAN of per-rep paired ratios
(base and obs timed back-to-back within each rep, so slow drift —
thermal, scheduler — cancels inside the pair).

Writes ``results/bench/BENCH_obs.json`` (via ``common.write_bench``, so
the payload carries its own manifest stamp). Acceptance:
``within_5pct`` — the obs-enabled engine must sustain >= 95% of the
baseline rounds/sec for both benchmarked algorithms (FACADE, the
heaviest round body, and EL, the primary baseline).
"""
from __future__ import annotations

import gc
import time

import numpy as np

from repro.core.cache import EngineCache
from repro.core.runner import run_experiment
from repro.obs import Obs, ObsConfig, read_jsonl

from . import common

N_NODES = 32
EVAL_EVERY = 20
LOCAL_STEPS = 1
BATCH = 2
REPS = 9
ALGOS = ("facade", "el")


def _kw(rounds):
    return dict(rounds=rounds, k=2, degree=4, local_steps=LOCAL_STEPS,
                batch_size=BATCH, lr=0.05, eval_every=EVAL_EVERY)


def _time_variants(algo, cfg, ds, rounds, cache):
    """Paired wall-clock reps for (baseline, obs-enabled): within each
    rep the two variants run back-to-back, so slow drift (thermal,
    scheduler) cancels inside the pair instead of biasing whichever ran
    last. Returns (best_base, best_obs, per-rep obs/base ratios). A
    fresh ``Obs`` per rep (no sink: we meter the frame + drain cost,
    not disk IO), so frames never accumulate across reps."""
    best_base = best_obs = float("inf")
    ratios = []
    for _ in range(REPS):
        gc.collect()
        t0 = time.perf_counter()
        run_experiment(algo, cfg, ds, cache=cache, seed=0, **_kw(rounds))
        t_base = time.perf_counter() - t0
        t0 = time.perf_counter()
        run_experiment(algo, cfg, ds, cache=cache, obs=Obs(ObsConfig()),
                       seed=0, **_kw(rounds))
        t_obs = time.perf_counter() - t0
        best_base = min(best_base, t_base)
        best_obs = min(best_obs, t_obs)
        ratios.append(t_obs / t_base)
    return best_base, best_obs, ratios


def run(quick: bool = True) -> dict:
    rounds = 240 if quick else 480
    cfg, ds = common.micro_config(N_NODES)
    cache = EngineCache()

    results, rows = {}, []
    for algo in ALGOS:
        # warm both variants: each ObsConfig forks the key, so each owns
        # its compiled segment programs — compiles stay out of the timing
        run_experiment(algo, cfg, ds, cache=cache, seed=0,
                       **_kw(EVAL_EVERY))
        run_experiment(algo, cfg, ds, cache=cache, obs=Obs(ObsConfig()),
                       seed=0, **_kw(EVAL_EVERY))
        compiled = cache.compile_count
        t_base, t_obs, ratios = _time_variants(algo, cfg, ds, rounds, cache)
        r = {"base_rounds_per_sec": rounds / t_base,
             "obs_rounds_per_sec": rounds / t_obs,
             "overhead_pct": (float(np.median(ratios)) - 1.0) * 100.0,
             "rep_ratios": [round(x, 4) for x in ratios],
             "timed_recompiles": cache.compile_count - compiled}
        results[algo] = r
        rows.append([algo, f"{r['base_rounds_per_sec']:.1f}",
                     f"{r['obs_rounds_per_sec']:.1f}",
                     f"{r['overhead_pct']:+.1f}%"])
    print(common.table(["algo", "base r/s", "obs r/s", "overhead"], rows))

    worst = max(r["overhead_pct"] for r in results.values())
    payload = {"n_nodes": N_NODES, "rounds": rounds,
               "eval_every": EVAL_EVERY, "local_steps": LOCAL_STEPS,
               "batch_size": BATCH, "reps": REPS,
               "obs_config": repr(ObsConfig()),
               "results": results, "worst_overhead_pct": worst,
               "within_5pct": worst <= 5.0,
               "cache": cache.stats()}
    out = common.write_bench("obs", payload)
    print(f"wrote {out} (worst overhead {worst:+.1f}%, "
          f"within_5pct={payload['within_5pct']})")
    return payload


def smoke() -> dict:
    """Tiny obs exercise for the dry-run matrix: attaching a full
    ``Obs`` must not perturb the trajectory, frames must be finite and
    round-complete, and the JSONL sink must round-trip."""
    import tempfile

    from repro.configs.facade_paper import lenet
    from repro.data.synthetic import SynthSpec

    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    ds = common.make_ds(spec, (3, 1), ("rot0", "rot180"))
    cfg = lenet(smoke=True).replace(n_classes=4)
    kw = dict(rounds=4, k=2, degree=2, local_steps=2, batch_size=4,
              lr=0.05, eval_every=2, seed=0)
    ref = run_experiment("facade", cfg, ds, **kw)
    with tempfile.TemporaryDirectory() as td:
        obs = Obs(ObsConfig(), jsonl=f"{td}/trace.jsonl", out_dir=td)
        got = run_experiment("facade", cfg, ds, obs=obs, **kw)
        table = obs.frames_table()
        recs = read_jsonl(f"{td}/trace.jsonl")
    ok = (ref.acc_per_cluster == got.acc_per_cluster
          and ref.comm.bytes == got.comm.bytes
          and table["round"].tolist() == [1, 2, 3, 4]
          and all(np.isfinite(table[f]).all() for f in table)
          and len(recs) == obs.sink.n_emitted
          and len(obs.manifests) == 1)
    return {"status": "ok" if ok else "fail",
            "frames": len(table["round"]),
            "jsonl_records": len(recs),
            "spans": sorted(obs.tracer.rollup()["spans"])}


def smoke_health() -> dict:
    """Health + report smoke for the dry-run matrix: an unguarded
    NaN-corruption run (``resil`` faults, ``robust=False``) must come
    back with a ``fail`` verdict and fired ``health.*`` events, a
    fault-free run must stay a quiet ``ok``, and the report CLI must
    render markdown from the faulted run's real manifest + JSONL."""
    import dataclasses
    import tempfile

    from repro.configs.facade_paper import lenet
    from repro.data.synthetic import SynthSpec
    from repro.netsim import NetworkConfig
    from repro.obs.report import build_report
    from repro.resil import FaultConfig

    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    ds = common.make_ds(spec, (3, 1), ("rot0", "rot180"))
    cfg = lenet(smoke=True).replace(n_classes=4)
    kw = dict(rounds=4, k=2, degree=2, local_steps=2, batch_size=4,
              lr=0.05, eval_every=2, seed=0)
    ideal = NetworkConfig.preset("ideal")
    storm = dataclasses.replace(ideal, faults=FaultConfig(
        corrupt_rate=0.6, corrupt_mode="nan", robust=False))
    with tempfile.TemporaryDirectory() as td:
        clean_obs = Obs(ObsConfig(), jsonl=f"{td}/clean.jsonl", out_dir=td)
        run_experiment("facade", cfg, ds, net=ideal, obs=clean_obs, **kw)
        clean_verdict = clean_obs.manifests[-1].health["verdict"]
        clean_events = [e for e in clean_obs.tracer.events
                       if e["name"].startswith("health.")]

        storm_obs = Obs(ObsConfig(), jsonl=f"{td}/storm.jsonl", out_dir=td)
        run_experiment("facade", cfg, ds, net=storm, obs=storm_obs, **kw)
        storm_verdict = storm_obs.manifests[-1].health["verdict"]
        storm_events = [e["name"] for e in storm_obs.tracer.events
                        if e["name"].startswith("health.")]
        _, md = build_report(f"{td}/manifest_facade-seed0.json")
        rendered = "## Health" in md and "## Fairness trajectory" in md
    ok = (clean_verdict == "ok" and not clean_events
          and storm_verdict == "fail" and storm_events and rendered)
    return {"status": "ok" if ok else "fail",
            "clean_verdict": clean_verdict,
            "storm_verdict": storm_verdict,
            "storm_events": sorted(set(storm_events)),
            "report_rendered": bool(rendered)}


if __name__ == "__main__":
    run()
