"""Churn resilience sweep (netsim): fair accuracy, traffic, and simulated
wall-clock for every algorithm under increasingly hostile network presets.

The paper's headline claim is communication efficiency on an ideal medium;
this table asks whether FACADE's advantage (and its cluster assignment)
survives message loss, node churn and stragglers — and converts bytes into
"simulated hours to finish" via the netsim latency/bandwidth cost model.

The grid rides ``repro.sweep.run_sweep`` over one shared ``EngineCache``:
presets over one algorithm are separate cache entries (netsim config is a
static key field), but every cell shares the SAME compiled evaluator
(keyed on model config + eval split, not on the network).
"""
from __future__ import annotations

import numpy as np

from repro.core.cache import EngineCache
from repro.netsim import NetworkConfig
from repro.sweep import SweepCell, run_sweep

from . import common

# netsim v1 presets + the v2 axes (bursty links / core-edge tiers / async
# stale gossip / all three at once)
PRESETS = ("ideal", "wan", "edge-churn", "hostile",
           "bursty-wan", "core-edge", "async-edge", "edge-v2")


def _settled_frac(res) -> float:
    """Fraction of NODES whose cluster choice stayed constant over the last
    quarter of the run (FACADE only; 1.0 for baselines)."""
    if not res.cluster_history:
        return 1.0
    tail = np.stack(
        [c for _, c in
         res.cluster_history[-max(2, len(res.cluster_history) // 4):]])
    return float((tail == tail[-1]).all(axis=0).mean())


def run(quick: bool = True) -> dict:
    cluster_cfgs, rounds, spec, cfg = common.scaled(quick)
    sizes = cluster_cfgs[1]                      # the imbalanced 6:2 config
    ds = common.make_ds(spec, sizes, ("rot0", "rot180"))
    algos = ("facade", "el") if quick else common.ALGOS
    rounds = min(rounds, 24) if quick else rounds

    kw = {k: v for k, v in common.std_kwargs(quick).items() if k != "seed"}
    cells = [SweepCell(name=f"{preset}/{algo}", algo=algo, cfg=cfg,
                       dataset=ds, rounds=rounds, net=preset,
                       kwargs=dict(kw))
             for preset in PRESETS for algo in algos]
    cache = EngineCache()
    sweep = run_sweep(cells, seeds=(0,), cache=cache)

    rows, payload = [], {}
    for cres in sweep.cells:
        res = cres.results[0]
        preset, algo = cres.cell.net, cres.cell.algo
        fair = res.best_fair_acc()
        settled = _settled_frac(res)
        rows.append([preset, algo, f"{fair:.3f}",
                     f"{res.comm.bytes[-1]/1e6:.1f} MB",
                     f"{res.comm.seconds[-1]/3600:.2f} h",
                     f"{settled:.2f}"])
        payload[cres.cell.name] = {
            "best_fair_acc": fair,
            "final_acc": res.final_acc,
            "total_bytes": res.comm.bytes[-1],
            "sim_seconds": res.comm.seconds[-1],
            "settled_frac": settled,
        }
    print(common.table(
        ["preset", "algo", "fair_acc", "traffic", "sim time", "settled"],
        rows))
    payload["sweep_cache"] = cache.stats()
    common.save("churn_resilience", payload)
    return payload


def smoke() -> dict:
    """Tiny netsim exercise for the dry-run matrix: 4 nodes, 2 rounds of
    FACADE under edge-churn. Cheap enough to run on every invocation."""
    from repro.configs.facade_paper import lenet
    from repro.data.synthetic import SynthSpec

    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    ds = common.make_ds(spec, (3, 1), ("rot0", "rot180"))
    cfg = lenet(smoke=True).replace(n_classes=4)
    res = common.run_algo("facade", cfg, ds, 2, True, local_steps=2,
                          batch_size=4, eval_every=1,
                          net=NetworkConfig.preset("edge-churn"))
    # the fixed seeds guarantee at least one active round, so the simulated
    # clock must actually advance — a 0 here means the timing path broke
    ok = (len(res.comm.seconds) == 2
          and np.isfinite(res.comm.bytes[-1])
          and 0 < res.comm.seconds[-1] < np.inf)
    return {"status": "ok" if ok else "fail",
            "preset": "edge-churn",
            "sim_seconds": float(res.comm.seconds[-1]),
            "total_bytes": float(res.comm.bytes[-1]),
            # SLO surface: simulated wall-clock + time-to-accuracy, the
            # same CommLog quantities the roofline tables report
            "sim_hours": float(res.comm.total_hours),
            "seconds_to_target": res.comm.seconds_to_target(0.1)}


def smoke_v2() -> dict:
    """netsim-v2 exercise for the dry-run matrix: 2 rounds of EL under
    ``edge-v2`` (bursty + core/edge tiers + async stale gossip, all in one
    preset) plus a channel-statistics sanity check — cheap enough to run
    on every invocation so the v2 paths can't rot."""
    import dataclasses

    from repro import netsim
    from repro.configs.facade_paper import lenet
    from repro.data.synthetic import SynthSpec

    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    ds = common.make_ds(spec, (3, 1), ("rot0", "rot180"))
    cfg = lenet(smoke=True).replace(n_classes=4)
    net = NetworkConfig.preset("edge-v2")
    res = common.run_algo("el", cfg, ds, 2, True, local_steps=2,
                          batch_size=4, eval_every=1, net=net)
    # async staleness must shed traffic vs the same preset run sync
    sync = dataclasses.replace(net, async_gossip=False)
    res_sync = common.run_algo("el", cfg, ds, 2, True, local_steps=2,
                               batch_size=4, eval_every=1, net=sync)
    stats = netsim.channel_stats(net, n=6, rounds=200)
    ok = (len(res.comm.seconds) == 2
          and np.isfinite(res.comm.bytes[-1])
          and 0 <= res.comm.seconds[-1] < np.inf
          and res.comm.bytes[-1] <= res_sync.comm.bytes[-1]
          and stats["symmetric"] and stats["binary"]
          and abs(stats["bad_rate"] - net.burst.stationary_bad()) < 0.15)
    return {"status": "ok" if ok else "fail",
            "preset": "edge-v2",
            "sim_seconds": float(res.comm.seconds[-1]),
            "total_bytes": float(res.comm.bytes[-1]),
            "sim_hours": float(res.comm.total_hours),
            "seconds_to_target": res.comm.seconds_to_target(0.1),
            "sync_bytes": float(res_sync.comm.bytes[-1]),
            "channel_bad_rate": stats["bad_rate"],
            "channel_mean_burst_len": stats["mean_burst_len"]}


if __name__ == "__main__":
    run()
