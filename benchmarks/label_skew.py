"""Paper Appendix G: label heterogeneity — cluster 0 holds one label subset
(the paper's 'vehicles'), cluster 1 the rest ('animals'). FACADE should
stay at least as good as EL/DAC on the minority cluster."""
from __future__ import annotations

from . import common


def run(quick: bool = True) -> dict:
    cluster_cfgs, rounds, spec, cfg = common.scaled(quick)
    n_cls = spec.n_classes
    split = [list(range(n_cls // 2)), list(range(n_cls // 2, n_cls))]
    rows, payload = [], {}
    for sizes in cluster_cfgs:
        ds = common.make_ds(spec, sizes, ("rot0", "rot0"),
                            label_split=split)
        for algo in common.ALGOS:
            res = common.run_algo(algo, cfg, ds, rounds, quick)
            rows.append([f"{sizes[0]}:{sizes[1]}", algo,
                         f"{res.final_acc[0]:.3f}",
                         f"{res.final_acc[-1]:.3f}",
                         f"{res.best_fair_acc():.3f}"])
            payload[f"{sizes}/{algo}"] = {
                "acc_majority": res.final_acc[0],
                "acc_minority": res.final_acc[-1],
                "fair_acc": res.best_fair_acc()}
    print(common.table(["config", "algo", "acc_maj", "acc_min",
                        "fair_acc"], rows))
    common.save("label_skew", payload)
    return payload


if __name__ == "__main__":
    run()
