"""Fault-tolerance benchmark (repro.resil): the robust-aggregation payoff
table and the crash-churn byte/accuracy ledger, written to
``results/bench/BENCH_resil.json``.

Headline table: FACADE under on-device NaN corruption (a fraction of
senders publish poisoned models each round, ``corrupt_mode="nan"``) at
increasing rates, with the robust gossip guard (non-finite quarantine +
norm clipping, shared by every algorithm's ``gossip_mix``) switched on vs
off. The contract the resilience tests pin qualitatively, measured
quantitatively here: with the guard, fair accuracy stays near the
fault-free run even at 5-10% corruption; without it, one NaN sender
poisons the whole mixture within a round or two and the run collapses
(non-finite parameters or a >20% accuracy drop).

Second table: crash churn (``crash_rate`` Markov chain, rejoin-stale
restarts). Crashed nodes publish nothing and never gate the simulated
round clock, so total traffic drops roughly with the stationary downtime
while accuracy degrades gracefully — the byte-honesty contract.

The module also hosts the resilience smokes for the dry-run matrix:
:func:`smoke` (fault off-switch bit-parity + a guarded NaN-storm run) and
:func:`smoke_resume` (save -> kill mid-run -> resume bit-parity via the
crash-safe checkpoint path).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.netsim import NetworkConfig
from repro.resil import FaultConfig

from . import common

# corruption rates for the headline table; 0.0 is the fault-free anchor
RATES = (0.05, 0.1)


def _fair(res) -> float:
    return res.best_fair_acc()


def _finite(res) -> bool:
    return bool(np.all(np.isfinite(np.asarray(res.final_acc, float)))
                and np.isfinite(_fair(res)))


def run(quick: bool = True) -> dict:
    cluster_cfgs, rounds, spec, cfg = common.scaled(quick)
    sizes = cluster_cfgs[1]                      # the imbalanced 6:2 config
    ds = common.make_ds(spec, sizes, ("rot0", "rot180"))
    rounds = min(rounds, 32) if quick else rounds
    base = NetworkConfig.preset("ideal")         # isolate faults from churn

    def go(fcfg):
        net = dataclasses.replace(base, faults=fcfg)
        return common.run_algo("facade", cfg, ds, rounds, quick, net=net)

    clean = go(None)
    rows = [["0.00", "-", f"{_fair(clean):.3f}",
             f"{min(clean.final_acc):.3f}", "yes"]]
    payload = {"clean": {"fair_acc": _fair(clean),
                         "worst_cluster": float(min(clean.final_acc)),
                         "total_bytes": clean.comm.bytes[-1]}}

    # --- NaN corruption x robust guard on/off -----------------------------
    collapse_ok = within_ok = True
    for rate in RATES:
        for robust in (True, False):
            res = go(FaultConfig(corrupt_rate=rate, corrupt_mode="nan",
                                 robust=robust))
            fair, finite = _fair(res), _finite(res)
            rows.append([f"{rate:.2f}", "on" if robust else "off",
                         f"{fair:.3f}" if finite else "nan",
                         f"{min(res.final_acc):.3f}" if finite else "nan",
                         "yes" if finite else "NO"])
            payload[f"corrupt{rate}-{'robust' if robust else 'unguarded'}"] = {
                "fair_acc": fair, "finite": finite,
                "worst_cluster": float(min(res.final_acc)),
                "total_bytes": res.comm.bytes[-1]}
            if robust:
                # guard keeps the run within a few points of fault-free
                within_ok &= finite and fair >= _fair(clean) - 0.05
            else:
                # unguarded: non-finite params or a >20% fair-acc drop
                collapse_ok &= ((not finite)
                                or fair <= _fair(clean) - 0.20)
    print(common.table(
        ["corrupt", "guard", "fair_acc", "worst_cluster", "finite"], rows))

    # --- crash churn: bytes drop with downtime, accuracy degrades
    # --- gracefully (crashed senders cost 0 bytes, never gate the clock)
    crash_rows = [["0.00", f"{_fair(clean):.3f}",
                   f"{clean.comm.bytes[-1]/1e6:.1f} MB"]]
    crash_ok = True
    for crate in ((0.25,) if quick else (0.1, 0.25)):
        res = go(FaultConfig(crash_rate=crate, restart_rate=0.5,
                             restart_mode="rejoin-stale"))
        crash_rows.append([f"{crate:.2f}", f"{_fair(res):.3f}",
                           f"{res.comm.bytes[-1]/1e6:.1f} MB"])
        payload[f"crash{crate}"] = {
            "fair_acc": _fair(res), "finite": _finite(res),
            "total_bytes": res.comm.bytes[-1]}
        crash_ok &= (_finite(res)
                     and res.comm.bytes[-1] < clean.comm.bytes[-1])
    print("\ncrash churn (rejoin-stale restarts):")
    print(common.table(["crash_rate", "fair_acc", "traffic"], crash_rows))

    payload["headline"] = {"robust_within_5pts": within_ok,
                           "unguarded_collapsed": collapse_ok,
                           "crash_bytes_drop": crash_ok}
    verdict = "PASS" if (within_ok and collapse_ok and crash_ok) else "FAIL"
    print(f"\nresilience contract: robust-within-5pts={within_ok} "
          f"unguarded-collapsed={collapse_ok} crash-bytes-drop={crash_ok} "
          f"-> {verdict}")
    common.write_bench("resil", payload)
    return payload


def _tiny():
    from repro.configs.facade_paper import lenet
    from repro.data.synthetic import SynthSpec

    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    ds = common.make_ds(spec, (3, 1), ("rot0", "rot180"))
    cfg = lenet(smoke=True).replace(n_classes=4)
    return cfg, ds


def smoke() -> dict:
    """Resilience smoke for the dry-run matrix: (a) a zero-rate
    ``FaultConfig`` is bit-for-bit the no-faults run (the off-switch
    contract), (b) a guarded crash+NaN storm stays finite and sheds bytes
    (crashed senders cost 0). Cheap enough to run on every invocation."""
    cfg, ds = _tiny()
    net = NetworkConfig.preset("edge-churn")
    kw = dict(local_steps=2, batch_size=4, eval_every=1)
    plain = common.run_algo("facade", cfg, ds, 2, True, net=net, **kw)
    off = common.run_algo(
        "facade", cfg, ds, 2, True,
        net=dataclasses.replace(net, faults=FaultConfig()), **kw)
    parity = (list(plain.final_acc) == list(off.final_acc)
              and np.array_equal(plain.comm.bytes, off.comm.bytes)
              and np.array_equal(plain.comm.seconds, off.comm.seconds))
    # the storm runs on "ideal" so the byte comparison has signal — on
    # edge-churn a 2-round window can legitimately deliver 0 edges
    ideal = NetworkConfig.preset("ideal")
    clean = common.run_algo("facade", cfg, ds, 2, True, net=ideal, **kw)
    storm = common.run_algo(
        "facade", cfg, ds, 2, True,
        net=dataclasses.replace(ideal, faults=FaultConfig(
            crash_rate=0.5, restart_rate=0.5,
            corrupt_rate=0.5, corrupt_mode="nan")), **kw)
    finite = bool(np.all(np.isfinite(np.asarray(storm.final_acc, float))))
    shed = 0 < storm.comm.bytes[-1] < clean.comm.bytes[-1]
    ok = parity and finite and shed
    return {"status": "ok" if ok else "fail",
            "off_switch_parity": bool(parity),
            "storm_finite": finite,
            "storm_bytes": float(storm.comm.bytes[-1]),
            "plain_bytes": float(clean.comm.bytes[-1])}


def smoke_resume() -> dict:
    """Checkpoint/resume smoke for the dry-run matrix: run with
    ``ckpt=``, kill the driver after the first fused segment, resume from
    the on-disk checkpoint, and demand bit-parity with an uninterrupted
    reference — metrics AND the final saved carry, leaf for leaf."""
    import tempfile

    import jax

    from repro import checkpoint
    from repro.core import engine as engine_mod
    from repro.core.runner import run_experiment

    cfg, ds = _tiny()
    net = dataclasses.replace(
        NetworkConfig.preset("edge-churn"),
        faults=FaultConfig(crash_rate=0.3, corrupt_rate=0.3))
    kw = dict(rounds=4, k=2, degree=2, local_steps=2, batch_size=4,
              lr=0.05, eval_every=2, seed=0, net=net)
    tmp = tempfile.mkdtemp(prefix="resil-smoke-")
    ref_ck, ck = f"{tmp}/ref.npz", f"{tmp}/killed.npz"
    ref = run_experiment("facade", cfg, ds, ckpt=ref_ck, **kw)

    class _Killed(Exception):
        pass

    orig = engine_mod.SegmentEngine.run_segment
    calls = {"n": 0}

    def killer(self, *a, **k):
        if calls["n"] >= 1:
            raise _Killed()
        calls["n"] += 1
        return orig(self, *a, **k)

    engine_mod.SegmentEngine.run_segment = killer
    try:
        run_experiment("facade", cfg, ds, ckpt=ck, **kw)
        killed = False                      # killer never fired: bad plan
    except _Killed:
        killed = True
    finally:
        engine_mod.SegmentEngine.run_segment = orig
    got = run_experiment("facade", cfg, ds, ckpt=ck, **kw)

    metrics = (list(ref.final_acc) == list(got.final_acc)
               and np.array_equal(ref.comm.bytes, got.comm.bytes)
               and np.array_equal(ref.comm.seconds, got.comm.seconds)
               and ref.fair_acc == got.fair_acc)
    pr, _ = checkpoint.load(ref_ck)
    pg, _ = checkpoint.load(ck)
    carry = all(np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(pr["carry"]),
                                jax.tree.leaves(pg["carry"])))
    ok = killed and metrics and carry
    return {"status": "ok" if ok else "fail",
            "killed_mid_run": killed,
            "metrics_parity": bool(metrics),
            "carry_parity": bool(carry),
            "fair_acc": float(got.best_fair_acc())}


if __name__ == "__main__":
    run()
