"""Warm start: the persistent XLA compile cache across PROCESSES.

Every sweep process historically started cold — the in-process
``EngineCache`` shares compiled programs across runs, but the XLA
executables behind them died with the process, so a rerun grid, a CI
shard or a preemption-resumed sweep paid the full compile bill again.
``EngineCache(persist_dir=...)`` wires ``jax_compilation_cache_dir``
through, so serialized executables survive on disk.

This benchmark launches the SAME tiny run twice in two fresh child
processes sharing one persist dir: the first (cold) populates the disk
cache while compiling; the second (warm) deserializes executables and
reaches its first segment dispatch measurably faster. Each child reports
``first_dispatch_s`` (cache-entry build + first ``run_segment``, i.e.
time to first useful device work) and its tracer ``compile`` span total.

Writes ``results/bench/BENCH_warmstart.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

from . import common

N_NODES = 8
ROUNDS = 8
EVAL_EVERY = 8


def _child_payload(persist_dir: str) -> dict:
    """One fresh-process measurement: build an EngineCache over
    ``persist_dir`` and time cache-entry build + the first segment."""
    import jax  # noqa: F401  (imported before timing starts, like a real run)

    from repro.core.cache import EngineCache
    from repro.core.runner import run_experiment
    from repro.obs import Obs

    cfg, ds = common.micro_config(N_NODES)
    cache = EngineCache(persist_dir=persist_dir)
    obs = Obs(config=None)           # spans only: no device-side frames
    t0 = time.perf_counter()
    run_experiment("facade", cfg, ds, rounds=ROUNDS, k=2, degree=2,
                   local_steps=1, batch_size=2, lr=0.05,
                   eval_every=EVAL_EVERY, seed=0, cache=cache, obs=obs)
    first = time.perf_counter() - t0
    roll = obs.tracer.rollup()["spans"]
    return {"first_dispatch_s": first,
            "compile_s": roll.get("compile", {}).get("total_s", 0.0),
            "eval_s": roll.get("eval", {}).get("total_s", 0.0)}


def _spawn(persist_dir: str) -> dict:
    """Run ``_child_payload`` in a FRESH interpreter (the whole point:
    in-process jit caches don't survive it; only the persist dir does)."""
    env = dict(os.environ)
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.warm_start", "--child",
         persist_dir],
        cwd=str(pathlib.Path(__file__).resolve().parent.parent),
        env=env, capture_output=True, text=True, check=False)
    if proc.returncode != 0:
        raise RuntimeError(
            f"warm_start child failed:\n{proc.stdout}\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(quick: bool = True) -> dict:
    with tempfile.TemporaryDirectory(prefix="repro-xla-cache-") as td:
        cold = _spawn(td)
        n_files = len(list(pathlib.Path(td).iterdir()))
        warm = _spawn(td)
    speedup = cold["first_dispatch_s"] / max(warm["first_dispatch_s"], 1e-9)
    rows = [["cold", f"{cold['first_dispatch_s']:.2f}",
             f"{cold['compile_s']:.2f}"],
            ["warm", f"{warm['first_dispatch_s']:.2f}",
             f"{warm['compile_s']:.2f}"]]
    print(common.table(["process", "first_dispatch_s", "compile_s"], rows))
    payload = {"n_nodes": N_NODES, "rounds": ROUNDS,
               "cold": cold, "warm": warm,
               "speedup_first_dispatch": speedup,
               "persisted_files": n_files,
               "warm_faster": warm["first_dispatch_s"]
               < cold["first_dispatch_s"]}
    out = common.write_bench("warmstart", payload)
    print(f"wrote {out} (second process reaches first dispatch "
          f"{speedup:.2f}x faster)")
    return payload


def smoke() -> dict:
    """In-process persist-dir exercise for the dry-run matrix: a run over
    ``EngineCache(persist_dir=...)`` must populate the disk cache and stay
    bit-for-bit a plain run."""
    import numpy as np

    from repro.core.cache import EngineCache, detach_persist_dir
    from repro.core.runner import run_experiment

    cfg, ds = common.micro_config(4)
    kw = dict(rounds=4, k=2, degree=2, local_steps=1, batch_size=2,
              lr=0.05, eval_every=2, seed=0)
    ref = run_experiment("facade", cfg, ds, **kw)
    try:
        with tempfile.TemporaryDirectory(prefix="repro-xla-smoke-") as td:
            cache = EngineCache(persist_dir=td)
            got = run_experiment("facade", cfg, ds, cache=cache, **kw)
            n_files = len(list(pathlib.Path(td).iterdir()))
    finally:
        # the persist dir is process-global jax config; detach before the
        # tempdir disappears so later compiles don't write into the void
        detach_persist_dir()
    ok = (ref.acc_per_cluster == got.acc_per_cluster
          and ref.comm.bytes == got.comm.bytes and n_files > 0
          and np.isfinite(got.comm.bytes[-1]))
    return {"status": "ok" if ok else "fail", "persisted_files": n_files,
            "cache_stats": cache.stats()}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--child", metavar="PERSIST_DIR", default=None)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    if args.child is not None:
        print(json.dumps(_child_payload(args.child)))
        return 0
    run(quick=not args.full)
    return 0


if __name__ == "__main__":
    sys.exit(main())
