"""Paper Fig. 7: communication volume (GB) to reach a target network-wide
accuracy, per algorithm and cluster configuration. DEPRL excluded as in the
paper (Sec. V-E)."""
from __future__ import annotations

from . import common


def run(quick: bool = True) -> dict:
    cluster_cfgs, rounds, spec, cfg = common.scaled(quick)
    target = 0.80 if quick else 0.63
    algos = [a for a in common.ALGOS if a != "deprl"]
    rows, payload = [], {}
    for sizes in cluster_cfgs:
        ds = common.make_ds(spec, sizes, ("rot0", "rot180"))
        per = {}
        for algo in algos:
            res = common.run_algo(algo, cfg, ds, rounds, quick,
                                  target_acc=target)
            b = res.comm.bytes_to_target(target)
            per[algo] = b
            payload[f"{sizes}/{algo}"] = {
                "bytes_to_target": b, "target": target,
                "rounds_run": res.comm.rounds[-1] if res.comm.rounds else 0}
        base = per.get("el")
        rows.append([f"{sizes[0]}:{sizes[1]}"] + [
            ("n/r" if per[a] is None else f"{per[a]/1e6:.1f} MB") for a in algos
        ] + [("n/a" if (per["facade"] is None or not base) else
              f"{(1 - per['facade']/base)*100:+.1f}% vs EL")])
    print(f"target accuracy: {target}")
    print(common.table(["config", *algos, "facade saving"], rows))
    common.save("comm_cost", payload)
    return payload


if __name__ == "__main__":
    run()
