"""Paper Fig. 3 / Tables II-IV: per-cluster test accuracy for varying
cluster configurations, FACADE vs EL/DAC/DEPRL.

Validates: FACADE >= baselines on the majority cluster and strictly better
on the minority cluster as imbalance grows.
"""
from __future__ import annotations

from . import common


def run(quick: bool = True) -> dict:
    cluster_cfgs, rounds, spec, cfg = common.scaled(quick)
    rows, payload = [], {}
    for sizes in cluster_cfgs:
        ds = common.make_ds(spec, sizes, ("rot0", "rot180"))
        for algo in common.ALGOS:
            res = common.run_algo(algo, cfg, ds, rounds, quick)
            maj, mino = res.final_acc[0], res.final_acc[-1]
            rows.append([f"{sizes[0]}:{sizes[1]}", algo,
                         f"{maj:.3f}", f"{mino:.3f}",
                         f"{res.best_fair_acc():.3f}"])
            payload[f"{sizes}/{algo}"] = {
                "acc_majority": maj, "acc_minority": mino,
                "fair_acc": res.best_fair_acc(),
                "acc_history": res.acc_per_cluster}
    print(common.table(
        ["config", "algo", "acc_maj", "acc_min", "fair_acc"], rows))
    common.save("percluster_accuracy", payload)
    return payload


if __name__ == "__main__":
    run()
