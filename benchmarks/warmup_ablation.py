"""Appendix F ablation: shared-head warmup rounds vs settlement quality.

The paper mitigates non-settlement (a head never selected, all clusters on
one head) by starting with a few EL-style rounds where all heads share
weights. This benchmark sweeps warmup_rounds over seeds and reports the
settlement rate and minority accuracy with/without warmup.
"""
from __future__ import annotations

import numpy as np

from . import common
from .settlement import settle_round


def run(quick: bool = True) -> dict:
    _, rounds, spec, cfg = common.scaled(quick)
    sizes = (5, 2, 1) if quick else (20, 10, 2)
    seeds = (0, 1, 2) if quick else tuple(range(8))
    rows, payload = [], {}
    for warmup in (0, 5):
        settled, fair, minority = [], [], []
        for seed in seeds:
            ds = common.make_ds(spec, sizes, ("rot0", "rot90", "rot180"))
            res = common.run_algo("facade", cfg, ds, rounds, quick, k=3,
                                  warmup_rounds=warmup, seed=seed)
            sr = settle_round(res.cluster_history, ds.node_cluster, ds.k)
            settled.append(sr is not None)
            fair.append(res.best_fair_acc())
            minority.append(res.final_acc[-1])
        rows.append([warmup, f"{np.mean(settled):.2f}",
                     f"{np.mean(fair):.3f}", f"{np.mean(minority):.3f}"])
        payload[f"warmup={warmup}"] = {
            "settle_rate": float(np.mean(settled)),
            "fair_acc": float(np.mean(fair)),
            "acc_minority": float(np.mean(minority)),
            "n_seeds": len(seeds)}
    print(common.table(
        ["warmup_rounds", "settle rate", "fair_acc", "acc_minority"], rows))
    common.save("warmup_ablation", payload)
    return payload


if __name__ == "__main__":
    run()
