"""Paper Fig. 9 / Sec. V-G / App. F: head-selection (settlement) dynamics.
Records which head each node selects per round; reports the round by which
each cluster settles (all its nodes pick the same head) and whether the
assignment is a bijection cluster->head."""
from __future__ import annotations

import numpy as np

from . import common


def settle_round(history, node_cluster, k_clusters):
    """First round after which each cluster's nodes all agree, forever."""
    node_cluster = np.asarray(node_cluster)
    agreed_from = None
    for rnd, cid in history:
        cid = np.asarray(cid)
        ok = all(len(set(cid[node_cluster == c].tolist())) == 1
                 for c in range(k_clusters))
        if ok and agreed_from is None:
            agreed_from = rnd
        elif not ok:
            agreed_from = None
    return agreed_from


def run(quick: bool = True) -> dict:
    _, rounds, spec, cfg = common.scaled(quick)
    sizes = (5, 2, 1) if quick else (20, 10, 2)
    ds = common.make_ds(spec, sizes, ("rot0", "rot90", "rot180"))
    res = common.run_algo("facade", cfg, ds, rounds, quick, k=3)

    sr = settle_round(res.cluster_history, ds.node_cluster, ds.k)
    final_cid = np.asarray(res.cluster_history[-1][1])
    heads = [sorted(set(final_cid[np.asarray(ds.node_cluster) == c].tolist()))
             for c in range(ds.k)]
    distinct = len({h[0] for h in heads if len(h) == 1}) == ds.k

    rows = [[c, f"{sizes[c]} nodes", str(heads[c])] for c in range(ds.k)]
    print(common.table(["cluster", "size", "selected head(s)"], rows))
    print(f"settled at round: {sr}   bijective assignment: {distinct}")
    payload = {"settle_round": sr, "bijective": bool(distinct),
               "history": [(int(r), np.asarray(c).tolist())
                           for r, c in res.cluster_history]}
    common.save("settlement", payload)
    return payload


if __name__ == "__main__":
    run()
