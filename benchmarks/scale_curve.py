"""Scale curve: 1024-node FACADE on a multi-device ``node`` mesh.

The sharded segment engine (``run_experiment(mesh=...)``) lays the
``EngineCarry`` node axis out across devices and turns gossip mixing
into a ``shard_map`` row-block matmul (:mod:`repro.core.meshctx`). This
benchmark proves the headline claim: a 1024-node FACADE run on an
8-device mesh sustains near-linear *per-device-time* throughput versus
a single-device run at the matched per-device node count (128).

Methodology (single-core CPU with forced host devices): the 8 "devices"
from ``--xla_force_host_platform_device_count=8`` timeshare one physical
core, so wall time approximates *aggregate device busy time*. Throughput
is therefore measured in node-rounds per wall-second (== node-rounds per
device-second on this box); perfect linear scaling makes the 1024-node/
8-device figure equal the 128-node/1-device figure, and
``linear_frac = thr_sharded / thr_single`` is the fraction of linear
retained after the O(n^2) mixing term and shard_map collectives are
paid. The acceptance bar is ``linear_frac >= 0.7`` (within 30% of
linear). Each child process compiles once (cold run) and times a second
run over the same in-process ``EngineCache`` so the curve measures
steady-state dispatch, not XLA compiles. ``local_steps``/``batch_size``
are sized so local training (embarrassingly node-parallel) dominates the
per-round collective tax, as it does in any realistic FACADE config —
with near-zero local work the benchmark would only measure the host
platform's emulated-interconnect memcpys.

Writes ``results/bench/BENCH_scale.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

from . import common

LOCAL_STEPS = 48
BATCH_SIZE = 16
LINEAR_BAR = 0.7


def _child_payload(spec: dict) -> dict:
    """One measurement in a fresh process whose device count was forced
    by the parent: cold run (compile) + timed warm run."""
    import jax

    from repro.core.runner import run_experiment

    n = int(spec["n_nodes"])
    rounds = int(spec["rounds"])
    mesh = (len(jax.devices()),) if spec["sharded"] else None
    cfg, ds = common.micro_config(n)
    cache = common.engine_cache()
    kw = dict(rounds=rounds, k=2, degree=2, local_steps=LOCAL_STEPS,
              batch_size=BATCH_SIZE, lr=0.05, eval_every=rounds, seed=0,
              cache=cache, mesh=mesh)
    run_experiment("facade", cfg, ds, **kw)          # cold: pays compiles
    t0 = time.perf_counter()
    res = run_experiment("facade", cfg, ds, **kw)    # warm: steady state
    wall = time.perf_counter() - t0
    return {"n_devices": len(jax.devices()), "n_nodes": n,
            "rounds": rounds, "wall_s": wall,
            "node_rounds_per_s": n * rounds / wall,
            "final_acc": [float(a) for a in res.acc_per_cluster[-1][1]],
            "total_bytes": float(res.comm.bytes[-1])}


def _spawn(n_devices: int, spec: dict) -> dict:
    """Run ``_child_payload`` in a fresh interpreter with ``n_devices``
    forced host devices — the flag must be set BEFORE jax is imported,
    which only a new process guarantees."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices} "
        + env.get("XLA_FLAGS", "")).strip()
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    env.pop("REPRO_XLA_CACHE_DIR", None)  # time real compiles per child
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.scale_curve", "--child",
         json.dumps(spec)],
        cwd=str(pathlib.Path(__file__).resolve().parent.parent),
        env=env, capture_output=True, text=True, check=False)
    if proc.returncode != 0:
        raise RuntimeError(
            f"scale_curve child failed:\n{proc.stdout}\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(quick: bool = True) -> dict:
    rounds = 2 if quick else 8
    n_dev = 8
    n_big = 1024
    n_small = n_big // n_dev
    single = _spawn(1, {"n_nodes": n_small, "rounds": rounds,
                        "sharded": False})
    sharded = _spawn(n_dev, {"n_nodes": n_big, "rounds": rounds,
                             "sharded": True})
    linear_frac = (sharded["node_rounds_per_s"]
                   / single["node_rounds_per_s"])
    rows = [[f"{r['n_nodes']}@{r['n_devices']}dev", f"{r['wall_s']:.2f}",
             f"{r['node_rounds_per_s']:.1f}"]
            for r in (single, sharded)]
    print(common.table(["config", "warm_wall_s", "node_rounds/s"], rows))
    payload = {
        "single": single, "sharded": sharded,
        "linear_frac": linear_frac, "linear_bar": LINEAR_BAR,
        "within_bar": linear_frac >= LINEAR_BAR,
        "methodology": (
            "forced host devices timeshare one core, so wall time ~ "
            "aggregate device time; node-rounds/wall-s is per-device-time "
            "throughput and linear scaling keeps it flat between "
            f"{n_small}@1dev and {n_big}@{n_dev}dev"),
    }
    out = common.write_bench("scale", payload)
    print(f"wrote {out} ({n_big}-node sharded run retains "
          f"{linear_frac:.2f} of linear per-device throughput; "
          f"bar {LINEAR_BAR})")
    if not payload["within_bar"]:
        raise AssertionError(
            f"sharded engine fell below the linear-scaling bar: "
            f"{linear_frac:.2f} < {LINEAR_BAR}")
    return payload


ACC_TOL = 0.1   # multi-device accuracy tolerance (see _parity_child)


def _parity_child(spec: dict) -> dict:
    """Smoke half that needs >1 device: same tiny FACADE run with
    ``mesh=(n_dev,)`` and ``mesh=None`` in ONE process, so the sharded
    engine's trajectory can be checked against the unsharded one without
    cross-process float noise. Comm byte counts must match EXACTLY (the
    PRNG stream, topology draws and active masks are layout-independent);
    accuracies get a tolerance — per-node convolutions accumulate in a
    different order inside the shard_map blocks, and at smoke scale a
    last-bit float difference can flip an argmin head selection."""
    import jax
    import numpy as np

    from repro.core.runner import run_experiment

    n = int(spec["n_nodes"])
    cfg, ds = common.micro_config(n)
    kw = dict(rounds=4, k=2, degree=2, local_steps=1, batch_size=2,
              lr=0.05, eval_every=2, seed=0)
    ref = run_experiment("facade", cfg, ds, **kw)
    got = run_experiment("facade", cfg, ds,
                         mesh=(len(jax.devices()),), **kw)
    ra = np.array([a for _, accs in ref.acc_per_cluster for a in accs])
    ga = np.array([a for _, accs in got.acc_per_cluster for a in accs])
    return {"n_devices": len(jax.devices()),
            "acc_maxdiff": float(np.abs(ra - ga).max()),
            "acc_finite": bool(np.isfinite(ga).all()),
            "bytes_parity": ref.comm.bytes == got.comm.bytes,
            "total_bytes": float(got.comm.bytes[-1])}


def smoke() -> dict:
    """Sharded-engine exercise for the dry-run matrix: an 8-node FACADE
    run on a forced 8-device mesh (subprocess — the device-count flag
    only takes effect before jax init) must match the unsharded engine's
    trajectory (bytes exactly, accuracy within ``ACC_TOL``)."""
    rec = _spawn(8, {"kind": "parity", "n_nodes": 8})
    ok = (rec["n_devices"] == 8 and rec["bytes_parity"]
          and rec["acc_finite"] and rec["acc_maxdiff"] <= ACC_TOL)
    return {"status": "ok" if ok else "fail", **rec}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--child", metavar="SPEC_JSON", default=None)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    if args.child is not None:
        spec = json.loads(args.child)
        if spec.get("kind") == "parity":
            print(json.dumps(_parity_child(spec)))
        else:
            print(json.dumps(_child_payload(spec)))
        return 0
    run(quick=not args.full)
    return 0


if __name__ == "__main__":
    sys.exit(main())
