"""Rounds/sec: the seed's per-round driver vs the scan-fused segment engine.

The paper sweeps 5 algorithms x seeds x hundreds of rounds x netsim
presets, so driver overhead — not model FLOPs — is what bounds sweep
throughput. This benchmark therefore uses a deliberately small 32-node
GN-LeNet config (8x8 images, width 2, 1 local step) where the per-round
compute is a few ms and the driver dominates, and measures steady-state
(round/segment programs compiled before timing starts).

``legacy`` reproduces the seed driver faithfully, per round: eager batch
sampling, one XLA dispatch, a forced device->host sync on
``float(round_bytes)``, a per-round ``cluster_id`` transfer (FACADE), and
— every ``eval_every`` rounds — the seed's evaluator: a fresh ``@jax.jit``
closure (recompiles every eval) looping in Python over nodes x ragged
batches. ``engine`` is this PR's path: one dispatch + one bulk host drain
per 20-round segment (``SegmentEngine``) and the vmapped padded evaluator.

Writes ``results/bench/BENCH_throughput.json``. Acceptance floor: the
engine must sustain >= 3x the legacy rounds/sec for both benchmarked
algorithms — FACADE (the paper's contribution, the heaviest round body)
and EL (its primary baseline); ``min_speedup`` covers exactly these two.

The engine side rides the sweep subsystem (``repro.sweep.run_sweep`` over
a shared ``EngineCache``): a short warm pass compiles the segment program
and evaluator, then the timed pass runs warm-cache — the steady state a
multi-seed sweep actually pays per run.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommLog
from repro.core.bindings import make_binding
from repro.core.cache import EngineCache
from repro.core.runner import algo_setup, run_experiment
from repro.data import pipeline
from repro.data.synthetic import SynthSpec, make_clustered_data
from repro.models import cnn as cnn_mod
from repro.configs.facade_paper import lenet
from repro.sweep import SweepCell, run_sweep

from . import common

N_NODES = 32
EVAL_EVERY = 20
LOCAL_STEPS = 1
BATCH = 2


def _seed_eval_models(cfg, models, node_cluster, test_x, test_y):
    """The seed's ``_eval_models``, verbatim semantics: a FRESH ``@jax.jit``
    closure per call (so every eval recompiles) and a Python loop over
    nodes x ragged batches — the evaluation path this PR replaced."""
    @jax.jit
    def predict(params, x):
        return jnp.argmax(cnn_mod.forward(cfg, params, x), -1)

    accs = []
    for c in range(len(test_x)):
        nodes = [i for i in range(len(node_cluster))
                 if node_cluster[i] == c]
        cluster_accs = []
        for i in nodes:
            params_i = jax.tree.map(lambda l: l[i], models)
            preds = np.concatenate(
                [np.asarray(predict(params_i, test_x[c][j:j + 256]))
                 for j in range(0, len(test_x[c]), 256)])
            cluster_accs.append((preds == test_y[c]).mean())
        accs.append(float(np.mean(cluster_accs)))
    return accs


def _legacy_driver(setup, cfg, ds, tx, ty, kd, rounds, start=0):
    """The seed run_experiment loop: per-round dispatch + host syncs."""
    comm = CommLog()
    stepper = jax.jit(setup.round_fn)
    state = setup.state
    for rnd in range(start, start + rounds):
        kd, kb = jax.random.split(kd)
        batches = pipeline.sample_round_batches(kb, tx, ty, LOCAL_STEPS,
                                                BATCH)
        state, info = stepper(state, batches, net=None)
        if (rnd + 1) % EVAL_EVERY == 0:
            accs = _seed_eval_models(cfg, setup.models_of(state),
                                     ds.node_cluster, ds.test_x, ds.test_y)
            comm.record(rnd + 1, float(info["round_bytes"]),
                        float(np.mean(accs)))
        else:
            comm.record(rnd + 1, float(info["round_bytes"]))
        if setup.track_cluster:
            _ = np.asarray(state.cluster_id)
    return state


def _bench_algo(algo, cfg, ds, rounds, cache):
    binding = make_binding(cfg)
    tx, ty = jnp.asarray(ds.train_x), jnp.asarray(ds.train_y)
    kd = jax.random.PRNGKey(1)
    setup = algo_setup(algo, binding, jax.random.PRNGKey(0), N_NODES, 2,
                       degree=4, local_steps=LOCAL_STEPS, lr=0.05)

    # --- legacy: warm the round program (the per-eval recompile is the
    # seed's steady-state behavior and stays in the timed region) ---
    _legacy_driver(setup, cfg, ds, tx, ty, kd, 2)
    t0 = time.perf_counter()
    _legacy_driver(setup, cfg, ds, tx, ty, kd, rounds)
    t_legacy = time.perf_counter() - t0

    # --- engine via the sweep path: a one-segment warm pass compiles the
    # (EVAL_EVERY, main) program + evaluator into the shared cache, then
    # the timed pass runs warm — zero compiles in the timed region ---
    kw = dict(k=2, degree=4, local_steps=LOCAL_STEPS, batch_size=BATCH,
              lr=0.05, eval_every=EVAL_EVERY)
    warm = SweepCell(name=algo, algo=algo, cfg=cfg, dataset=ds,
                     rounds=EVAL_EVERY, kwargs=dict(kw))
    run_sweep([warm], (0,), cache=cache)
    compiled = cache.compile_count
    cell = SweepCell(name=algo, algo=algo, cfg=cfg, dataset=ds,
                     rounds=rounds, kwargs=dict(kw))
    t0 = time.perf_counter()
    run_sweep([cell], (0,), cache=cache)
    t_engine = time.perf_counter() - t0

    return {"legacy_rounds_per_sec": rounds / t_legacy,
            "engine_rounds_per_sec": rounds / t_engine,
            "speedup": t_legacy / t_engine,
            "timed_recompiles": cache.compile_count - compiled}


def run(quick: bool = True) -> dict:
    rounds = 60 if quick else 200
    cfg, ds = common.micro_config(N_NODES)
    cache = EngineCache()
    results, rows = {}, []
    for algo in ("facade", "el"):
        r = _bench_algo(algo, cfg, ds, rounds, cache)
        results[algo] = r
        rows.append([algo, f"{r['legacy_rounds_per_sec']:.1f}",
                     f"{r['engine_rounds_per_sec']:.1f}",
                     f"{r['speedup']:.2f}x"])
    print(common.table(["algo", "legacy r/s", "engine r/s", "speedup"],
                       rows))
    payload = {"n_nodes": N_NODES, "rounds": rounds,
               "eval_every": EVAL_EVERY, "local_steps": LOCAL_STEPS,
               "batch_size": BATCH, "results": results,
               "min_speedup": min(r["speedup"] for r in results.values()),
               "cache": cache.stats()}
    out = common.write_bench("throughput", payload)
    print(f"wrote {out} (min speedup {payload['min_speedup']:.2f}x)")
    return payload


def smoke() -> dict:
    """Tiny engine exercise for the dry-run matrix: 4 nodes, fused
    segments, parity-checked against the legacy per-round driver."""
    cfg = lenet(smoke=True).replace(n_classes=4)
    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    ds = make_clustered_data(spec, (3, 1), ("rot0", "rot180"))
    kw = dict(rounds=4, k=2, degree=2, local_steps=2, batch_size=4,
              lr=0.05, eval_every=2, seed=0)
    ref = run_experiment("facade", cfg, ds, engine=False, **kw)
    eng = run_experiment("facade", cfg, ds, engine=True, **kw)
    ok = (ref.acc_per_cluster == eng.acc_per_cluster
          and ref.comm.bytes == eng.comm.bytes
          and np.isfinite(eng.comm.bytes[-1]))
    return {"status": "ok" if ok else "fail",
            "final_acc": [float(a) for a in eng.final_acc],
            "total_bytes": float(eng.comm.bytes[-1])}


if __name__ == "__main__":
    run()
