"""Shared harness for the paper-table benchmarks.

Every benchmark module exposes ``run(quick: bool) -> dict`` and registers
itself in ``REGISTRY``. ``quick`` (the default for ``-m benchmarks.run``)
scales the paper's 16-32-node/1200-round experiments down to CPU size
(8 nodes / tens of rounds) while keeping cluster-ratio structure; ``--full``
uses the paper-shaped configuration (slow on CPU).
"""
from __future__ import annotations

import json
import os
import pathlib
import time

from repro.configs.facade_paper import lenet
from repro.core.runner import run_experiment
from repro.data.synthetic import SynthSpec, make_clustered_data

RESULTS_DIR = pathlib.Path(__file__).resolve().parent.parent / "results" / "bench"

ALGOS = ("facade", "el", "dac", "deprl")


def scaled(quick: bool):
    """(cluster configs, rounds, spec, cnn cfg) at CPU scale."""
    if quick:
        # noise=0.8 calibrated so EL shows the paper's minority-cluster gap
        # at CPU scale (EL ~0.32 vs FACADE ~0.87 on the 7:1 minority)
        spec = SynthSpec(n_classes=6, image_size=16, samples_per_class=12,
                         test_per_class=32, noise=0.8, seed=3)
        cfg = lenet(smoke=True).replace(n_classes=6)
        cluster_cfgs = [(4, 4), (6, 2), (7, 1)]   # 16:16 / 24:8 / 30:2 scaled
        rounds = 48
    else:
        spec = SynthSpec(n_classes=10, image_size=32, samples_per_class=32,
                         test_per_class=64, seed=3)
        cfg = lenet(smoke=False)
        cluster_cfgs = [(16, 16), (24, 8), (30, 2)]
        rounds = 400
    return cluster_cfgs, rounds, spec, cfg


def std_kwargs(quick: bool):
    return dict(degree=2 if quick else 4, local_steps=4 if quick else 10,
                batch_size=8, lr=0.05, eval_every=8 if quick else 40,
                seed=0)


def run_algo(algo, cfg, ds, rounds, quick, **overrides):
    kw = std_kwargs(quick)
    kw.update(overrides)
    k = kw.pop("k", ds.k)
    t0 = time.time()
    res = run_experiment(algo, cfg, ds, rounds=rounds, k=k, **kw)
    res.wall_s = time.time() - t0
    return res


def save(name: str, payload: dict) -> pathlib.Path:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out = RESULTS_DIR / f"{name}.json"
    out.write_text(json.dumps(payload, indent=2, default=float))
    return out


def trajectory_path() -> pathlib.Path:
    """Where :func:`write_bench` appends its history. Module-level
    ``RESULTS_DIR`` lookup at call time so tests can monkeypatch it."""
    return RESULTS_DIR / "TRAJECTORY.jsonl"


def write_bench(name: str, payload: dict) -> pathlib.Path:
    """The one way a benchmark writes its ``BENCH_<name>.json``: stamps a
    ``manifest`` block (payload content fingerprint + jax version +
    timestamp, :func:`repro.obs.bench_stamp`) so every benchmark artifact
    records what exactly produced it, then routes through :func:`save`.

    Every payload is ALSO appended to ``results/bench/TRAJECTORY.jsonl``
    (one record per write, never truncated) — the across-runs history
    ``benchmarks/check_regress.py`` diffs latest-vs-previous against.
    """
    from repro.obs import bench_stamp

    payload = dict(payload)
    payload["manifest"] = bench_stamp(name, payload)
    traj = trajectory_path()
    traj.parent.mkdir(parents=True, exist_ok=True)
    with traj.open("a") as fh:
        fh.write(json.dumps({"name": name, "payload": payload},
                            default=repr) + "\n")
        fh.flush()
    return save(f"BENCH_{name}", payload)


def engine_cache(max_entries: int | None = None):
    """Build the benchmark-suite :class:`repro.core.cache.EngineCache`,
    honoring ``REPRO_XLA_CACHE_DIR``: when that env var names a directory,
    compiled XLA executables persist there across benchmark PROCESSES
    (``EngineCache(persist_dir=...)``), so a re-run of ``-m benchmarks.run``
    or a CI shard starts warm. Unset => a plain in-process cache."""
    from repro.core.cache import EngineCache

    return EngineCache(persist_dir=os.environ.get("REPRO_XLA_CACHE_DIR")
                       or None, max_entries=max_entries)


def fmt_to_target(v, fmt: str = "{:.1f} s"):
    """Render a ``CommLog`` bytes/seconds-to-target value for a table.
    ``None`` is the log's never-reached sentinel (see
    :mod:`repro.comm.accounting`) — formatted as ``"not reached"``
    instead of crashing an f-string's float format."""
    return "not reached" if v is None else fmt.format(v)


def to_target_ratio(base, new):
    """Speedup ``base / new`` for a pair of to-target values, propagating
    the never-reached sentinel: ``None`` when either side never crossed
    the target (a run that never got there has no finite speedup)."""
    if base is None or new is None or new == 0:
        return None
    return base / new


def table(headers, rows) -> str:
    w = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
         else len(str(h)) for i, h in enumerate(headers)]
    line = " | ".join(str(h).ljust(w[i]) for i, h in enumerate(headers))
    sep = "-+-".join("-" * x for x in w)
    body = "\n".join(" | ".join(str(c).ljust(w[i])
                                for i, c in enumerate(r)) for r in rows)
    return f"{line}\n{sep}\n{body}"


def make_ds(spec, sizes, transforms=None, label_split=None):
    return make_clustered_data(spec, sizes, transforms,
                               label_split=label_split)


def micro_config(n_nodes: int = 32, seed: int = 3):
    """Deliberately tiny 32-node GN-LeNet setup (8x8 images, width 2) where
    per-round compute is a few ms — the regime where driver overhead and
    XLA compiles, not model FLOPs, bound sweep throughput. Shared by the
    ``round_throughput`` and ``seed_sweep`` benchmarks."""
    from repro.models.base import CNNConfig

    cfg = CNNConfig(name="lenet-micro", kind="lenet", image_size=8,
                    width=2, n_classes=4)
    spec = SynthSpec(n_classes=4, image_size=8, samples_per_class=8,
                     test_per_class=16, seed=seed)
    half = n_nodes // 2
    ds = make_clustered_data(spec, (half, n_nodes - half),
                             ("rot0", "rot180"))
    return cfg, ds
