"""Perf regression gate over the benchmark trajectory.

``common.write_bench`` appends every ``BENCH_*`` payload to
``results/bench/TRAJECTORY.jsonl``; this suite diffs each benchmark's
LATEST record against its PREVIOUS one under per-metric tolerance gates,
so a perf claim from PRs 2-9 (engine speedup, obs overhead, pipeline
drain, sharding linearity, warm start) can't silently rot between runs.

Semantics:

* a benchmark with fewer than two trajectory records is reported as
  ``baseline`` (nothing to diff yet) — the FIRST full benchmark run
  seeds the gate, it never fails it;
* ``direction="higher"`` passes when
  ``new >= prev - rel_tol*|prev| - abs_tol``; ``"lower"`` mirrors it.
  Tolerances are deliberately loose — CPU benchmark timings are noisy
  and the gate is for *regressions*, not run-to-run jitter;
* a gate ``path`` walks nested dicts with ``"*"`` fanning out over all
  values at that level (e.g. ``results.*.base_rounds_per_sec`` checks
  every benchmarked algorithm); a path absent on EITHER side is skipped
  (schema growth is not a regression);
* any failed gate raises ``RuntimeError`` after the full table prints,
  which is how ``-m benchmarks.run`` reports it.

Registered LAST in ``benchmarks/run.py`` so the gate sees the records
the same invocation just wrote. Results go through :func:`common.save`
(NOT ``write_bench`` — the gate must not append itself to the
trajectory it reads).
"""
from __future__ import annotations

import dataclasses

from repro.obs import read_jsonl

from . import common


@dataclasses.dataclass(frozen=True)
class Gate:
    """One metric's tolerance gate. ``path`` is dot-separated into the
    payload, ``"*"`` fans out over a dict level."""
    path: str
    direction: str            # "higher" = bigger is better, "lower" = smaller
    rel_tol: float = 0.25
    abs_tol: float = 0.0

    def __post_init__(self):
        if self.direction not in ("higher", "lower"):
            raise ValueError(f"direction must be higher|lower, "
                             f"got {self.direction!r}")

    def passes(self, prev: float, new: float) -> bool:
        slack = self.rel_tol * abs(prev) + self.abs_tol
        if self.direction == "higher":
            return new >= prev - slack
        return new <= prev + slack


# per-benchmark gates, keyed by the write_bench name
GATES: "dict[str, tuple]" = {
    "obs": (
        Gate("worst_overhead_pct", "lower", rel_tol=0.0, abs_tol=3.0),
        Gate("results.*.base_rounds_per_sec", "higher"),
        Gate("results.*.obs_rounds_per_sec", "higher"),
    ),
    "throughput": (
        Gate("min_speedup", "higher"),
    ),
    "pipeline": (
        Gate("min_drain_wait_reduction", "higher",
             rel_tol=0.0, abs_tol=0.15),
    ),
    "scale": (
        Gate("linear_frac", "higher", rel_tol=0.0, abs_tol=0.15),
    ),
    "warmstart": (
        Gate("speedup_first_dispatch", "higher", rel_tol=0.5),
    ),
}


def _resolve(payload, path: str) -> "list[tuple[str, float]]":
    """All ``(concrete_path, value)`` leaves ``path`` names in
    ``payload`` — one entry per ``"*"`` expansion, empty when the path
    is absent or a leaf is non-numeric."""
    slots = [("", payload)]
    for part in path.split("."):
        nxt = []
        for prefix, node in slots:
            if not isinstance(node, dict):
                continue
            if part == "*":
                nxt += [(f"{prefix}.{k}".lstrip("."), v)
                        for k, v in sorted(node.items())]
            elif part in node:
                nxt.append((f"{prefix}.{part}".lstrip("."), node[part]))
        slots = nxt
    return [(p, float(v)) for p, v in slots
            if isinstance(v, (int, float)) and not isinstance(v, bool)]


def check(records: "list[dict]", gates: "dict[str, tuple]") -> dict:
    """Pure comparison: group trajectory ``records`` (each
    ``{"name":..., "payload":...}``) by benchmark name, diff latest vs
    previous under ``gates``. Returns ``{"rows": [...], "failures":
    [...], "baselines": [names...]}``."""
    by_name: "dict[str, list]" = {}
    for rec in records:
        by_name.setdefault(rec["name"], []).append(rec["payload"])
    rows, failures, baselines = [], [], []
    for name, gs in sorted(gates.items()):
        history = by_name.get(name, [])
        if len(history) < 2:
            baselines.append(name)
            continue
        prev, new = history[-2], history[-1]
        for gate in gs:
            prev_leaves = dict(_resolve(prev, gate.path))
            for cpath, new_v in _resolve(new, gate.path):
                if cpath not in prev_leaves:
                    continue        # schema growth, not a regression
                prev_v = prev_leaves[cpath]
                ok = gate.passes(prev_v, new_v)
                row = {"bench": name, "metric": cpath,
                       "direction": gate.direction,
                       "prev": prev_v, "new": new_v, "ok": ok}
                rows.append(row)
                if not ok:
                    failures.append(row)
    return {"rows": rows, "failures": failures, "baselines": baselines}


def run(quick: bool = True) -> dict:
    records = read_jsonl(common.trajectory_path())
    verdict = check(records, GATES)
    if verdict["rows"]:
        print(common.table(
            ["bench", "metric", "dir", "prev", "new", "ok"],
            [[r["bench"], r["metric"], r["direction"],
              f"{r['prev']:.3f}", f"{r['new']:.3f}",
              "ok" if r["ok"] else "FAIL"] for r in verdict["rows"]]))
    for name in verdict["baselines"]:
        print(f"  [{name}] baseline only "
              "(< 2 trajectory records; nothing to diff)")
    payload = {"n_records": len(records),
               "n_checked": len(verdict["rows"]),
               "n_failed": len(verdict["failures"]),
               "baselines": verdict["baselines"],
               "rows": verdict["rows"]}
    common.save("check_regress", payload)   # save, NOT write_bench: the
    #                                         gate must not feed itself
    if verdict["failures"]:
        raise RuntimeError(
            "benchmark regression gate failed: " + "; ".join(
                f"{f['bench']}.{f['metric']} {f['prev']:.3f} -> "
                f"{f['new']:.3f} ({f['direction']} is better)"
                for f in verdict["failures"]))
    print(f"regression gate: {len(verdict['rows'])} metrics checked, "
          "0 failures")
    return payload


if __name__ == "__main__":
    run()
