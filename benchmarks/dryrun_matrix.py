"""Systems benchmark: render the §Dry-run / §Roofline tables from the
records produced by ``python -m repro.launch.dryrun`` (results/dryrun/).

Does not recompute anything — the 512-device lowering runs in its own
process (device-count pinning); this module aggregates and validates.
"""
from __future__ import annotations

import json
import pathlib

from . import common

DRYRUN_DIR = pathlib.Path(__file__).resolve().parent.parent / "results" / "dryrun"

HBM_PER_CHIP_GB = 16.0  # TPU v5e


def load(pattern: str):
    recs = []
    for f in sorted(DRYRUN_DIR.glob(pattern)):
        with f.open() as fh:
            recs += [json.loads(l) for l in fh if l.strip()]
    # newest record wins per (arch, shape, mesh, tag)
    dedup = {}
    for r in recs:
        dedup[(r["arch"], r["shape"], r["mesh"], r.get("tag", ""))] = r
    return list(dedup.values())


def run(quick: bool = True) -> dict:
    # tiny netsim config exercised on every invocation (churn_resilience
    # smoke: 4-node FACADE under edge-churn) so the netsim path can't rot;
    # a smoke failure is reported in the payload, never aborts the table
    from . import churn_resilience
    try:
        net_rec = churn_resilience.smoke()
    except Exception as e:
        net_rec = {"status": "fail", "preset": "edge-churn", "error": repr(e)}
        print(f"netsim smoke [edge-churn]: FAIL ({e!r})")
    else:
        s2t = net_rec["seconds_to_target"]
        print(f"netsim smoke [{net_rec['preset']}]: {net_rec['status']} "
              f"({net_rec['sim_seconds']:.2f} sim-s, "
              f"{net_rec['total_bytes']/1e3:.1f} KB); SLO: "
              + (f"{s2t:.2f} sim-s to acc 0.1" if s2t is not None
                 else "target acc 0.1 not reached"))

    # netsim-v2 smoke: bursty + core/edge tiers + async stale gossip in one
    # preset, plus channel statistics; reported, never aborts the table
    try:
        v2_rec = churn_resilience.smoke_v2()
    except Exception as e:
        v2_rec = {"status": "fail", "preset": "edge-v2", "error": repr(e)}
        print(f"netsim-v2 smoke [edge-v2]: FAIL ({e!r})")
    else:
        print(f"netsim-v2 smoke [{v2_rec['preset']}]: {v2_rec['status']} "
              f"({v2_rec['total_bytes']/1e3:.1f} KB async vs "
              f"{v2_rec['sync_bytes']/1e3:.1f} KB sync, "
              f"bad-rate {v2_rec['channel_bad_rate']:.2f})")

    # segment-engine smoke: one fused span, parity-checked vs the legacy
    # driver (keeps the scan path from rotting); reported, never aborts
    try:
        from . import round_throughput
        eng_rec = round_throughput.smoke()
    except Exception as e:
        eng_rec = {"status": "fail", "error": repr(e)}
        print(f"engine smoke: FAIL ({e!r})")
    else:
        print(f"engine smoke: {eng_rec['status']} "
              f"({eng_rec['total_bytes']/1e3:.1f} KB)")

    # sweep smoke: 2-seed x 2-algorithm grid on one shared EngineCache —
    # asserts zero recompiles after the first run of each cell
    try:
        from . import seed_sweep
        sweep_rec = seed_sweep.smoke()
    except Exception as e:
        sweep_rec = {"status": "fail", "error": repr(e)}
        print(f"sweep smoke: FAIL ({e!r})")
    else:
        print(f"sweep smoke: {sweep_rec['status']} "
              f"({sweep_rec['compiles_after_first']} compiles, "
              f"{sweep_rec['recompiles']} recompiles after first run)")

    # adaptive-topology smoke: uniform-policy bit-parity + one adaptive
    # run + the sampler's fairness floor (repro.topo); reported, never
    # aborts the table
    try:
        from . import topo_adapt
        topo_rec = topo_adapt.smoke()
    except Exception as e:
        topo_rec = {"status": "fail", "error": repr(e)}
        print(f"topo smoke: FAIL ({e!r})")
    else:
        print(f"topo smoke [{topo_rec['preset']}]: {topo_rec['status']} "
              f"(uniform parity {topo_rec['uniform_parity']}, adaptive "
              f"{topo_rec['adaptive_bytes']/1e3:.1f} KB vs uniform "
              f"{topo_rec['uniform_bytes']/1e3:.1f} KB, min inclusion "
              f"{topo_rec['min_inclusion_freq']:.2f})")

    # obs smoke: full telemetry attached to a tiny run — trajectory
    # parity, finite round-complete frames, JSONL round-trip; reported,
    # never aborts the table
    try:
        from . import obs_overhead
        obs_rec = obs_overhead.smoke()
    except Exception as e:
        obs_rec = {"status": "fail", "error": repr(e)}
        print(f"obs smoke: FAIL ({e!r})")
    else:
        print(f"obs smoke: {obs_rec['status']} "
              f"({obs_rec['frames']} frames, "
              f"{obs_rec['jsonl_records']} JSONL records, spans "
              f"{obs_rec['spans']})")

    # health+report smoke: an unguarded NaN-corruption run must be
    # flagged (fail verdict + health.* events) while a clean run stays
    # quiet, and the report CLI must render from the real manifest +
    # JSONL; reported, never aborts the table
    try:
        from . import obs_overhead as obs_bench
        health_rec = obs_bench.smoke_health()
    except Exception as e:
        health_rec = {"status": "fail", "error": repr(e)}
        print(f"health smoke: FAIL ({e!r})")
    else:
        print(f"health smoke: {health_rec['status']} "
              f"(clean={health_rec['clean_verdict']}, "
              f"storm={health_rec['storm_verdict']} via "
              f"{health_rec['storm_events']}, report rendered "
              f"{health_rec['report_rendered']})")

    # resil smoke: fault off-switch bit-parity + a guarded crash/NaN
    # storm staying finite while shedding bytes; reported, never aborts
    try:
        from . import fault_tolerance
        resil_rec = fault_tolerance.smoke()
    except Exception as e:
        resil_rec = {"status": "fail", "error": repr(e)}
        print(f"resil smoke: FAIL ({e!r})")
    else:
        print(f"resil smoke: {resil_rec['status']} "
              f"(off-switch parity {resil_rec['off_switch_parity']}, "
              f"storm finite {resil_rec['storm_finite']}, "
              f"{resil_rec['storm_bytes']/1e3:.1f} KB under faults vs "
              f"{resil_rec['plain_bytes']/1e3:.1f} KB clean)")

    # checkpoint smoke: save -> kill mid-run -> resume, bit-parity with an
    # uninterrupted run (metrics and final carry); reported, never aborts
    try:
        from . import fault_tolerance
        ckpt_rec = fault_tolerance.smoke_resume()
    except Exception as e:
        ckpt_rec = {"status": "fail", "error": repr(e)}
        print(f"ckpt smoke: FAIL ({e!r})")
    else:
        print(f"ckpt smoke: {ckpt_rec['status']} "
              f"(killed {ckpt_rec['killed_mid_run']}, metrics parity "
              f"{ckpt_rec['metrics_parity']}, carry parity "
              f"{ckpt_rec['carry_parity']})")

    # persist-dir smoke: a run over EngineCache(persist_dir=...) must stay
    # bit-for-bit a plain run AND leave serialized executables on disk
    try:
        from . import warm_start
        warm_rec = warm_start.smoke()
    except Exception as e:
        warm_rec = {"status": "fail", "error": repr(e)}
        print(f"persist smoke: FAIL ({e!r})")
    else:
        print(f"persist smoke: {warm_rec['status']} "
              f"({warm_rec['persisted_files']} files persisted)")

    # sharded-engine smoke: 8-node FACADE on a forced 8-device node mesh
    # (own subprocess: the device-count flag must precede jax init) —
    # bytes bit-parity + tolerance-pinned accuracy vs the unsharded run
    try:
        from . import scale_curve
        shard_rec = scale_curve.smoke()
    except Exception as e:
        shard_rec = {"status": "fail", "error": repr(e)}
        print(f"shard smoke: FAIL ({e!r})")
    else:
        print(f"shard smoke: {shard_rec['status']} "
              f"({shard_rec['n_devices']} devices, bytes parity "
              f"{shard_rec['bytes_parity']}, acc maxdiff "
              f"{shard_rec['acc_maxdiff']:.4f})")

    # pipeline smoke: pipeline=True bit-parity with the serialized driver
    try:
        from . import pipeline as pipeline_bench
        pipe_rec = pipeline_bench.smoke()
    except Exception as e:
        pipe_rec = {"status": "fail", "error": repr(e)}
        print(f"pipeline smoke: FAIL ({e!r})")
    else:
        print(f"pipeline smoke: {pipe_rec['status']} "
              f"({pipe_rec['total_bytes']/1e3:.1f} KB)")

    # pipeline+ckpt smoke: a checkpointed pipelined run matches serialized
    # and leaves a resumable archive behind
    try:
        from . import pipeline as pipeline_bench
        pipeckpt_rec = pipeline_bench.smoke_ckpt()
    except Exception as e:
        pipeckpt_rec = {"status": "fail", "error": repr(e)}
        print(f"pipeline+ckpt smoke: FAIL ({e!r})")
    else:
        print(f"pipeline+ckpt smoke: {pipeckpt_rec['status']} "
              f"(ckpt written {pipeckpt_rec['ckpt_written']})")

    recs = [r for r in load("dryrun_*.jsonl") if r.get("tag", "") == ""]
    if not recs:
        print("no dry-run records; run `python -m repro.launch.dryrun --all` "
              "(and --multi-pod) first")
        return {"netsim_smoke": net_rec, "netsim_v2_smoke": v2_rec,
                "engine_smoke": eng_rec, "sweep_smoke": sweep_rec,
                "topo_smoke": topo_rec, "obs_smoke": obs_rec,
                "health_smoke": health_rec,
                "resil_smoke": resil_rec, "ckpt_smoke": ckpt_rec,
                "persist_smoke": warm_rec, "shard_smoke": shard_rec,
                "pipeline_smoke": pipe_rec,
                "pipeline_ckpt_smoke": pipeckpt_rec}
    rows = []
    ok = fail = skip = 0
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "skipped":
            skip += 1
            continue
        if r["status"] == "fail":
            fail += 1
            rows.append([r["arch"], r["shape"], r["mesh"], "FAIL",
                         "", "", "", ""])
            continue
        ok += 1
        fits = "Y" if r["peak_gbytes_per_dev"] <= HBM_PER_CHIP_GB else "over"
        rows.append([
            r["arch"], r["shape"], r["mesh"],
            f"{r['peak_gbytes_per_dev']:.1f}GB/{fits}",
            f"{r['t_compute_s']:.3f}", f"{r['t_memory_s']:.3f}",
            f"{r['t_collective_s']:.3f}", r["dominant"]])
    print(common.table(
        ["arch", "shape", "mesh", "peak/fits", "t_comp", "t_mem",
         "t_coll", "dominant"], rows))
    print(f"\n{ok} compiled, {fail} failed, {skip} skipped "
          f"(full-attention long_500k carve-outs)")
    payload = {"n_ok": ok, "n_fail": fail, "n_skip": skip, "records": recs,
               "netsim_smoke": net_rec, "netsim_v2_smoke": v2_rec,
               "engine_smoke": eng_rec, "sweep_smoke": sweep_rec,
               "topo_smoke": topo_rec, "obs_smoke": obs_rec,
               "health_smoke": health_rec,
               "resil_smoke": resil_rec, "ckpt_smoke": ckpt_rec,
               "persist_smoke": warm_rec, "shard_smoke": shard_rec,
               "pipeline_smoke": pipe_rec,
               "pipeline_ckpt_smoke": pipeckpt_rec}
    common.save("dryrun_matrix", payload)
    return payload


if __name__ == "__main__":
    run()
