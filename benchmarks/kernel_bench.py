"""Kernel micro-benchmarks: wall-time of the pure-jnp oracle vs the Pallas
kernel in interpret mode, plus the STRUCTURAL comparison that matters on
CPU: HBM traffic implied by each formulation (the oracle materializes the
full score/logit tensors; the kernels tile them through VMEM).

Interpret-mode wall time is NOT a TPU speed estimate — the structural
bytes columns are the roofline-relevant output.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ops as fa
from repro.kernels.head_select import ops as hs
from repro.kernels.head_select.ref import head_losses_ref
from repro.kernels.rwkv6 import ops as rw

from . import common


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e3  # ms


def run(quick: bool = True) -> dict:
    rows, payload = [], {}
    key = jax.random.PRNGKey(0)

    # flash attention: oracle materializes B*H*S^2 fp32 scores
    b, hq, hkv, s, d = (1, 4, 2, 512, 64) if quick else (2, 8, 2, 2048, 128)
    q = 0.3 * jax.random.normal(key, (b, hq, s, d))
    k = 0.3 * jax.random.normal(key, (b, hkv, s, d))
    v = 0.3 * jax.random.normal(key, (b, hkv, s, d))
    t_ref = _time(fa.attention_ref, q, k, v)
    t_ker = _time(fa.flash_attention_op, q, k, v, interpret=True)
    bytes_ref = b * hq * s * s * 4               # score tensor in HBM
    bytes_ker = 128 * 128 * 4                    # one VMEM tile
    rows.append(["flash_attention", f"{t_ref:.1f}", f"{t_ker:.1f}",
                 f"{bytes_ref/1e6:.1f} MB", f"{bytes_ker/1e3:.0f} KB"])
    payload["flash_attention"] = {
        "ms_ref": t_ref, "ms_interp": t_ker,
        "hbm_bytes_ref": bytes_ref, "vmem_tile_bytes": bytes_ker}

    # head-select fused CE: oracle materializes K*T*V fp32 logits
    kk, t, dd, vv = (3, 512, 64, 1024) if quick else (3, 4096, 256, 32768)
    feats = 0.5 * jax.random.normal(key, (t, dd))
    heads = 0.05 * jax.random.normal(key, (kk, dd, vv))
    labels = jax.random.randint(key, (t,), 0, vv, dtype=jnp.int32)
    t_ref = _time(head_losses_ref, feats, heads, labels)
    t_ker = _time(hs.facade_head_losses, feats, heads, labels,
                  interpret=True)
    rows.append(["head_select(kCE)", f"{t_ref:.1f}", f"{t_ker:.1f}",
                 f"{kk*t*vv*4/1e6:.1f} MB", f"{128*512*4/1e3:.0f} KB"])
    payload["head_select"] = {"ms_ref": t_ref, "ms_interp": t_ker,
                              "hbm_bytes_ref": kk * t * vv * 4}

    # rwkv6 wkv
    b2, t2, h2, hd2 = (1, 256, 2, 32) if quick else (2, 1024, 4, 64)
    r = 0.3 * jax.random.normal(key, (b2, t2, h2, hd2))
    kk2 = 0.3 * jax.random.normal(key, (b2, t2, h2, hd2))
    v2 = 0.3 * jax.random.normal(key, (b2, t2, h2, hd2))
    w2 = jnp.exp(-jnp.exp(0.3 * jax.random.normal(key, (b2, t2, h2, hd2))))
    u2 = 0.3 * jax.random.normal(key, (h2, hd2))
    t_ref = _time(rw.wkv_ref, r, kk2, v2, w2, u2)
    t_ker = _time(rw.wkv_op, r, kk2, v2, w2, u2, interpret=True)
    rows.append(["rwkv6_wkv", f"{t_ref:.1f}", f"{t_ker:.1f}",
                 f"{b2*t2*h2*hd2*hd2*4/1e6:.1f} MB(T steps)",
                 f"{64*hd2*4/1e3:.0f} KB"])
    payload["rwkv6_wkv"] = {"ms_ref": t_ref, "ms_interp": t_ker}

    print(common.table(
        ["kernel", "oracle ms", "interp ms", "oracle HBM", "kernel VMEM"],
        rows))
    common.save("kernel_bench", payload)
    return payload


if __name__ == "__main__":
    run()
