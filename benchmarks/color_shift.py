"""Paper Appendix H: feature heterogeneity via color filters — four
clusters (none/gray/sepia/saturate), balanced and imbalanced."""
from __future__ import annotations

from . import common

TRANSFORMS = ("none", "gray", "sepia", "saturate")


def run(quick: bool = True) -> dict:
    _, rounds, spec, cfg = common.scaled(quick)
    configs = [(2, 2, 2, 2), (5, 2, 2, 1)] if quick else \
        [(8, 8, 8, 8), (20, 6, 4, 2)]
    rows, payload = [], {}
    for sizes in configs:
        ds = common.make_ds(spec, sizes, TRANSFORMS)
        for algo in common.ALGOS:
            res = common.run_algo(algo, cfg, ds, rounds, quick, k=4)
            accs = " ".join(f"{a:.2f}" for a in res.final_acc)
            rows.append([":".join(map(str, sizes)), algo, accs,
                         f"{res.best_fair_acc():.3f}"])
            payload[f"{sizes}/{algo}"] = {
                "final_acc": res.final_acc,
                "fair_acc": res.best_fair_acc()}
    print(common.table(["config", "algo", "per-cluster acc",
                        "fair_acc"], rows))
    common.save("color_shift", payload)
    return payload


if __name__ == "__main__":
    run()
