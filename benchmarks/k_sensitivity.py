"""Paper Fig. 8 (Sec. V-F): sensitivity to the number of model heads k.
Three clusters (rot0/rot90/rot180) with sizes scaled from the paper's
20:10:2; k sweeps 1..5. k=1 should behave like EL; overestimating k should
stay close to the optimum k=3."""
from __future__ import annotations

from . import common


def run(quick: bool = True) -> dict:
    _, rounds, spec, cfg = common.scaled(quick)
    sizes = (5, 2, 1) if quick else (20, 10, 2)
    ds = common.make_ds(spec, sizes, ("rot0", "rot90", "rot180"))
    rows, payload = [], {}
    for k in range(1, 6):
        res = common.run_algo("facade", cfg, ds, rounds, quick, k=k)
        accs = [f"{a:.3f}" for a in res.final_acc]
        rows.append([k, *accs, f"{res.best_fair_acc():.3f}"])
        payload[f"k={k}"] = {"final_acc": res.final_acc,
                             "fair_acc": res.best_fair_acc()}
    print(common.table(
        ["k", "acc_c0", "acc_c1", "acc_c2", "fair_acc"], rows))
    common.save("k_sensitivity", payload)
    return payload


if __name__ == "__main__":
    run()
