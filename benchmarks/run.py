"""Run every paper-table benchmark: ``python -m benchmarks.run [--full]
[--only NAME ...]``.

One module per paper table/figure (DESIGN.md §9). ``--quick`` (default)
scales node counts / rounds to CPU; ``--full`` uses paper-shaped configs.
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (check_regress, churn_resilience, color_shift, comm_cost,
               dryrun_matrix, fair_accuracy, fairness_dp_eo, fault_tolerance,
               k_sensitivity, kernel_bench, label_skew, obs_overhead,
               percluster_accuracy, pipeline, round_throughput, scale_curve,
               seed_sweep, settlement, topo_adapt, warm_start,
               warmup_ablation)

SUITES = {
    "percluster_accuracy": percluster_accuracy,   # Fig. 3 / Tab. II
    "fair_accuracy": fair_accuracy,               # Fig. 5 / App. D
    "fairness_dp_eo": fairness_dp_eo,             # Fig. 6
    "comm_cost": comm_cost,                       # Fig. 7
    "k_sensitivity": k_sensitivity,               # Fig. 8
    "settlement": settlement,                     # Fig. 9 / App. F
    "warmup_ablation": warmup_ablation,           # App. F mitigation
    "label_skew": label_skew,                     # App. G
    "color_shift": color_shift,                   # App. H
    "churn_resilience": churn_resilience,         # netsim presets sweep
    "resil": fault_tolerance,                     # faults + robust gossip
    "topo_adapt": topo_adapt,                     # adaptive topology policies
    "round_throughput": round_throughput,         # segment engine rounds/sec
    "pipeline": pipeline,                         # double-buffered dispatch
    "seed_sweep": seed_sweep,                     # compile-cache sweep vs naive
    "warm_start": warm_start,                     # persistent XLA cache
    "scale_curve": scale_curve,                   # sharded engine scaling
    "obs_overhead": obs_overhead,                 # in-scan telemetry cost
    "kernel_bench": kernel_bench,                 # kernels (systems)
    "dryrun_matrix": dryrun_matrix,               # §Dry-run / §Roofline
    "check_regress": check_regress,               # trajectory perf gate
    #   LAST: diffs the records this very invocation just appended
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-shaped configs (slow on CPU)")
    ap.add_argument("--only", nargs="+", choices=sorted(SUITES),
                    default=None)
    args = ap.parse_args(argv)

    names = args.only or list(SUITES)
    failures = []
    for name in names:
        print(f"\n{'='*72}\n== {name}\n{'='*72}", flush=True)
        t0 = time.time()
        try:
            SUITES[name].run(quick=not args.full)
            print(f"[{name}] done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # keep the suite going; report at the end
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
    if failures:
        print("\nFAILED:", failures)
        return 1
    print(f"\nall {len(names)} benchmark suites completed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
