"""Adaptive topology benchmark (repro.topo): bytes- and simulated-seconds-
to-target, adaptive vs uniform sampling, under the netsim-v2 presets.

The paper's headline systems result is communication efficiency (Fig. 7:
GB to target accuracy); netsim added the simulated-time companion. This
table asks what a *netsim-aware* topology buys on top: the ``reliability``
policy (per-link goodput EWMAs -> Gumbel-top-k) concentrates the degree
budget on links that deliver and links that are fast, while the
``min_inclusion`` fairness floor keeps edge-tier nodes in the mixture —
the per-tier accuracy-gap table shows throttled, not starved.

Acceptance (asserted, and written to ``results/bench/BENCH_topo.json``):
on ``core-edge`` the reliability policy strictly reduces simulated
seconds-to-target vs the uniform sampler, and every node's measured
inclusion frequency stays >= ``min_inclusion``.

The presets are made communication-bound (``compute_s_per_step``
scaled down) so the simulated clock measures the links the policy picks,
not a compute floor common to every policy.
"""
from __future__ import annotations

import numpy as np

from repro import netsim
from repro.core.cache import EngineCache
from repro.netsim import NetworkConfig
from repro.topo import TopoConfig, inclusion_stats

from . import common

PRESETS = ("bursty-wan", "core-edge", "edge-v2")
MIN_INCLUSION = 0.25


def _nets() -> dict:
    # comm-bound scaling: keep every preset's loss/churn/tier structure,
    # shrink the uniform compute term so round time is link-dominated
    return {name: NetworkConfig.preset(name, compute_s_per_step=0.002)
            for name in PRESETS}


def _policies() -> dict:
    adaptive = dict(decay=0.7, min_inclusion=MIN_INCLUSION,
                    ref_payload_bytes=5e4)
    return {
        "uniform": None,
        "reliability": TopoConfig(policy="reliability", **adaptive),
        "bandwidth": TopoConfig(policy="bandwidth", **adaptive),
    }


def _tier_row(net, res) -> dict:
    """Per-tier accuracy from the final per-node accuracies (fairness
    floor check: edge tier throttled, not starved)."""
    n = len(res.node_acc)
    tiers = np.asarray(netsim.node_tiers(net, n))
    if tiers.max() == 0:        # preset without link classes
        return {}
    core = float(np.mean(res.node_acc[tiers == 0]))
    edge = float(np.mean(res.node_acc[tiers == 1]))
    return {"core_acc": core, "edge_acc": edge, "tier_gap": core - edge}


def run(quick: bool = True) -> dict:
    cluster_cfgs, rounds, spec, cfg = common.scaled(quick)
    sizes = cluster_cfgs[1]                      # the imbalanced 6:2 config
    ds = common.make_ds(spec, sizes, ("rot0", "rot180"))
    rounds = min(rounds, 64) if quick else rounds
    degree = common.std_kwargs(quick)["degree"]
    nets = _nets()
    policies = _policies()

    cache = EngineCache()
    rows, payload = [], {}
    for preset, net in nets.items():
        results = {}
        for pol_name, topo in policies.items():
            results[pol_name] = common.run_algo(
                "facade", cfg, ds, rounds, quick, net=net, topo=topo,
                cache=cache)
        # a target every policy measurably crossed: just under the worst
        # policy's final mean accuracy, so to-target numbers always exist
        target = 0.98 * min(r.comm.acc[-1] for r in results.values())
        for pol_name, res in results.items():
            b2t = res.comm.bytes_to_target(target)
            s2t = res.comm.seconds_to_target(target)
            tier = _tier_row(net, res)
            rows.append([preset, pol_name, f"{target:.3f}",
                         common.fmt_to_target(
                             None if b2t is None else b2t / 1e6,
                             "{:.2f} MB"),
                         common.fmt_to_target(s2t, "{:.1f} s"),
                         f"{res.comm.seconds[-1]:.1f} s",
                         (f"{tier['core_acc']:.3f}/{tier['edge_acc']:.3f}"
                          if tier else "-")])
            payload[f"{preset}/{pol_name}"] = {
                "target": target,
                "bytes_to_target": b2t,
                "seconds_to_target": s2t,
                "total_bytes": res.comm.bytes[-1],
                "sim_seconds": res.comm.seconds[-1],
                "final_acc": res.final_acc,
                "node_acc": [float(a) for a in res.node_acc],
                **tier,
            }

    # measured inclusion frequency of the sampler itself, on the preset
    # the acceptance bar names (long roll, so the empirical frequency is
    # a fair estimate of the floored participation probability)
    incl = inclusion_stats(policies["reliability"], nets["core-edge"],
                           n=ds.n_nodes, rounds=600, degree=degree)
    payload["inclusion"] = {
        "min_inclusion": MIN_INCLUSION,
        "per_node": [float(f) for f in incl["inclusion"]],
        "min_node": float(incl["inclusion"].min()),
        "mean_degree": incl["mean_degree"],
        "mean_edges": incl["mean_edges"],
        "edge_budget": incl["edge_budget"],
    }

    print(common.table(
        ["preset", "policy", "target", "bytes-to-tgt", "secs-to-tgt",
         "total sim", "core/edge acc"], rows))
    print(f"\ninclusion frequency (reliability @ core-edge): min "
          f"{payload['inclusion']['min_node']:.2f} over {ds.n_nodes} nodes "
          f"(floor {MIN_INCLUSION})")

    # --- acceptance: adaptivity must pay on the tiered preset ---
    uni = payload["core-edge/uniform"]
    rel = payload["core-edge/reliability"]
    # None is the CommLog never-reached sentinel: the adaptive policy must
    # reach the target; a uniform policy that never does counts as beaten
    assert rel["seconds_to_target"] is not None, (
        "reliability policy never reached the core-edge target accuracy "
        f"{rel['target']:.3f} — adaptivity must at least converge")
    assert (uni["seconds_to_target"] is None
            or rel["seconds_to_target"] < uni["seconds_to_target"]), (
        "reliability policy must strictly reduce simulated "
        f"seconds-to-target on core-edge: {rel['seconds_to_target']} vs "
        f"uniform {uni['seconds_to_target']}")
    assert payload["inclusion"]["min_node"] >= MIN_INCLUSION, (
        "fairness floor violated: some node's inclusion frequency "
        f"{payload['inclusion']['min_node']} < {MIN_INCLUSION}")
    payload["speedup_core_edge"] = common.to_target_ratio(
        uni["seconds_to_target"], rel["seconds_to_target"])
    print(f"core-edge seconds-to-target: uniform "
          f"{common.fmt_to_target(uni['seconds_to_target'], '{:.1f}s')} "
          f"-> reliability {rel['seconds_to_target']:.1f}s "
          f"({common.fmt_to_target(payload['speedup_core_edge'], '{:.2f}x')})")
    common.write_bench("topo", payload)
    return payload


def smoke() -> dict:
    """Tiny adaptive-topology exercise for the dry-run matrix: uniform
    policy bit-parity vs ``topo=None`` plus one adaptive run and a
    sampler-floor check — cheap enough for every invocation."""
    from repro.configs.facade_paper import lenet
    from repro.data.synthetic import SynthSpec

    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    ds = common.make_ds(spec, (3, 1), ("rot0", "rot180"))
    cfg = lenet(smoke=True).replace(n_classes=4)
    net = NetworkConfig.preset("core-edge")
    kw = dict(local_steps=2, batch_size=4, eval_every=1)
    ref = common.run_algo("el", cfg, ds, 2, True, net=net, **kw)
    uni = common.run_algo("el", cfg, ds, 2, True, net=net,
                          topo=TopoConfig(), **kw)
    tcfg = TopoConfig(policy="reliability", min_inclusion=0.3)
    ad = common.run_algo("el", cfg, ds, 2, True, net=net, topo=tcfg, **kw)
    incl = inclusion_stats(tcfg, net, n=ds.n_nodes, rounds=200, degree=2)
    ok = (ref.comm.bytes == uni.comm.bytes
          and ref.comm.seconds == uni.comm.seconds
          and ref.acc_per_cluster == uni.acc_per_cluster
          and np.isfinite(ad.comm.bytes[-1])
          and incl["symmetric"] and incl["binary"]
          and float(incl["inclusion"].min()) >= 0.3 - 0.1
          and incl["mean_edges"] <= incl["edge_budget"])
    return {"status": "ok" if ok else "fail",
            "preset": "core-edge",
            "uniform_parity": ref.comm.bytes == uni.comm.bytes,
            "adaptive_bytes": float(ad.comm.bytes[-1]),
            "uniform_bytes": float(ref.comm.bytes[-1]),
            "min_inclusion_freq": float(incl["inclusion"].min()),
            "sim_hours": ad.comm.total_hours,
            "seconds_to_target": ad.comm.seconds_to_target(0.1)}


if __name__ == "__main__":
    run()
