"""Paper Fig. 6: demographic parity (Eq. 1) and equalized odds (Eq. 2) of
the final models, per algorithm and cluster configuration."""
from __future__ import annotations

from . import common


def run(quick: bool = True) -> dict:
    cluster_cfgs, rounds, spec, cfg = common.scaled(quick)
    rows, payload = [], {}
    for sizes in cluster_cfgs:
        ds = common.make_ds(spec, sizes, ("rot0", "rot180"))
        for algo in common.ALGOS:
            res = common.run_algo(algo, cfg, ds, rounds, quick)
            rows.append([f"{sizes[0]}:{sizes[1]}", algo,
                         f"{res.dp:.4f}", f"{res.eo:.4f}",
                         f"{min(res.final_acc):.3f}"])
            payload[f"{sizes}/{algo}"] = {
                "dp": res.dp, "eo": res.eo, "acc_min": min(res.final_acc)}
    print(common.table(["config", "algo", "DP (dn)", "EO (dn)",
                        "acc_min (up)"], rows))
    common.save("fairness_dp_eo", payload)
    return payload


if __name__ == "__main__":
    run()
