"""Pipelined segment dispatch: overlap evidence + rounds/sec for the
double-buffered driver.

The serialized engine loop runs dispatch -> drain -> host bookkeeping ->
dispatch ... per segment, so the host sits in a blocking ``device_get``
while the device computes, then the device idles while the host drains
scalars, reduces the eval and (under ``ckpt``) snapshots the carry — the
``dispatch`` vs ``drain`` tracer spans PR 6 added show exactly this gap.
``run_experiment(pipeline=True)`` dispatches segment ``t+1`` (and
enqueues ``t``'s eval) before draining ``t``, overlapping all host work
with device compute.

Two measurements over the ``round_throughput`` micro config (32-node
GN-LeNet, few-ms rounds, driver-bound), warm over one shared
``EngineCache``:

* **Overlap (the headline):** tracer-measured time the host spends
  BLOCKED in ``drain`` waiting on the device, serialized vs pipelined.
  Pipelining drains a segment only after the next one was dispatched,
  so by drain time the device work is already done — the blocking wait
  collapses to a residual (~99% reduction measured here). This is the
  direct evidence the overlap works, and it is backend-independent.
* **rounds/sec**, ``plain`` and per-segment-``ckpt`` scenarios,
  best-of-``REPEATS``. CAVEAT: on a single-core CPU host (this box:
  ``nproc == 1``) "device" compute and host work time-slice the same
  core, so removing the blocking wait cannot reduce wall-clock — the
  numbers here are a parity/no-regression gate. The wall-clock win
  materializes when host and device are separate resources (any real
  accelerator, or a multi-core CPU under per-segment checkpoint I/O);
  the cross-PROCESS rounds/sec win of the always-warm engine is
  measured by ``benchmarks/warm_start.py`` (2.5x to first dispatch,
  ``BENCH_warmstart.json``).

Writes ``results/bench/BENCH_pipeline.json``; ``all_parity`` gates that
every timed variant stayed bit-identical.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core.cache import EngineCache
from repro.core.runner import run_experiment
from repro.obs import Obs

from . import common

N_NODES = 32
EVAL_EVERY = 5
LOCAL_STEPS = 1
BATCH = 2
REPEATS = 3


def _base_kwargs(rounds, cache):
    return dict(rounds=rounds, k=2, degree=4, local_steps=LOCAL_STEPS,
                batch_size=BATCH, lr=0.05, eval_every=EVAL_EVERY, seed=0,
                cache=cache)


def _drain_share(algo, cfg, ds, rounds, cache, pipeline: bool) -> dict:
    """Tracer rollup of one warm run: how much wall time the host spent
    blocked in ``drain`` (device wait) vs the whole ``run`` span."""
    obs = Obs(config=None)              # spans only: no device-side frames
    run_experiment(algo, cfg, ds, pipeline=pipeline, obs=obs,
                   **_base_kwargs(rounds, cache))
    roll = obs.tracer.rollup()["spans"]
    run_s = roll.get("run", {}).get("total_s", 0.0)
    drain_s = roll.get("drain", {}).get("total_s", 0.0)
    return {"run_s": run_s, "drain_s": drain_s,
            "drain_share": drain_s / run_s if run_s else 0.0}


def _time_variant(algo, cfg, ds, rounds, cache, pipeline: bool,
                  ckpt_dir=None) -> float:
    kw = _base_kwargs(rounds, cache)
    best = float("inf")
    for rep in range(REPEATS):
        ck = (None if ckpt_dir is None else
              os.path.join(ckpt_dir, f"{algo}-{pipeline}-{rep}.npz"))
        t0 = time.perf_counter()
        run_experiment(algo, cfg, ds, pipeline=pipeline, ckpt=ck, **kw)
        best = min(best, time.perf_counter() - t0)
    return best


def _parity(algo, cfg, ds, rounds, cache) -> bool:
    kw = _base_kwargs(rounds, cache)
    off = run_experiment(algo, cfg, ds, pipeline=False, **kw)
    on = run_experiment(algo, cfg, ds, pipeline=True, **kw)
    return (off.acc_per_cluster == on.acc_per_cluster
            and off.comm.bytes == on.comm.bytes
            and off.comm.seconds == on.comm.seconds
            and off.dp == on.dp and off.eo == on.eo)


def run(quick: bool = True) -> dict:
    rounds = 60 if quick else 200
    cfg, ds = common.micro_config(N_NODES)
    cache = EngineCache()
    results, rows = {}, []
    with tempfile.TemporaryDirectory(prefix="repro-pipe-bench-") as td:
        for algo in ("facade", "el"):
            parity = _parity(algo, cfg, ds, rounds, cache)  # also warms
            ser = _drain_share(algo, cfg, ds, rounds, cache, False)
            pipe = _drain_share(algo, cfg, ds, rounds, cache, True)
            reduction = (1.0 - pipe["drain_s"] / ser["drain_s"]
                         if ser["drain_s"] else 0.0)
            r = {"parity": parity,
                 "blocking_drain": {"serial": ser, "pipelined": pipe,
                                    "wait_reduction": reduction}}
            rows.append([algo, "drain-wait",
                         f"{ser['drain_share']:.1%} of wall",
                         f"{pipe['drain_share']:.1%} of wall",
                         f"-{reduction:.0%}", parity])
            for scen, ckd in (("plain", None), ("ckpt", td)):
                t_off = _time_variant(algo, cfg, ds, rounds, cache, False,
                                      ckpt_dir=ckd)
                t_on = _time_variant(algo, cfg, ds, rounds, cache, True,
                                     ckpt_dir=ckd)
                r[scen] = {"serial_rounds_per_sec": rounds / t_off,
                           "pipelined_rounds_per_sec": rounds / t_on,
                           "speedup": t_off / t_on}
                rows.append([algo, scen,
                             f"{rounds / t_off:.1f} r/s",
                             f"{rounds / t_on:.1f} r/s",
                             f"{t_off / t_on:.2f}x", parity])
            results[algo] = r
    print(common.table(["algo", "measure", "serialized", "pipelined",
                        "delta", "parity"], rows))
    payload = {"n_nodes": N_NODES, "rounds": rounds,
               "eval_every": EVAL_EVERY, "local_steps": LOCAL_STEPS,
               "batch_size": BATCH, "repeats": REPEATS,
               "host_cores": os.cpu_count(),
               "results": results,
               "min_drain_wait_reduction": min(
                   r["blocking_drain"]["wait_reduction"]
                   for r in results.values()),
               "all_parity": all(r["parity"] for r in results.values())}
    out = common.write_bench("pipeline", payload)
    print(f"wrote {out} (host-blocking drain wait down >= "
          f"{payload['min_drain_wait_reduction']:.0%}; wall-clock on a "
          f"{payload['host_cores']}-core host is a parity gate — see "
          "module docstring)")
    return payload


def smoke() -> dict:
    """Pipeline exercise for the dry-run matrix: pipeline=True parity on
    a tiny FACADE run."""
    cfg, ds = common.micro_config(4)
    kw = dict(rounds=4, k=2, degree=2, local_steps=2, batch_size=4,
              lr=0.05, eval_every=2, seed=0)
    off = run_experiment("facade", cfg, ds, pipeline=False, **kw)
    on = run_experiment("facade", cfg, ds, pipeline=True, **kw)
    ok = (off.acc_per_cluster == on.acc_per_cluster
          and off.comm.bytes == on.comm.bytes
          and np.isfinite(on.comm.bytes[-1]))
    return {"status": "ok" if ok else "fail",
            "final_acc": [float(a) for a in on.final_acc],
            "total_bytes": float(on.comm.bytes[-1])}


def smoke_ckpt() -> dict:
    """Pipeline + checkpoint exercise for the dry-run matrix: a
    checkpointed pipelined run must match an uncheckpointed serialized
    one and leave a resumable archive behind."""
    cfg, ds = common.micro_config(4)
    kw = dict(rounds=4, k=2, degree=2, local_steps=2, batch_size=4,
              lr=0.05, eval_every=2, seed=0)
    ref = run_experiment("facade", cfg, ds, **kw)
    with tempfile.TemporaryDirectory(prefix="repro-pipe-ckpt-") as td:
        ck = os.path.join(td, "run.npz")
        got = run_experiment("facade", cfg, ds, pipeline=True, ckpt=ck,
                             **kw)
        resumed = run_experiment("facade", cfg, ds, pipeline=True,
                                 ckpt=ck, **kw)   # finished: no-op replay
        ck_exists = os.path.exists(ck)
    ok = (ref.acc_per_cluster == got.acc_per_cluster
          and ref.comm.bytes == got.comm.bytes
          and got.acc_per_cluster == resumed.acc_per_cluster
          and ck_exists)
    return {"status": "ok" if ok else "fail", "ckpt_written": ck_exists}


if __name__ == "__main__":
    run()
