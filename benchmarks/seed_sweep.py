"""Seed-sweep compile-cache benchmark: ``run_sweep`` (shared EngineCache)
vs naive per-run ``run_experiment`` over the paper's multi-seed regime.

The paper's tables average every (algorithm, imbalance, dataset) cell over
seeds, and ``run_experiment`` historically rebuilt + recompiled the engine
and evaluator per call — S seeds paid S identical XLA compiles. This
benchmark runs 8 seeds x 2 algorithms on the 32-node micro CNN
(eval_every=20) both ways and records wall-clock plus exact compile counts
from the cache's counters.

Acceptance: ZERO engine recompiles after the first run of each cell (the
sweep is run as first-seed pass + remaining-seeds pass on one shared cache
to measure exactly that) and >= 2x wall-clock over the naive driver.
Writes ``results/bench/BENCH_sweep.json``.
"""
from __future__ import annotations

import time

from repro.core.cache import EngineCache
from repro.core.runner import run_experiment
from repro.sweep import SweepCell, aggregate_cell, run_sweep

from . import common

N_NODES = 32
EVAL_EVERY = 20
LOCAL_STEPS = 1
BATCH = 2
ALGOS = ("facade", "el")
N_SEEDS = 8


def _cells(cfg, ds, rounds):
    kw = dict(k=2, degree=4, local_steps=LOCAL_STEPS, batch_size=BATCH,
              lr=0.05, eval_every=EVAL_EVERY)
    return [SweepCell(name=algo, algo=algo, cfg=cfg, dataset=ds,
                      rounds=rounds, kwargs=dict(kw)) for algo in ALGOS]


def run(quick: bool = True) -> dict:
    rounds = 20 if quick else 60
    seeds = tuple(range(N_SEEDS))
    cfg, ds = common.micro_config(N_NODES)
    cells = _cells(cfg, ds, rounds)

    # --- naive: a fresh cache per run — the historical per-call cost ---
    naive_compiles = []
    t0 = time.perf_counter()
    for cell in cells:
        for seed in seeds:
            solo = EngineCache()
            run_experiment(cell.algo, cell.cfg, cell.dataset,
                           rounds=cell.rounds, seed=seed, cache=solo,
                           **cell.kwargs)
            naive_compiles.append(solo.compile_count)
    t_naive = time.perf_counter() - t0

    # --- sweep: one shared cache; split first seed / rest so the compile
    # counter isolates "after the first run of each cell" exactly ---
    shared = EngineCache()
    t0 = time.perf_counter()
    first = run_sweep(cells, seeds[:1], cache=shared)
    compiles_first = shared.compile_count
    rest = run_sweep(cells, seeds[1:], cache=shared)
    t_sweep = time.perf_counter() - t0
    recompiles = shared.compile_count - compiles_first

    results = {}
    rows = []
    for cell, cf, cr in zip(cells, first.cells, rest.cells):
        summary = aggregate_cell(cf.results + cr.results)
        results[cell.name] = summary
        rows.append([cell.name, f"{summary['best_fair_acc']['mean']:.3f}"
                     f"±{summary['best_fair_acc']['std']:.3f}",
                     f"{summary['total_bytes']['mean'] / 1e6:.1f} MB"])
    print(common.table(["cell", "best_fair_acc", "traffic"], rows))

    speedup = t_naive / t_sweep
    payload = {
        "n_nodes": N_NODES, "rounds": rounds, "eval_every": EVAL_EVERY,
        "local_steps": LOCAL_STEPS, "batch_size": BATCH,
        "n_seeds": N_SEEDS, "algos": list(ALGOS),
        "naive": {"wall_s": t_naive, "compiles": sum(naive_compiles),
                  "compiles_per_run": naive_compiles},
        "sweep": {"wall_s": t_sweep, "compiles": shared.compile_count,
                  "compiles_after_first_run_per_cell": compiles_first,
                  "cache": shared.stats()},
        "recompiles_after_first": recompiles,
        "zero_recompiles_after_first": recompiles == 0,
        "speedup": speedup,
        "results": results,
    }
    out = common.write_bench("sweep", payload)
    st = shared.stats()
    print(f"cache: {st['entries']} entries, {st['hits']} hits / "
          f"{st['misses']} misses, {st['compiles']} compiles "
          f"({st['evaluator_builds']} evaluator builds)")
    print(f"wrote {out} (naive {t_naive:.1f}s / sweep {t_sweep:.1f}s = "
          f"{speedup:.2f}x, {recompiles} recompiles after first run)")
    return payload


def smoke() -> dict:
    """Tiny sweep exercise for the dry-run matrix: 2 seeds x 2 algorithms
    at 4 nodes on one shared cache; asserts zero recompiles after the
    first run of each cell."""
    from repro.configs.facade_paper import lenet
    from repro.data.synthetic import SynthSpec

    spec = SynthSpec(n_classes=4, image_size=16, samples_per_class=8,
                     test_per_class=8, seed=3)
    ds = common.make_ds(spec, (3, 1), ("rot0", "rot180"))
    cfg = lenet(smoke=True).replace(n_classes=4)
    kw = dict(k=2, degree=2, local_steps=2, batch_size=4, lr=0.05,
              eval_every=2)
    cells = [SweepCell(name=a, algo=a, cfg=cfg, dataset=ds, rounds=2,
                       kwargs=dict(kw)) for a in ("facade", "el")]
    cache = EngineCache()
    first = run_sweep(cells, (0,), cache=cache)    # first run of each cell
    compiles_first = cache.compile_count
    rest = run_sweep(cells, (1,), cache=cache)     # must all run warm
    recompiles = cache.compile_count - compiles_first
    summaries = [aggregate_cell(f.results + r.results)
                 for f, r in zip(first.cells, rest.cells)]
    ok = (recompiles == 0
          and all(s["n_seeds"] == 2 for s in summaries))
    return {"status": "ok" if ok else "fail",
            "compiles_after_first": compiles_first,
            "recompiles": recompiles,
            "entries": len(cache)}


if __name__ == "__main__":
    run()
